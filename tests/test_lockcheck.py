"""The lockdep-style runtime detector (repro.analysis.lockcheck):
acquisition-order cycle detection, notify-under-lock hazards, and the
crafted pre-PR-7 ReorderArray fixture that the detector must flag while
the current (fixed) pattern stays clean.

Tests build PRIVATE LockCheck instances so the global detector (the one
``pytest --lockcheck`` fails the session on) never sees the deliberate
hazards manufactured here."""
import threading
from collections import deque

from repro.analysis.lockcheck import CheckedLock, LockCheck


# --------------------------------------------------------------------------- ordering
def test_abba_inversion_flagged_single_thread():
    lc = LockCheck()
    a, b = lc.lock("A"), lc.lock("B")
    with a:
        with b:
            pass
    with b:
        with a:  # second ordering observed -> cycle, no deadlock needed
            pass
    kinds = [v.kind for v in lc.violations]
    assert kinds == ["order-cycle"]
    assert "A" in lc.violations[0].detail and "B" in lc.violations[0].detail


def test_abba_inversion_flagged_across_threads():
    lc = LockCheck()
    a, b = lc.lock("A"), lc.lock("B")
    barrier = threading.Barrier(2)

    def t1():
        with a:
            barrier.wait()
            # don't actually take b (that could truly deadlock); the order
            # edge A->B was already recorded below
        barrier.wait()

    def t2():
        barrier.wait()  # t1 holds a
        barrier.wait()
        with b:
            with a:
                pass

    with a:
        with b:
            pass  # record A -> B
    ths = [threading.Thread(target=f) for f in (t1, t2)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert any(v.kind == "order-cycle" for v in lc.violations)


def test_consistent_order_is_clean():
    lc = LockCheck()
    a, b = lc.lock("A"), lc.lock("B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert lc.violations == []
    assert lc.edges() == {"A": {"B"}}


def test_same_class_nesting_flagged():
    lc = LockCheck()
    w1, w2 = lc.lock("wq"), lc.lock("wq")  # two instances, one class
    with w1:
        with w2:
            pass
    assert [v.kind for v in lc.violations] == ["order-cycle"]
    assert "same-class" in lc.violations[0].detail


def test_reentrant_rlock_reacquire_clean():
    lc = LockCheck()
    r = lc.rlock("reorder")
    with r:
        with r:  # same INSTANCE: tracked, not edge-recorded
            assert lc.held() == ["reorder"]
    assert lc.violations == []


def test_duplicate_violations_deduplicated():
    lc = LockCheck()
    a, b = lc.lock("A"), lc.lock("B")
    with a:
        with b:
            pass
    for _ in range(5):
        with b:
            with a:
                pass
    assert len(lc.violations) == 1


# --------------------------------------------------------------------------- notify regions
def test_notify_region_clean_when_unlocked():
    lc = LockCheck()
    with lc.notify_region("callbacks"):
        pass
    assert lc.violations == []


def test_notify_region_flags_held_lock():
    lc = LockCheck()
    eng = lc.lock("engine")
    with eng:
        with lc.notify_region("callbacks"):
            pass
    vs = lc.violations
    assert [v.kind for v in vs] == ["notify-under-lock"]
    assert "engine" in vs[0].detail and "callbacks" in vs[0].detail


# --------------------------------------------------------------------------- factories
def test_disabled_detector_returns_plain_locks():
    lc = LockCheck(enabled=False)
    assert not isinstance(lc.lock("x"), CheckedLock)
    assert not isinstance(lc.rlock("x"), CheckedLock)
    # and plain locks still work as locks
    with lc.lock("x"):
        pass


def test_global_factories_follow_enable_state():
    from repro.analysis import lockcheck as L

    was = L.enabled()
    try:
        L.disable()
        assert not isinstance(L.checked_lock("t"), CheckedLock)
        L.enable()
        lk = L.checked_lock("t")
        assert isinstance(lk, CheckedLock)
        assert lk._check is L.GLOBAL
    finally:
        L.GLOBAL.enabled = was


def test_report_format():
    lc = LockCheck()
    assert "clean" in lc.report()
    a, b = lc.lock("A"), lc.lock("B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    rep = lc.report()
    assert "1 violation" in rep and "order-cycle" in rep


# --------------------------------------------------------------------------- the PR 7 bug class
class _PumpingFuture:
    """Pre-PR-7 future shape: ``is_done()`` PUMPS the engine, which
    dispatches completion listeners right there — inside whatever lock the
    caller happens to hold."""

    def __init__(self, lc, done=True):
        self._lc = lc
        self._done = done

    def is_done(self):
        with self._lc.notify_region("engine.listeners"):
            pass  # listener dispatch happens HERE, inside the caller's lock
        return self._done


class _PassiveFuture:
    """Current-tree future shape: ``is_done()`` only reads the record; the
    wait-policy loop dispatches callbacks outside any subsystem lock."""

    def __init__(self, done=True):
        self._done = done

    def is_done(self):
        return self._done


def _reorder_drain(lc, futures):
    """The ReorderArray commit loop, reduced: pop the completed prefix
    while holding the reorder lock (exactly what pop_completed does)."""
    lock = lc.rlock("serving.reorder")
    entries = deque((i, f) for i, f in enumerate(futures))
    out = []
    with lock:
        while entries:
            tag, fut = entries[0]
            if not fut.is_done():
                break
            entries.popleft()
            out.append(tag)
    return out


def test_lockcheck_reproduces_pre_pr7_reorder_hazard():
    """On the pre-PR-7 pattern — engine-pumping is_done() under the reorder
    lock — the detector flags the held-lock-listener-dispatch hazard that
    had to be found by hand back then."""
    lc = LockCheck()
    committed = _reorder_drain(lc, [_PumpingFuture(lc) for _ in range(3)])
    assert committed == [0, 1, 2]
    vs = lc.violations
    assert any(v.kind == "notify-under-lock" for v in vs)
    v = next(v for v in vs if v.kind == "notify-under-lock")
    assert "serving.reorder" in v.detail and "engine.listeners" in v.detail


def test_current_reorder_pattern_is_clean():
    """The fixed pattern — passive is_done() under the lock, callback
    dispatch outside it (wait_any's notify path) — records nothing."""
    lc = LockCheck()
    committed = _reorder_drain(lc, [_PassiveFuture() for _ in range(3)])
    # dispatch happens after the lock is released:
    with lc.notify_region("engine.listeners"):
        pass
    assert committed == [0, 1, 2]
    assert lc.violations == []


def test_current_serving_reorder_array_is_clean():
    """End-to-end on the REAL ReorderArray: drive push/pop_completed with
    a private detector substituted for its lock; the current implementation
    must not trip notify-under-lock or ordering hazards."""
    from repro.serving.pipeline import ReorderArray

    lc = LockCheck()
    ra = ReorderArray(size=8)
    ra._lock = lc.rlock("serving.reorder")
    futs = [_PassiveFuture(done=False) for _ in range(4)]
    for i, f in enumerate(futs):
        ra.push(i, f, payload=f"p{i}")
    assert ra.pop_completed() == []
    for f in futs[:2]:
        f._done = True
    assert [t for t, _ in ra.pop_completed()] == [0, 1]
    with lc.notify_region("engine.listeners"):
        pass
    for f in futs:
        f._done = True
    assert [t for t, _ in ra.pop_completed()] == [2, 3]
    assert lc.violations == []
