"""Completion subsystem (core/completion.py): WaitPolicy host-cycle
accounting, wait_any/wait_all/as_completed ordering and error propagation,
interrupt coalescing, and exactly-once callbacks under concurrent waiters."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    InterruptWait,
    OpType,
    PauseWait,
    SpinWait,
    Status,
    UmwaitWait,
    WaitTimeout,
    WorkDescriptor,
    get_wait_policy,
    make_device,
)
from repro.core.telemetry import Telemetry


def _x(shape=(32, 128)):
    return jnp.asarray(np.arange(np.prod(shape)).reshape(shape), jnp.float32)


def _bad_desc():
    return WorkDescriptor(op=OpType.DELTA_APPLY, src=None, src_idx=None, src2=None)


# --------------------------------------------------------------------------- policies
@pytest.mark.parametrize("policy", ["spin", "pause", "umwait", "interrupt"])
def test_each_policy_completes_and_accounts(policy):
    d = make_device(wait_policy=policy)
    x = _x()
    futs = [d.memcpy_async(x) for _ in range(5)]
    assert d.wait_all(futs) == futs
    for f in futs:
        assert f.status == Status.SUCCESS
        assert np.allclose(np.asarray(f.record.result), np.asarray(x))
    ws = d.wait_stats[policy]
    assert ws.waits == 1
    assert ws.polls >= 1
    assert ws.busy_s > 0


def test_spin_and_pause_never_free_the_host():
    for policy in ("spin", "pause"):
        d = make_device(wait_policy=policy)
        d.wait_all([d.memcpy_async(_x()) for _ in range(4)])  # dsalint: disable=DSA106 — per-descriptor path under test
        ws = d.wait_stats[policy]
        assert ws.free_s == 0.0  # the core never parks
        assert ws.wakes == 0 and ws.irqs == 0
        assert ws.host_free_frac == 0.0


def test_umwait_parks_host_free():
    """Gate completion on a host event so the wait MUST park: the parked
    interval is measured free time, each wake bills the modeled exit
    latency."""
    d = make_device(wait_policy="umwait")
    gate = d.promise()
    fut = d.memcpy_async(_x(), after=[gate])
    t = threading.Timer(0.05, gate.set_result, args=(None,))
    t.start()
    d.wait_all([fut])
    assert fut.status == Status.SUCCESS
    ws = d.wait_stats["umwait"]
    assert ws.free_s > 0.02  # parked across the gate delay
    assert ws.wakes >= 1
    assert ws.modeled_overhead_s > 0  # wake latency billed
    assert 0.0 < ws.host_free_frac <= 1.0


def test_interrupt_coalesces_completions():
    # a wide coalescing window makes the batching deterministic: the first
    # wake holds the IRQ open until the remaining in-flight copies land
    d = make_device(wait_policy=InterruptWait(coalesce_window_s=0.25))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(512, 512)), jnp.float32)
    d.memcpy_async(x).wait(policy="spin")  # warm the kernel off-bucket
    # fence the batch on a promise so no copy retires before the wait: every
    # completion is then observed (and coalesced) by the wait itself
    gate = d.promise()
    futs = [d.memcpy_async(x, after=[gate]) for _ in range(8)]
    gate.set_result(None)
    d.wait_all(futs)
    ws = d.wait_stats["interrupt"]
    assert ws.completions == 8
    assert 1 <= ws.irqs <= 3  # coalesced: far fewer IRQs than completions
    assert ws.irqs == ws.wakes
    if ws.irqs:
        assert ws.modeled_overhead_s > 0  # per-IRQ cost billed


def test_policy_instances_and_overrides():
    d = make_device(wait_policy="spin")
    assert d.wait_policy.name == "spin"
    # per-wait override routes accounting to the override's bucket
    d.wait_all([d.memcpy_async(_x())], policy="umwait")
    assert d.wait_stats["umwait"].waits == 1
    assert d.wait_stats["spin"].waits == 0
    # policy instances pass through, with custom knobs
    pol = InterruptWait(irq_cost_s=1e-6, coalesce_window_s=0.0)
    d.wait_all([d.memcpy_async(_x())], policy=pol)
    assert d.wait_stats["interrupt"].waits == 1


def test_get_wait_policy_validates():
    with pytest.raises(ValueError, match="unknown wait policy"):
        get_wait_policy("busyloop")
    p = UmwaitWait()
    assert get_wait_policy(p) is p
    assert isinstance(get_wait_policy(None), UmwaitWait)
    assert isinstance(get_wait_policy("pause"), PauseWait)
    assert isinstance(get_wait_policy("spin"), SpinWait)


def test_future_wait_routes_through_subsystem():
    """Future.wait() is no longer a private busy-pump: it is a one-element
    set wait under the device's policy, so every wait shows up in the
    host-cycle accounting."""
    d = make_device()  # default policy: umwait
    out = d.memcpy_async(_x()).wait()
    assert np.allclose(np.asarray(out), np.asarray(_x()))
    assert d.wait_stats["umwait"].waits >= 1


# --------------------------------------------------------------------------- set primitives
def test_wait_any_returns_first_available():
    d = make_device()
    gate = d.promise()
    blocked = d.memcpy_async(_x(), after=[gate])
    free = d.memcpy_async(_x())
    done, pending = d.wait_any([blocked, free])
    assert free in done
    assert blocked in pending
    gate.set_result(None)
    d.wait_all([blocked])
    assert blocked.status == Status.SUCCESS


def test_wait_any_timeout_zero_is_single_poll():
    d = make_device()
    gate = d.promise()
    fut = d.memcpy_async(_x(), after=[gate])
    t0 = time.perf_counter()
    done, pending = d.wait_any([fut], timeout=0)
    assert time.perf_counter() - t0 < 1.0  # no park, no spin
    assert done == [] and pending == [fut]
    gate.set_result(None)
    d.wait_all([fut])


def test_wait_all_timeout_raises():
    d = make_device()
    gate = d.promise()
    fut = d.memcpy_async(_x(), after=[gate])
    with pytest.raises(WaitTimeout):
        d.wait_all([fut], timeout=0.05)
    gate.set_result(None)
    d.wait_all([fut])  # still completable afterwards


def test_as_completed_yields_in_completion_order():
    d = make_device()
    gate = d.promise()
    late = d.memcpy_async(_x(), after=[gate])
    early = d.memcpy_async(_x())
    it = d.as_completed([late, early])
    first = next(it)
    assert first is early  # completion order, not submission order
    gate.set_result(None)
    second = next(it)
    assert second is late
    with pytest.raises(StopIteration):
        next(it)


def test_as_completed_propagates_errors():
    d = make_device()
    bad = d.submit(_bad_desc())
    good = d.memcpy_async(_x())
    seen = list(d.as_completed([bad, good]))
    assert set(seen) == {bad, good}
    assert bad.status == Status.ERROR
    with pytest.raises(RuntimeError):
        bad.result()
    assert good.status == Status.SUCCESS


def test_wait_all_surfaces_failed_dependents():
    """wait_all treats a failed descriptor as complete; result() raises."""
    d = make_device()
    gate = d.promise()
    child = d.memcpy_async(_x(), after=[gate])
    gate.set_error("upstream torn")
    d.wait_all([child])
    assert child.status == Status.ERROR
    with pytest.raises(RuntimeError):
        child.result()


def test_set_waits_cover_chained_futures():
    d = make_device()
    chained = d.crc32_async(jnp.asarray([1, 2, 3, 4], jnp.uint32)).then(
        lambda c: int(c) & 0xFFFFFFFF
    )
    d.wait_all([chained])
    assert chained.status == Status.SUCCESS
    assert isinstance(chained.record.result, int)


# --------------------------------------------------------------------------- callbacks under concurrency
def test_callbacks_fire_exactly_once_with_concurrent_waiters():
    d = make_device(n_instances=2)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(256, 128)), jnp.float32)
    for _ in range(3):  # repeat to shake races
        fut = d.memcpy_async(x)  # dsalint: disable=DSA106 — per-descriptor path under test
        fired = []
        lock = threading.Lock()

        def cb(f):
            with lock:
                fired.append(threading.get_ident())

        fut.add_done_callback(cb)
        threads = [threading.Thread(target=fut.wait) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        fut.wait()
        assert len(fired) == 1, f"callback fired {len(fired)} times"


def test_callbacks_fire_outside_engine_lock():
    """Completion callbacks must not run under the device's engine lock: a
    blocking callback would deadlock any other thread mid-wait.  The
    notification queue defers firing until the pumping thread releases it."""
    d = make_device()
    held = []
    fut = d.memcpy_async(_x())
    fut.add_done_callback(lambda f: held.append(d._engine_lock._is_owned()))
    d.wait_all([fut])
    assert held == [False]


# --------------------------------------------------------------------------- new op helpers
def test_dif_and_compare_pattern_helpers():
    """Satellite: the OpType members that existed without Device sugar —
    DIF insert/check/strip and compare_pattern — surfaced as *_async
    helpers and driven through the completion subsystem."""
    d = make_device()
    w = jnp.asarray(np.random.default_rng(2).integers(0, 2**32, 1024, dtype=np.uint32))
    framed = d.dif_insert_async(w).result()
    assert framed.shape == (8, 130)  # 128-word blocks + crc + tag
    check, strip = d.wait_all([d.dif_check_async(framed),
                               d.dif_strip_async(framed)])
    assert bool(np.asarray(check.result()).all())
    assert (np.asarray(strip.result()) == np.asarray(w)).all()
    pat = jnp.asarray([0xDEADBEEF], jnp.uint32)
    eq, first = d.compare_pattern_async(jnp.full((256,), 0xDEADBEEF, jnp.uint32),
                                        pat).result()
    assert bool(eq)
    neq, first = d.compare_pattern_async(w, pat).result()
    assert not bool(neq)
    assert int(first) >= 0


# --------------------------------------------------------------------------- telemetry
def test_telemetry_reports_wait_accounting():
    d = make_device(wait_policy="umwait")
    tel = Telemetry(d)
    d.wait_all([d.memcpy_async(_x()) for _ in range(3)])
    snap = tel.snapshot()
    assert "umwait" in snap["wait"]
    ws = snap["wait"]["umwait"]
    for key in ("waits", "polls", "wakes", "irqs", "busy_s", "free_s",
                "host_free_frac", "modeled_overhead_s", "completions"):
        assert key in ws
    assert "wait umwait:" in tel.report()
