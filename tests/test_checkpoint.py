"""Checkpoint manager: full/delta round trips, CRC corruption fallback,
replica (dualcast) recovery, elastic restore, async overlap."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager


def _tree(rng, scale=1.0):
    return {
        "params": {
            "w": jnp.asarray(rng.normal(size=(64, 32)) * scale, jnp.float32),
            "b": jnp.asarray(rng.normal(size=(32,)) * scale, jnp.bfloat16),
        },
        "step_count": jnp.asarray(3, jnp.int32),
    }


def test_full_roundtrip(tmp_path, rng):
    m = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))
    t = _tree(rng)
    m.save(1, t)
    step, restored = m.restore(treedef_like=t)
    assert step == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_delta_saves_space_and_roundtrips(tmp_path, rng):
    m = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), async_save=False, full_every=100)
    )
    t = _tree(rng)
    m.save(1, t)  # full
    # small change -> delta save
    t2 = jax.tree.map(lambda x: x, t)
    t2["params"]["w"] = t["params"]["w"].at[0, 0].add(1.0)
    m.save(2, t2)
    assert m.stats["delta_leaves"] >= 1
    assert m.stats["bytes_saved_by_delta"] > 0
    step, restored = m.restore(treedef_like=t)
    assert step == 2
    assert np.allclose(np.asarray(restored["params"]["w"]), np.asarray(t2["params"]["w"]))


def test_delta_overflow_falls_back_to_full(tmp_path, rng):
    m = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), async_save=False, full_every=100,
                         delta_cap_frac=0.01)
    )
    t = _tree(rng)
    m.save(1, t)
    t2 = jax.tree.map(lambda x: x + 1, t)  # everything changes
    m.save(2, t2)
    assert m.stats["delta_overflows"] >= 1
    _, restored = m.restore(treedef_like=t)
    assert np.allclose(np.asarray(restored["params"]["w"]), np.asarray(t2["params"]["w"]))


def test_crc_detects_corruption_and_falls_back(tmp_path, rng):
    m = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))
    t = _tree(rng)
    m.save(1, t)
    m.save(2, jax.tree.map(lambda x: x + 1, t), force_full=True)
    # corrupt the newest save
    target = next((tmp_path / "step_00000002").glob("params__w.bin"))
    raw = bytearray(target.read_bytes())
    raw[10] ^= 0xFF
    target.write_bytes(bytes(raw))
    step, restored = m.restore(treedef_like=t)
    assert step == 1  # fell back past the corrupt save
    assert np.allclose(np.asarray(restored["params"]["w"]), np.asarray(t["params"]["w"]))


def test_replica_recovers_corruption(tmp_path, rng):
    m = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path / "ck"), async_save=False, replicas=2)
    )
    t = _tree(rng)
    m.save(1, t)
    target = next((tmp_path / "ck" / "step_00000001").glob("params__w.bin"))
    raw = bytearray(target.read_bytes())
    raw[0] ^= 0xFF
    target.write_bytes(bytes(raw))
    step, restored = m.restore(treedef_like=t)  # dualcast replica saves the day
    assert step == 1
    assert np.allclose(np.asarray(restored["params"]["w"]), np.asarray(t["params"]["w"]))


def test_async_save_overlaps(tmp_path, rng):
    m = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=True))
    t = _tree(rng)
    m.save(1, t)  # returns immediately
    m.save(2, jax.tree.map(lambda x: x + 1, t))  # waits for save 1 internally
    m.wait()
    assert m.all_steps() == [1, 2]


def test_elastic_restore_resharding(tmp_path, rng):
    """Save on one device layout, restore with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    m = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))
    t = _tree(rng)
    m.save(1, t)
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    step, restored = m.restore(shardings=sh, treedef_like=t)
    w = restored["params"]["w"]
    assert isinstance(w, jax.Array) and w.sharding == NamedSharding(mesh, P())
    assert np.allclose(np.asarray(w), np.asarray(t["params"]["w"]))


def test_kernel_crc_impl_equivalent(tmp_path, rng):
    """crc_impl='kernel' (on-device Pallas CRC) agrees with zlib on save."""
    t = {"w": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)}
    m1 = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path / "a"), async_save=False, crc_impl="kernel")
    )
    m1.save(1, t)
    man = json.loads((tmp_path / "a" / "step_00000001" / "manifest.json").read_text())
    import zlib

    want = zlib.crc32(np.asarray(t["w"]).tobytes()) & 0xFFFFFFFF
    assert man["leaves"]["w"]["crc"] == want


def test_kernel_crc_routes_through_device(tmp_path, rng):
    """With a Device attached, kernel CRCs are engine descriptors: they agree
    with zlib AND show up in the device's submission telemetry."""
    from repro.core import make_device

    d = make_device()
    t = {"w": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)}
    m = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path / "dev"), async_save=False,
                         crc_impl="kernel"),
        device=d,
    )
    m.save(1, t)
    man = json.loads((tmp_path / "dev" / "step_00000001" / "manifest.json").read_text())
    import zlib

    want = zlib.crc32(np.asarray(t["w"]).tobytes()) & 0xFFFFFFFF
    assert man["leaves"]["w"]["crc"] == want
    # the save path reads each leaf out anyway, so the CRC rides the fused
    # copy+CRC descriptor (one launch instead of a copy pass plus a CRC pass)
    assert d.policy_stats["decisions_by_op"].get("dsa0/copy_crc", 0) >= 1
