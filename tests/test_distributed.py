"""Sharding rules, fault tolerance, collectives, and the HLO cost model."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault import (
    Heartbeat,
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
    run_with_restarts,
)
from repro.distributed.sharding import ShardingRules
from jax.sharding import PartitionSpec as P

# minutes-scale on CPU: excluded from the quick lane (-m "not slow")
pytestmark = pytest.mark.slow


def _rules(model=16, data=16, pod=None):
    axes = {"data": data, "model": model}
    if pod:
        axes["pod"] = pod
    table = {
        "batch": tuple(a for a in ("pod", "data") if a in axes),
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "seq": None,
    }
    return ShardingRules(mesh_axes=axes, table=table)


def test_divisibility_fallback():
    r = _rules()
    # 25 heads % 16 != 0 -> replicated (batch 32 IS divisible by data=16)
    assert r.spec((32, 128, 25, 64), ("batch", None, "heads", None)) == P("data", None, None, None)
    # 64 heads -> sharded
    assert r.spec((32, 128, 64, 64), ("batch", None, "heads", None)) == P("data", None, "model", None)
    # odd vocab replicates
    assert r.spec((50280, 1024), ("vocab", None)) == P(None, None)
    assert r.spec((262144, 1024), ("vocab", None)) == P("model", None)


def test_no_duplicate_mesh_axes():
    r = _rules()
    # both dims want "model": second falls back
    spec = r.spec((64, 22016), ("heads", "mlp"))
    assert spec == P("model", None)


def test_multi_axis_batch():
    r = _rules(pod=2)
    spec = r.spec((256, 4096), ("batch", None))
    assert spec == P(("pod", "data"), None)
    # batch=2 not divisible by 2*16 -> replicate
    assert r.spec((2, 16), ("batch", None)) == P(None, None)


def test_zero1_pspec():
    from repro.distributed.params import zero1_pspec

    r = _rules()
    # param replicated on dim0 (4096 % 16 == 0) -> moments shard over data
    s = zero1_pspec(P(None, "model"), (4096, 22016), r)
    assert s == P("data", "model")
    # nothing divisible -> unchanged
    s = zero1_pspec(P(None,), (17,), r)
    assert s == P(None)


# --------------------------------------------------------------------------- fault tolerance
def test_heartbeat_monitor(tmp_path):
    hb = Heartbeat(str(tmp_path), rank=0)
    hb.beat(5)
    mon = HeartbeatMonitor(str(tmp_path), world_size=2, timeout_s=60)
    dead = mon.dead_ranks()
    assert dead == [1]  # rank 1 never beat


def test_straggler_detector():
    det = StragglerDetector(min_samples=4, z_threshold=2.0)
    for step in range(10):
        for r in range(7):
            det.record(r, 0.1)
        det.record(7, 0.5)  # rank 7 is slow
    assert det.stragglers() == [7]


def test_run_with_restarts_recovers():
    calls = {"n": 0}
    saved = {"step": 0}

    def train_fn(start):
        calls["n"] += 1
        for i in range(start, 10):
            saved["step"] = i
            if calls["n"] == 1 and i == 4:
                raise RuntimeError("simulated node failure")
        return 10

    final = run_with_restarts(
        train_fn, lambda: saved["step"], RestartPolicy(backoff_base_s=0.0), sleep=lambda s: None
    )
    assert final == 10 and calls["n"] == 2


def test_restart_policy_bounds():
    p = RestartPolicy(max_restarts=2, backoff_base_s=0.0)
    assert p.should_restart()
    p.backoff()
    p.backoff()
    assert not p.should_restart()


# --------------------------------------------------------------------------- collectives
def test_compressed_psum_single_device():
    from repro.distributed.collectives import compressed_psum_tree

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    red, fb = compressed_psum_tree(g, mesh, "data")
    # n=1: reduction is identity up to int8 quantization error
    err = np.abs(np.asarray(red["w"]) - np.asarray(g["w"])).max()
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err <= scale * 1.01
    # error feedback carries the quantization residual
    assert np.abs(np.asarray(fb["w"])).max() <= scale * 1.01


def test_ring_all_reduce_single_device():
    from repro.distributed.collectives import ring_all_reduce

    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(12.0).reshape(3, 4)
    y = ring_all_reduce(x, mesh, "data")
    assert np.allclose(np.asarray(y), np.asarray(x))


# --------------------------------------------------------------------------- hlo cost model
def test_hlo_cost_counts_loop_trips():
    from repro.roofline.hlo_cost import analyze_hlo

    L, B, D = 7, 32, 64

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jnp.zeros((L, D, D), jnp.float32)
    x = jnp.zeros((B, D), jnp.float32)
    comp = jax.jit(f).lower(ws, x).compile()
    cost = analyze_hlo(comp.as_text())
    assert cost.flops == pytest.approx(L * 2 * B * D * D, rel=0.01)
    g = jax.jit(jax.grad(f)).lower(ws, x).compile()
    cost_g = analyze_hlo(g.as_text())
    assert cost_g.flops == pytest.approx(3 * L * 2 * B * D * D, rel=0.05)


_COLL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_cost import analyze_hlo

mesh = jax.make_mesh((4,), ("model",))
w = jax.ShapeDtypeStruct((256, 512), jnp.float32, sharding=NamedSharding(mesh, P(None, "model")))
x = jax.ShapeDtypeStruct((64, 256), jnp.float32, sharding=NamedSharding(mesh, P()))


def f(x, w):
    h = x @ w  # column-parallel
    return (h @ w.T).sum()  # row-parallel -> psum


with mesh:
    comp = jax.jit(f).lower(x, w).compile()
cost = analyze_hlo(comp.as_text())
assert cost.coll_bytes > 0, "expected collectives"
assert "all-reduce" in cost.coll_ops or "reduce-scatter" in cost.coll_ops, cost.coll_ops
# ring model: AR of [64,256] f32 over 4 devices = 2*(3/4)*64*256*4 bytes,
# possibly on a scalar instead if XLA reduces post-sum; just bound it
assert cost.coll_bytes < 1e8
print("COLL OK", cost.coll_bytes)
"""


def test_hlo_cost_collectives_counted(tmp_path):
    """Collective byte accounting on a real sharded module (subprocess: the
    main pytest process is pinned to 1 device)."""
    import subprocess
    import sys
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    res = subprocess.run(
        [sys.executable, "-c", _COLL_SCRIPT],
        env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "COLL OK" in res.stdout
