"""Per-architecture smoke tests: REDUCED config of the same family, one
train step + prefill + decode on CPU, asserting shapes and finiteness.
Also checks prefill+decode consistency against teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build_model, make_batch

B, S = 2, 32

# minutes-scale on CPU: excluded from the quick lane (-m "not slow")
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=True)
    params = model.init(key)
    batch = make_batch(cfg, B, S, key, kind="train")
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    # gradient flows and is finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(jnp.isfinite(x).all() for x in leaves), arch
    assert any(float(jnp.abs(x.astype(jnp.float32)).max()) > 0 for x in leaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(key)
    batch = make_batch(cfg, B, S, key, kind="prefill")
    cache, logits, lengths = model.prefill(params, batch, max_cache_len=S + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), arch
    assert int(cache["lengths"][0]) == int(lengths[0]) + 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma3-1b", "mamba2-370m",
                                  "deepseek-moe-16b", "hymba-1.5b"])
def test_decode_matches_teacher_forcing(arch, key):
    """prefill(t[:k]) + decode(t[k]) must reproduce the teacher-forced
    logits of the full sequence (cache correctness).

    MoE capacity dropping is sequence-length dependent (a token near the
    end may be dropped in the longer prefill but not the shorter one), so
    the consistency check runs in the no-drop regime (high capacity)."""
    import dataclasses

    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg, remat=False)
    params = model.init(key)
    toks = jax.random.randint(jax.random.key(7), (1, S), 0, cfg.vocab_size, dtype=jnp.int32)

    # teacher forcing: logits at position S-1 from a full prefill
    _, logits_full, _ = model.prefill(params, {"tokens": toks}, max_cache_len=S + 4)

    # prefill on S-1 tokens then decode token S-1
    cache, _, _ = model.prefill(params, {"tokens": toks[:, : S - 1]}, max_cache_len=S + 4)
    logits_step, _ = model.decode_step(params, cache, toks[:, S - 1 :])

    np.testing.assert_allclose(
        np.asarray(logits_step, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=0.08, atol=0.08,  # bf16 accumulation differences
    )


def test_gemma3_layer_pattern():
    cfg = get_config("gemma3-4b")
    lt = cfg.layer_types()
    assert len(lt) == 34
    assert lt[5] == "global" and lt[11] == "global"
    assert lt[:5] == ("local",) * 5
    assert sum(t == "global" for t in lt) == 5  # 34 = 5 full periods + 4 locals


def test_moe_aux_loss_nonzero(key):
    cfg = get_config("deepseek-moe-16b").reduced()
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, B, S, key, kind="train")
    _, metrics = model.loss(params, batch)
    assert float(metrics["aux"]) > 0.0


def test_loss_decreases_short_training(key):
    """5-step integration: loss moves down on learnable synthetic data."""
    from repro.data.pipeline import SyntheticLMDataset
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamW

    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(key)
    opt = AdamW(lr=5e-3)
    opt_state = opt.init(params)
    ds = SyntheticLMDataset(cfg, batch=8, seq_len=64)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses


def test_grad_accumulation_consistency(key):
    """micro_steps=2 ~= micro_steps=1 on the same batch (fp32 accumulation)."""
    from repro.optim.gradients import GradAccumulator

    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(key)
    batch = make_batch(cfg, 4, 32, key, kind="train")
    l1, _, g1 = GradAccumulator.accumulate(model.loss, params, batch, 1)
    l2, _, g2 = GradAccumulator.accumulate(model.loss, params, batch, 2)
    assert abs(float(l1) - float(l2)) < 0.05
    n1 = jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32))) for a in jax.tree.leaves(g1)))
    n2 = jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32))) for a in jax.tree.leaves(g2)))
    assert abs(float(n1) - float(n2)) / max(float(n1), 1e-6) < 0.1
