import os
import sys
from pathlib import Path

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see the single real device (dry-run sets its
# own flag as its first import action).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import zlib

import numpy as np
import pytest


@pytest.fixture
def rng(request):
    """Deterministic PER TEST: the generator is keyed by the test's node id,
    so every test draws the same stream whether it runs alone, in a file
    subset, or in the full suite.  (The old session-scoped fixture advanced
    one shared stream in collection order, so subsets saw different data
    than the full run.)"""
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))
