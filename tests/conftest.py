import os
import sys
from pathlib import Path

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see the single real device (dry-run sets its
# own flag as its first import action).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
