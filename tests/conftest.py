import os
import sys
from pathlib import Path

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see the single real device (dry-run sets its
# own flag as its first import action).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import zlib

import numpy as np
import pytest


@pytest.fixture
def rng(request):
    """Deterministic PER TEST: the generator is keyed by the test's node id,
    so every test draws the same stream whether it runs alone, in a file
    subset, or in the full suite.  (The old session-scoped fixture advanced
    one shared stream in collection order, so subsets saw different data
    than the full run.)"""
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


# --------------------------------------------------------------------------- lockcheck
def pytest_addoption(parser):
    parser.addoption(
        "--lockcheck", action="store_true", default=False,
        help="instrument core locks with the repro.analysis.lockcheck "
             "lockdep detector; the session fails if any ordering or "
             "notify-under-lock hazards are recorded")


def pytest_configure(config):
    if config.getoption("--lockcheck"):
        # enable BEFORE collection imports repro.core: locks are plain or
        # instrumented at construction time, so the detector must be on
        # before any Device/engine objects exist
        from repro.analysis import lockcheck

        lockcheck.enable()


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not config.getoption("--lockcheck"):
        return
    from repro.analysis import lockcheck

    terminalreporter.section("lockcheck")
    terminalreporter.write_line(lockcheck.report())


def pytest_sessionfinish(session, exitstatus):
    if not session.config.getoption("--lockcheck"):
        return
    from repro.analysis import lockcheck

    if lockcheck.violations() and exitstatus == 0:
        session.exitstatus = 1
