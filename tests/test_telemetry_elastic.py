"""Telemetry counters + elastic restore across different mesh shapes."""
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_device
from repro.core.telemetry import Telemetry

SRC = str(Path(__file__).resolve().parent.parent / "src")

# minutes-scale (subprocess jax re-init): excluded from the quick lane
pytestmark = pytest.mark.slow


def test_telemetry_counters(rng):
    d = make_device(n_instances=2)
    tele = Telemetry(d)  # device-attached: per-op rows + policy attribution
    big = jnp.asarray(rng.normal(size=(1024, 128)), jnp.float32)  # 512KB
    small = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)  # 4KB
    for _ in range(3):
        d.memcpy_async(big).wait()  # dsalint: disable=DSA106 — per-descriptor path under test
        d.crc32_async(small).wait()  # dsalint: disable=DSA106 — per-descriptor path under test
        tele.sample()
    snap = tele.snapshot()
    total_ops = sum(
        c["count"] for e in snap["engines"].values() for c in e["ops"].values()
    )
    total_bytes = sum(
        c["bytes"] for e in snap["engines"].values() for c in e["ops"].values()
    )
    assert total_ops == 6
    assert total_bytes == 3 * (big.size + small.size) * 4
    # per-op attribution: the op name is carried on the completion record
    keys = {k for e in snap["engines"].values() for k in e["ops"]}
    assert any(k.startswith("memcpy/") for k in keys)
    assert any(k.startswith("crc32/") for k in keys)
    # per-policy-decision attribution
    assert snap["policy"]["name"] == "round_robin"
    assert sum(snap["policy"]["decisions"].values()) == 6
    assert "projected" in tele.report()
    assert "policy round_robin" in tele.report()


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointConfig, CheckpointManager

d = sys.argv[1]
tree = {"w": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
        "b": jnp.ones((32,), jnp.bfloat16)}

# save on a (2,2) mesh with w sharded 2-way
mesh_a = jax.make_mesh((2, 2), ("data", "model"))
w_a = jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model")))
m = CheckpointManager(CheckpointConfig(directory=d, async_save=False))
m.save(1, {"w": w_a, "b": tree["b"]})

# restore onto a DIFFERENT mesh shape (4,2) with a different layout
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh_b, P("model", "data")), "b": NamedSharding(mesh_b, P())}
step, restored = m.restore(shardings=sh, treedef_like=tree)
assert step == 1
assert restored["w"].sharding == sh["w"]
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
np.testing.assert_array_equal(
    np.asarray(restored["b"], np.float32), np.asarray(tree["b"], np.float32)
)
print("ELASTIC OK")
"""


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoints are logical: save sharded on a (2,2) mesh, restore onto a
    (4,2) mesh with a different PartitionSpec — bit-identical values."""
    res = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT, str(tmp_path)],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "ELASTIC OK" in res.stdout
