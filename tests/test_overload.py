"""Overload soak: 2x-capacity open-loop traffic through the real pipeline.

The server runs with the NullDecoder (constant-work model), so thousands of
virtual-clock steps exercise the REAL datapath — priority WQs, batch
descriptors, reorder array, paged KV pool — while the assertions stay about
queueing and admission, not model compute.  The invariants pinned here are
the ones ISSUE.md names:

  conservation   admitted + shed + in-flight == generated (in-flight == 0
                 after drain), and the AdmissionController's own per-class
                 ledger closes;
  no KV leak     every reserved page is back in the pool after drain;
  SLO isolation  the latency class's p99 stays strictly below bulk's under
                 overload (priority admission + priority WQ + shed-first
                 bulk).
"""
import numpy as np
import pytest

from repro.serving.kv_pool import PagedKVPool
from repro.serving.nullmodel import NullDecoder
from repro.serving.pipeline import VhostStyleServer
from repro.serving.slo import (
    DEFAULT_SLO_CLASSES,
    AdmissionController,
    LatencyTracker,
    SLOClass,
    percentile,
)
from repro.serving.traffic import PoissonArrivals, TrafficGenerator, ZipfLengths


def _make_server(*, slots=4, pool_pages=64, watermark=24):
    pool = PagedKVPool(n_device_pages=pool_pages, n_host_pages=4,
                       page_tokens=32, kv_dim=8)
    adm = AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=watermark)
    tracker = LatencyTracker(DEFAULT_SLO_CLASSES)
    server = VhostStyleServer(NullDecoder(64), {}, slots=slots,
                              max_cache_len=128, kv_pool=pool,
                              admission=adm, tracker=tracker)
    return server, pool, adm, tracker


def _traffic(rate_rps: float, seed: int = 7) -> TrafficGenerator:
    return TrafficGenerator(
        PoissonArrivals(rate_rps, seed=seed),
        prompt_lengths=ZipfLengths(s=1.2, lo=8, hi=64),
        output_lengths=ZipfLengths(s=1.2, lo=2, hi=16),
        class_mix={"latency": 0.25, "bulk": 0.75},
        seed=seed,
    )


def test_overload_soak_conservation_and_slo_isolation():
    """2x-capacity Poisson for several virtual seconds, then drain."""
    server, pool, adm, tracker = _make_server()
    # capacity ~ slots / (mean decode steps per request * step_s); offered
    # is ~2x that, so the watermark + shed-first machinery must engage
    report = server.run_open_loop(_traffic(150.0), horizon_s=6.0,
                                  step_s=0.02, vocab_size=64)

    # -- conservation -------------------------------------------------------
    assert report["generated"] > 400  # the soak actually soaked
    assert report["in_flight"] == 0   # drained
    assert (report["admitted"] + report["shed"] + report["in_flight"]
            == report["generated"])
    assert report["admitted"] == report["completed"]
    assert adm.closes()               # per-class ledger closes too
    t = adm.totals()
    assert t["generated"] == report["generated"]
    assert t["admitted"] + t["shed"] == t["generated"]

    # -- overload engaged gracefully ---------------------------------------
    assert report["shed"] > 0
    assert report["completed"] > 100  # still doing real work while shedding
    assert 0 < report["sustained_rps"] < report["offered_rps"]

    # -- no KV page leak after drain ---------------------------------------
    assert pool.stats.device_pages_used == 0
    assert pool.stats.host_pages_used == 0
    assert not pool.page_table
    assert len(server.queue) == 0 and not server.active
    assert len(server.reorder) == 0

    # -- SLO isolation under overload --------------------------------------
    # bulk is shed-first AND priority-starved at 2x load: few completions
    # survive, but enough to compare tails
    assert tracker.count("latency") > 50 and tracker.count("bulk") >= 10
    lat_p99 = tracker.p("latency", 99)
    bulk_p99 = tracker.p("bulk", 99)
    assert lat_p99 < bulk_p99  # strictly: priority admission + shed-first bulk
    # bulk absorbs the shedding, the latency class keeps its admissions
    assert (adm.counters["bulk"]["shed"]
            > adm.counters["latency"]["shed"])


def test_underload_sheds_nothing_and_meets_targets():
    server, pool, adm, tracker = _make_server()
    # step_s=0.01: a 16-token response costs ~0.18 virtual seconds unloaded,
    # inside the 0.25s latency-class target the summary asserts below
    report = server.run_open_loop(_traffic(8.0, seed=3), horizon_s=5.0,
                                  step_s=0.01, vocab_size=64)
    assert report["generated"] > 20
    assert report["shed"] == 0
    assert report["completed"] == report["generated"]
    assert pool.stats.device_pages_used == 0 and not pool.page_table
    s = tracker.summary()
    # lightly-loaded server: both classes inside their p99 targets
    assert s["latency"]["p99_s"] <= tracker.classes["latency"].target_p99_s
    assert s["bulk"]["p99_s"] <= tracker.classes["bulk"].target_p99_s
    assert report["goodput_rps"] == pytest.approx(report["sustained_rps"])


def test_soak_trace_is_deterministic_and_always_conserves():
    """Same traffic seed, fresh server: the generated population is
    identical (the trace is pure), and the conservation identity closes on
    every run even though engine copy timings are wall-clock and may shift
    a request between completed and shed."""
    r1 = _make_server()[0].run_open_loop(_traffic(150.0), horizon_s=3.0,
                                         step_s=0.02, vocab_size=64)
    r2 = _make_server()[0].run_open_loop(_traffic(150.0), horizon_s=3.0,
                                         step_s=0.02, vocab_size=64)
    assert r1["generated"] == r2["generated"]
    for r in (r1, r2):
        assert r["in_flight"] == 0
        assert r["admitted"] + r["shed"] == r["generated"]
        assert r["admitted"] == r["completed"]


def test_kv_pressure_backpressure_no_leak():
    """A tiny device pool forces KV-allocation backpressure mid-run; pages
    must still all come home and the ledger must still close."""
    server, pool, adm, _ = _make_server(pool_pages=6, watermark=16)
    report = server.run_open_loop(_traffic(120.0, seed=11), horizon_s=4.0,
                                  step_s=0.02, vocab_size=64)
    assert server.metrics["kv_alloc_failures"] > 0  # pressure actually hit
    assert report["in_flight"] == 0
    assert (report["admitted"] + report["shed"] == report["generated"])
    assert pool.stats.device_pages_used == 0 and not pool.page_table
    assert adm.closes()


# --------------------------------------------------------------------------- controller units
def test_admission_watermark_and_shed_first_budget():
    adm = AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=8)
    # protected class admits up to the full watermark
    assert adm.admit("latency", queue_depth=7)
    assert not adm.admit("latency", queue_depth=8)
    # shed-first class gets half the budget
    assert adm.admit("bulk", queue_depth=3)
    assert not adm.admit("bulk", queue_depth=4)
    assert adm.closes()
    assert adm.counters["bulk"]["shed_watermark"] == 1


def test_backpressure_sheds_bulk_keeps_latency():
    adm = AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=8)
    assert adm.admit("bulk", 0) and adm.admit("latency", 0)
    assert adm.on_backpressure("bulk") is True       # shed-first: dropped
    assert adm.on_backpressure("latency") is False   # protected: kept queued
    assert adm.counters["bulk"]["admitted"] == 0
    assert adm.counters["bulk"]["shed_backpressure"] == 1
    assert adm.counters["latency"]["admitted"] == 1
    assert adm.closes()


def test_admission_wq_occupancy_probe():
    class _FakeDevice:
        def __init__(self, occ):
            self.occ = occ

        def occupancy(self, wq=None, node=None):
            return self.occ

    adm = AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=8,
                              wq_occupancy_high=0.95,
                              device=_FakeDevice(0.99))
    assert not adm.admit("latency", 0)
    assert adm.counters["latency"]["shed_wq_occupancy"] == 1
    adm2 = AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=8,
                               device=_FakeDevice(0.5))
    assert adm2.admit("latency", 0)


def test_admission_sampler_node_occupancy():
    class _FakeSeries(list):
        def last(self):
            return self[-1]

    class _FakeSampler:
        def __init__(self, series):
            self.series = series

    hot = {"engine.n0dsa0.wq_occupancy": _FakeSeries([0.4, 0.99])}
    adm = AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=8,
                              node_occupancy_high=0.98,
                              sampler=_FakeSampler(hot))
    assert not adm.admit("latency", 0, node=0)   # node 0 saturated
    assert adm.admit("latency", 0, node=1)       # node 1 has no series: admit
    assert adm.counters["latency"]["shed_node_occupancy"] == 1
    assert adm.closes()


def test_latency_tracker_percentiles_and_goodput():
    classes = (SLOClass("latency", target_p99_s=0.5),
               SLOClass("bulk", target_p99_s=2.0))
    tr = LatencyTracker(classes)
    assert np.isnan(tr.p("latency", 99))  # empty class: NaN, never passes
    for i in range(10):
        tr.record("latency", arrival_s=0.0, first_token_s=0.1 * i,
                  done_s=0.1 * (i + 1))
    assert tr.p("latency", 50) == pytest.approx(0.5)
    assert tr.p("latency", 99) == pytest.approx(1.0)
    assert tr.p("latency", 50, kind="ttft") == pytest.approx(0.4)
    assert tr.within_slo("latency") == 5  # e2e <= 0.5s
    with pytest.raises(KeyError):
        tr.record("nope", 0.0, None, 1.0)


def test_percentile_nearest_rank():
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0
    assert percentile([3.0, 1.0, 2.0], 100) == 3.0
    assert percentile([3.0, 1.0, 2.0], 0) == 1.0
    assert np.isnan(percentile([], 99))
    with pytest.raises(ValueError):
        percentile([1.0], 101)
