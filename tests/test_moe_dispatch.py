"""EP (shard_map) dispatch must be numerically equivalent to the dense
GSPMD dispatch in the no-drop regime — run in a subprocess with 8 virtual
devices (device count is fixed at first jax init, so it cannot be set
inside the main pytest process)."""
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

# minutes-scale (subprocess jax re-init): excluded from the quick lane
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import MoEConfig
from repro.models import moe as M

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared_experts=1,
                capacity_factor=8.0)
D = 16
rng = np.random.default_rng(0)
p = M.init_moe_params(jax.random.key(0), cfg, D, jnp.float32)
x = jnp.asarray(rng.normal(size=(2, 16, D)), jnp.float32)
with mesh:
    y_dense, aux_d = jax.jit(lambda x: M.moe_block(x, p, cfg, "silu", dispatch="dense"))(x)
    y_ep, aux_e = jax.jit(
        lambda x: M.moe_block(x, p, cfg, "silu", dispatch="a2a", mesh=mesh)
    )(x)
err = float(np.abs(np.asarray(y_dense) - np.asarray(y_ep)).max())
assert err < 1e-4, err
assert abs(float(aux_d) - float(aux_e)) < 1e-6
print("OK", err)
"""


def test_ep_dispatch_matches_dense():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
