"""WQ QoS subsystem: WQConfig validation, priority-weighted arbitration,
shared-WQ ENQCMD semantics vs dedicated-WQ MOVDIR64B semantics, per-WQ
telemetry rollups, and composition with ``after=`` fences (paper Fig. 9,
Fig. 12, §3.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Device,
    DeviceConfig,
    GroupConfig,
    OpType,
    QueueFull,
    Status,
    StreamEngine,
    WorkDescriptor,
    WorkQueue,
    WQConfig,
    make_device,
)
from repro.core.telemetry import Telemetry


def _desc(shape=(8, 128)):
    return WorkDescriptor(op=OpType.MEMCPY, src=jnp.zeros(shape, jnp.float32))


# --------------------------------------------------------------------------- WQConfig
def test_wqconfig_validation():
    WQConfig("ok", mode="shared", size=8, priority=15, traffic_class="to_cache")
    with pytest.raises(ValueError):
        WQConfig("bad", mode="hybrid")
    with pytest.raises(ValueError):
        WQConfig("bad", priority=0)  # DSA WQCFG priority is 1-15
    with pytest.raises(ValueError):
        WQConfig("bad", priority=16)
    with pytest.raises(ValueError):
        WQConfig("bad", size=0)
    with pytest.raises(ValueError):
        WQConfig("bad", traffic_class="to_l2")
    with pytest.raises(ValueError):
        WQConfig("bad", group=-1)


def test_from_wq_configs_topology():
    cfg = DeviceConfig.from_wq_configs([
        WQConfig("a", group=0), WQConfig("b", group=0), WQConfig("c", group=1),
    ], pes_per_group=2)
    assert [g.name for g in cfg.groups] == ["group0", "group1"]
    assert [w.name for w in cfg.groups[0].wqs] == ["a", "b"]
    assert cfg.groups[1].wqs[0].name == "c"
    assert all(g.n_pes == 2 for g in cfg.groups)
    with pytest.raises(ValueError):
        DeviceConfig.from_wq_configs([])
    with pytest.raises(ValueError):
        DeviceConfig.from_wq_configs([WQConfig("a"), WQConfig("a")])
    with pytest.raises(ValueError):
        DeviceConfig.from_wq_configs([WQConfig("a", group=1)])  # group 0 empty


def test_make_device_rejects_mixed_config_knobs():
    with pytest.raises(ValueError):
        make_device(wq_configs=[WQConfig("a")], wq_size=64)
    with pytest.raises(ValueError):
        Device(wq_configs=[WQConfig("a")], config=DeviceConfig.default())
    with pytest.raises(ValueError):  # pre-built engines can't be re-provisioned
        Device([StreamEngine()], wq_configs=[WQConfig("a")])


# --------------------------------------------------------------------------- arbitration
def test_priority_weighted_draining_order():
    """Deficit arbiter: a priority-10 WQ gets ~10 grants per priority-1
    grant, and the low WQ is never starved (its credit accrues until it
    wins)."""
    hi = WorkQueue("hi", size=32, priority=10)
    lo = WorkQueue("lo", size=32, priority=1)
    g = GroupConfig("g0", [hi, lo], n_pes=1)
    eng = StreamEngine(DeviceConfig(groups=[g]))
    for _ in range(22):
        hi.submit(_desc())  # dsalint: disable=DSA101,DSA106 — raw WQ submit returns Status
        lo.submit(_desc())  # dsalint: disable=DSA101,DSA106 — raw WQ submit returns Status
    picks = []
    for _ in range(22):
        desc, wq = eng._arbitrate(g)
        assert desc is not None
        picks.append(wq.name)
    # hi wins while its per-round credit (10) beats lo's accrual; lo's
    # credit reaches parity after ~10 rounds and takes the grant (its
    # fuller queue breaks the tie), so service is ~10:1 — proportional
    # to priority, never starved
    assert picks[:9] == ["hi"] * 9
    assert picks[9] == "lo"
    assert picks.count("lo") >= 2  # keeps winning every ~10 rounds
    assert picks.count("hi") >= 8 * picks.count("lo") / 2  # strongly weighted


def test_priority_lowers_queueing_delay():
    """Fig. 9 acceptance: under contention the higher-priority WQ sees lower
    mean queueing delay."""
    dev = make_device(wq_configs=[
        WQConfig("hi", size=32, priority=12),
        WQConfig("lo", size=32, priority=1),
    ], pes_per_group=1)
    gate = dev.promise()  # backlog both WQs before the arbiter runs
    futs = [dev.memcpy_async(jnp.zeros((8, 128), jnp.float32), wq=w, after=[gate])
            for _ in range(6) for w in ("hi", "lo")]
    gate.set_result()
    dev.drain()
    assert all(f.status == Status.SUCCESS for f in futs)
    eng = dev.engines[0]
    d_hi = eng.wq(0, 0).mean_queue_delay_us
    d_lo = eng.wq(0, 1).mean_queue_delay_us
    assert d_hi < d_lo


def test_wq_hint_by_name_and_priority():
    dev = make_device(wq_configs=[
        WQConfig("latency", priority=12, traffic_class="to_cache"),
        WQConfig("bulk", priority=2, mode="shared"),
    ])
    x = jnp.zeros((8, 128), jnp.float32)
    f_name = dev.memcpy_async(x, wq="latency")
    f_pri = dev.memcpy_async(x, priority=3)  # nearest-priority WQ -> bulk
    f_default = dev.memcpy_async(x)  # no hint -> first WQ
    dev.drain()
    assert f_name.wq == "latency" and f_name.steering == "to_cache"
    assert f_pri.wq == "bulk"
    assert f_default.wq == "latency"
    assert dev.has_wq("bulk") and not dev.has_wq("nope")
    with pytest.raises(KeyError):
        _ = dev.memcpy_async(x, wq="nope")


def test_priority_hint_respects_pinned_group():
    """An explicit group= pins the priority search to that group, so an
    isolation group's WQs never lose submissions to another group (docs/
    wq_guidelines.md §4); without group=, the search spans all groups."""
    dev = make_device(wq_configs=[
        WQConfig("g0hi", group=0, priority=12),
        WQConfig("g1lo", group=1, priority=2),
    ])
    x = jnp.zeros((8, 128), jnp.float32)
    pinned = dev.memcpy_async(x, group=1, priority=12)  # stays in group 1
    free = dev.memcpy_async(x, priority=12)  # global search -> g0hi
    dev.drain()
    assert pinned.wq == "g1lo"
    assert free.wq == "g0hi"


# --------------------------------------------------------------------------- SWQ vs DWQ
def test_shared_wq_charges_enqcmd_round_trip():
    """Identical copies: the shared WQ's modeled completion time includes the
    non-posted ENQCMD round trip; the dedicated (MOVDIR64B) one does not."""
    x = jnp.zeros((32, 128), jnp.float32)
    times = {}
    for mode in ("dedicated", "shared"):
        dev = make_device(wq_configs=[WQConfig("wq", mode=mode, priority=8)])
        fut = dev.memcpy_async(x, wq="wq")  # dsalint: disable=DSA106 — per-descriptor path under test
        fut.wait()
        times[mode] = fut.record.modeled_time_us
    model = make_device().engines[0].model
    extra_us = times["shared"] - times["dedicated"]
    assert extra_us == pytest.approx(model.enqcmd_overhead_s * 1e6, rel=1e-6)


def test_shared_wq_backoff_raises_queue_full():
    """A stalled shared WQ RETRYs every ENQCMD until Device's bounded
    backoff gives up with QueueFull (never an unbounded spin)."""
    cfg = DeviceConfig.from_wq_configs(
        [WQConfig("swq", mode="shared", size=2, priority=8)], pes_per_group=0)
    dev = Device([StreamEngine(cfg, name="stalled")],
                 max_retries=2, backoff_base_s=1e-6)
    _ = dev.memcpy_async(jnp.zeros((8, 128), jnp.float32))
    _ = dev.memcpy_async(jnp.zeros((8, 128), jnp.float32))
    with pytest.raises(QueueFull):
        _ = dev.memcpy_async(jnp.zeros((8, 128), jnp.float32))
    assert dev.engines[0].wq(0, 0).stats["retried"] >= 3


def test_dedicated_wq_owner_still_enforced_via_config():
    q = WorkQueue.from_config(WQConfig("dwq", owner="thread0", priority=8))
    assert q.submit(_desc(), producer="thread0") == Status.PENDING
    with pytest.raises(PermissionError):
        q.submit(_desc(), producer="thread1")  # dsalint: disable=DSA101 — raw WQ submit returns Status


# --------------------------------------------------------------------------- telemetry
def test_per_wq_telemetry_rollups():
    dev = make_device(wq_configs=[
        WQConfig("latency", priority=12, traffic_class="to_cache", size=16),
        WQConfig("bulk", priority=2, mode="shared", size=48),
    ])
    tel = Telemetry(dev)
    x = jnp.zeros((16, 128), jnp.float32)
    for _ in range(3):
        dev.memcpy_async(x, wq="latency").wait()  # dsalint: disable=DSA106 — per-descriptor path under test
    for _ in range(2):
        dev.memcpy_async(x, wq="bulk").wait()  # dsalint: disable=DSA106 — per-descriptor path under test
    dev.drain()
    snap = tel.snapshot()
    wqs = snap["engines"]["dsa0"]["wqs"]
    assert wqs["latency"]["dispatched"] == 3
    assert wqs["bulk"]["dispatched"] == 2
    assert wqs["latency"]["completed"] == 3
    assert wqs["bulk"]["completed"] == 2
    assert wqs["latency"]["traffic_class"] == "to_cache"
    assert wqs["bulk"]["mode"] == "shared" and wqs["bulk"]["priority"] == 2
    assert wqs["latency"]["mean_queue_delay_us"] >= 0
    assert wqs["latency"]["bytes"] == 3 * 16 * 128 * 4
    report = tel.report()
    assert "wq latency" in report and "qdelay" in report


# --------------------------------------------------------------------------- fences
def test_wq_hints_compose_with_fences(rng):
    """A descriptor parked on an ``after=`` fence keeps its WQ hint: it
    enters the hinted WQ (not the default) when the fence releases."""
    dev = make_device(wq_configs=[
        WQConfig("hi", priority=12), WQConfig("lo", priority=2),
    ])
    x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    gate = dev.promise()
    fut = dev.memcpy_async(x, wq="lo", after=[gate])
    assert not fut.done()
    assert dev.engines[0].wq(0, 1).stats["submitted"] == 0  # still parked
    gate.set_result()
    out = fut.result()
    assert np.allclose(np.asarray(out), np.asarray(x))
    assert fut.wq == "lo"


def test_fence_chain_across_wqs(rng):
    dev = make_device(wq_configs=[
        WQConfig("hi", priority=12), WQConfig("lo", priority=2),
    ])
    x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    a = dev.memcpy_async(x, wq="hi")
    b = dev.memcpy_async(x, wq="lo", after=[a])
    assert b.result() is not None
    assert a.wq == "hi" and b.wq == "lo"
    assert b.queue_delay_us >= 0


# --------------------------------------------------------------------------- serving
def test_serving_wq_provisioning():
    from repro.serving.pipeline import SERVING_WQ_CONFIGS

    dev = Device(wq_configs=list(SERVING_WQ_CONFIGS))
    assert dev.has_wq("latency") and dev.has_wq("bulk")
    lat = next(w for g in dev.engines[0].config.groups for w in g.wqs
               if w.name == "latency")
    blk = next(w for g in dev.engines[0].config.groups for w in g.wqs
               if w.name == "bulk")
    assert lat.priority > blk.priority
    assert lat.mode == "dedicated" and blk.mode == "shared"
    assert lat.traffic_class == "to_cache"
