"""Hypothesis property tests on the system's invariants."""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.perfmodel import DEFAULT_MODEL
from repro.kernels import ops, ref

_words = st.integers(min_value=0, max_value=2**32 - 1)


@settings(max_examples=25, deadline=None)
@given(st.lists(_words, min_size=1, max_size=600))
def test_crc32_matches_zlib_property(ws):
    x = jnp.asarray(np.asarray(ws, np.uint32))
    assert int(ops.crc32(x)) == zlib.crc32(np.asarray(ws, "<u4").tobytes()) & 0xFFFFFFFF


@settings(max_examples=25, deadline=None)
@given(st.lists(_words, min_size=2, max_size=400), st.data())
def test_crc_detects_any_single_word_corruption(ws, data):
    """CRC32 detects every single-word error (Hamming distance >= 1)."""
    x = np.asarray(ws, np.uint32)
    i = data.draw(st.integers(0, len(ws) - 1))
    delta = data.draw(st.integers(1, 2**32 - 1))
    y = x.copy()
    y[i] = np.uint32((int(y[i]) + delta) % 2**32)
    if (y == x).all():
        return
    assert int(ops.crc32(jnp.asarray(x))) != int(ops.crc32(jnp.asarray(y)))


@settings(max_examples=20, deadline=None)
@given(
    st.lists(_words, min_size=8, max_size=300),
    st.sets(st.integers(0, 299), min_size=0, max_size=40),
)
def test_delta_roundtrip_property(base_words, flip):
    base = np.asarray(base_words, np.uint32)
    flip = sorted(i for i in flip if i < len(base))
    src = base.copy()
    for i in flip:
        src[i] ^= 0xFFFFFFFF
    changed = int((src != base).sum())
    off, data, count, ovf = ops.delta_create(
        jnp.asarray(src), jnp.asarray(base), cap=max(changed, 8)
    )
    assert int(count) == changed and not bool(ovf)
    out = ops.delta_apply(jnp.asarray(base), off, data)
    assert (np.asarray(out) == src).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.data())
def test_batch_copy_equals_sequential(n_desc, data):
    """One batch descriptor == the same descriptors submitted one-by-one
    (paper F2: batching changes cost, not semantics)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    P = 8
    src_pool = jnp.asarray(rng.normal(size=(P, 8, 128)), jnp.float32)
    dst0 = jnp.asarray(rng.normal(size=(P, 8, 128)), jnp.float32)
    src_idx = jnp.asarray(rng.integers(0, P, n_desc), jnp.int32)
    dst_idx = jnp.asarray(rng.integers(0, P, n_desc), jnp.int32)
    batched = ops.batch_copy(src_pool, jnp.array(dst0), src_idx, dst_idx)
    seq = jnp.array(dst0)
    for i in range(n_desc):
        seq = ops.batch_copy(src_pool, seq, src_idx[i : i + 1], dst_idx[i : i + 1])
    assert (np.asarray(batched) == np.asarray(seq)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(6, 28), st.integers(1, 64), st.integers(1, 4), st.integers(1, 32))
def test_perfmodel_monotonicity(log2_bytes, batch, n_pe, depth):
    """Model invariants from the paper's figures: batching, PEs, and async
    depth never DECREASE throughput; throughput never exceeds the HBM copy
    roofline."""
    m = DEFAULT_MODEL
    nbytes = float(2 ** log2_bytes)
    t = m.throughput(nbytes, batch_size=batch, n_pe=n_pe, async_depth=depth)
    assert t <= m.pe_peak_bw + 1e-6
    assert m.throughput(nbytes, batch_size=batch + 1, n_pe=n_pe, async_depth=depth) >= t * 0.5
    assert m.throughput(nbytes, batch_size=batch, n_pe=n_pe, async_depth=depth + 1) >= t - 1e-9
    assert m.throughput(nbytes, batch_size=batch, n_pe=min(n_pe + 1, 4), async_depth=depth) >= t - 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(_words, min_size=1, max_size=256))
def test_fill_then_compare_pattern_is_equal(ws):
    pat = jnp.asarray(np.asarray(ws[:2] or [0], np.uint32))
    n = 4 * len(ws) + 3
    buf = ops.fill(pat, n)
    eq, idx = ops.compare_pattern(buf, pat)
    assert bool(eq), (idx, n)
