"""The Device/Future submission API: chaining, callbacks, dependency
fences (``after=``), submit policies, and bounded RETRY backoff."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Device,
    DeviceConfig,
    GroupConfig,
    LeastLoadedPolicy,
    OpType,
    QueueFull,
    Status,
    StreamEngine,
    WorkDescriptor,
    WorkQueue,
    get_policy,
    make_device,
)


def _desc(x=None):
    return WorkDescriptor(op=OpType.MEMCPY,
                          src=x if x is not None else jnp.zeros((8, 128), jnp.float32))


def _stalled_device(wq_size: int = 2, max_retries: int = 3) -> Device:
    """A device whose single engine has ZERO PEs: nothing ever drains, so
    the WQ genuinely fills and stays full."""
    cfg = DeviceConfig(groups=[
        GroupConfig("g0", [WorkQueue("wq0", mode="shared", size=wq_size)], n_pes=0)
    ])
    return Device([StreamEngine(cfg, name="stalled")],
                  max_retries=max_retries, backoff_base_s=1e-6)


# --------------------------------------------------------------------------- futures
def test_future_result_roundtrip(rng):
    d = make_device()
    x = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    fut = d.memcpy_async(x)
    assert np.allclose(np.asarray(fut.result()), np.asarray(x))
    assert fut.done() and fut.status == Status.SUCCESS
    assert fut.op == "memcpy"


def test_then_chains_transform(rng):
    d = make_device()
    x = jnp.asarray(rng.integers(0, 2**31, 1024), jnp.uint32)
    import zlib

    fut = d.crc32_async(x).then(lambda c: f"0x{int(c):08x}")
    expect = zlib.crc32(np.asarray(x, "<u4").tobytes()) & 0xFFFFFFFF
    assert fut.result() == f"0x{expect:08x}"


def test_then_of_then_and_error_propagation():
    d = make_device()
    bad = d.submit(WorkDescriptor(op=OpType.DELTA_APPLY, src=None, src_idx=None, src2=None))
    chained = bad.then(lambda v: v).then(lambda v: v)
    d.drain()
    assert chained.poll()
    assert chained.status == Status.ERROR
    with pytest.raises(RuntimeError):
        chained.result()


def test_then_fn_exception_marks_error(rng):
    d = make_device()
    fut = d.memcpy_async(jnp.zeros((8, 128), jnp.float32)).then(
        lambda v: (_ for _ in ()).throw(ValueError("boom"))
    )
    with pytest.raises(RuntimeError, match="boom"):
        fut.result()


def test_done_callbacks_fire_in_order(rng):
    d = make_device()
    order = []
    fut = d.memcpy_async(jnp.zeros((8, 128), jnp.float32))
    fut.add_done_callback(lambda f: order.append("a"))
    fut.add_done_callback(lambda f: order.append("b"))
    fut.wait()
    # late registration runs immediately, after the earlier ones
    fut.add_done_callback(lambda f: order.append("c"))
    assert order == ["a", "b", "c"]


def test_callbacks_fire_once(rng):
    d = make_device()
    count = []
    fut = d.memcpy_async(jnp.zeros((8, 128), jnp.float32))
    fut.add_done_callback(lambda f: count.append(1))
    fut.wait()
    fut.wait()
    fut.poll()
    assert len(count) == 1


# --------------------------------------------------------------------------- fences
def test_after_fence_defers_until_parent_retires():
    """A dependent descriptor must NOT launch before its parent resolves:
    gate the parent on a promise and watch the chain."""
    d = make_device()
    gate = d.promise()
    x = jnp.full((8, 128), 3.0, jnp.float32)
    child = d.memcpy_async(x, after=[gate])
    for _ in range(3):
        d.kick()
    assert not child.done()
    assert child.status == Status.PENDING  # held in the engine's fence list
    eng = child.engine
    assert len(eng._deferred) == 1  # parked, not in a WQ / PE
    gate.set_result(None)
    out = child.result()
    assert np.allclose(np.asarray(out), 3.0)
    assert not eng._deferred


def test_after_accepts_future_chain(rng):
    d = make_device()
    x = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    first = d.memcpy_async(x)
    second = d.memcpy_async(x, after=[first])
    third = d.memcpy_async(x, after=[first, second])
    assert np.allclose(np.asarray(third.result()), np.asarray(x))
    assert first.done() and second.done()


def test_failed_dependency_fails_dependent():
    d = make_device()
    gate = d.promise()
    child = d.memcpy_async(jnp.zeros((8, 128), jnp.float32), after=[gate])
    gate.set_error("upstream torn")
    d.kick()
    assert child.status == Status.ERROR
    assert "dependency failed" in (child.error or "")
    with pytest.raises(RuntimeError):
        child.result()


def test_already_failed_dependency_rejected_at_submit():
    d = make_device()
    bad = d.submit(WorkDescriptor(op=OpType.DELTA_APPLY, src=None, src_idx=None, src2=None))
    d.drain()
    assert bad.status == Status.ERROR
    child = d.memcpy_async(jnp.zeros((8, 128), jnp.float32), after=[bad])
    assert child.status == Status.ERROR


def test_drain_resolves_cross_engine_fences(rng):
    """Parent on dsa0, child fenced on it lands on dsa1: Device.drain pumps
    both instances until the fence releases."""
    d = make_device(n_instances=2, policy="round_robin")
    x = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    parent = d.memcpy_async(x)
    child = d.memcpy_async(x, after=[parent])
    d.drain()
    assert parent.done() and child.done()
    assert parent.engine is not child.engine or len(d.engines) == 1


# --------------------------------------------------------------------------- policies
def test_round_robin_spreads(rng):
    d = make_device(n_instances=3, policy="round_robin")
    x = jnp.zeros((8, 128), jnp.float32)
    for _ in range(6):
        d.memcpy_async(x).wait()  # dsalint: disable=DSA106 — per-descriptor path under test
    assert sorted(d.policy_stats["decisions"].values()) == [2, 2, 2]


def test_least_loaded_avoids_hot_instance():
    d = make_device(n_instances=2, policy="least_loaded")
    hot, cold = d.engines
    # preload the hot instance's WQ without kicking (raw portal writes)
    for _ in range(4):
        hot.wq(0, 0).submit(_desc())  # dsalint: disable=DSA101,DSA106 — raw WQ submit returns Status
    placed = LeastLoadedPolicy().select(d.engines, _desc(), None)
    assert placed is cold
    fut = d.memcpy_async(jnp.zeros((8, 128), jnp.float32))
    assert fut.engine is cold
    d.drain()


def test_sticky_policy_pins_producer():
    d = make_device(n_instances=4, policy="sticky")
    x = jnp.zeros((8, 128), jnp.float32)
    futs = [d.memcpy_async(x, producer="worker-7") for _ in range(5)]
    engines = {f.engine.name for f in futs}
    assert len(engines) == 1  # per-producer affinity
    other = d.memcpy_async(x, producer="worker-3")
    d.drain()
    # a different producer may land elsewhere; same producer never moves
    again = d.memcpy_async(x, producer="worker-7")
    assert again.engine.name in engines
    d.drain()


def test_get_policy_validates():
    with pytest.raises(ValueError, match="unknown submit policy"):
        get_policy("best_effort")
    p = LeastLoadedPolicy()
    assert get_policy(p) is p


# --------------------------------------------------------------------------- backoff
def test_queue_full_after_bounded_backoff():
    d = _stalled_device(wq_size=2, max_retries=3)
    x = jnp.zeros((8, 128), jnp.float32)
    _ = d.memcpy_async(x)
    _ = d.memcpy_async(x)  # WQ now full; no PEs will ever drain it
    with pytest.raises(QueueFull) as ei:
        _ = d.memcpy_async(x)
    assert ei.value.attempts == 4  # initial try + max_retries backoffs
    assert d.policy_stats["queue_full"] == 1
    assert d.policy_stats["backoff_retries"] >= 3


def test_backoff_succeeds_when_queue_drains(rng):
    """RETRY converts to backoff, not failure, when capacity frees up."""
    d = make_device(wqs_per_group=1, wq_size=2, wq_mode="shared")
    x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    futs = [d.memcpy_async(x) for _ in range(12)]  # >> wq_size
    for f in futs:
        assert np.allclose(np.asarray(f.result()), np.asarray(x))
    assert d.policy_stats["queue_full"] == 0


def test_fence_list_is_bounded():
    """Deferred (after=) submissions can't grow without bound: past
    max_deferred the engine answers RETRY, so Device backoff/QueueFull
    applies to the fence path too."""
    d = make_device(wqs_per_group=1, wq_size=2)
    d.max_retries = 2
    d.backoff_base_s = 1e-6
    eng = d.engines[0]
    eng.max_deferred = 3
    gate = d.promise()
    x = jnp.zeros((8, 128), jnp.float32)
    for _ in range(3):
        _ = d.memcpy_async(x, after=[gate])  # dsalint: disable=DSA106 — per-descriptor path under test
    with pytest.raises(QueueFull):
        _ = d.memcpy_async(x, after=[gate])
    assert len(eng._deferred) == 3
    gate.set_result(None)
    d.drain()
    assert not eng._deferred


def test_shared_device_across_threads(rng):
    """Two threads submitting through one Device (the async-checkpoint
    pattern) must not lose completions."""
    import threading

    d = make_device(n_instances=2)
    x = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    errors = []

    def worker():
        try:
            for _ in range(20):
                assert np.allclose(np.asarray(d.memcpy_async(x).result()),  # dsalint: disable=DSA106 — per-descriptor path under test
                                   np.asarray(x))
        except Exception as e:  # noqa: BLE001  # dsalint: disable=DSA104 — errors collected and asserted below
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors
    assert sum(d.policy_stats["decisions"].values()) == 40
