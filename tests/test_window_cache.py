"""Sliding-window ring-cache correctness: decoding PAST the window boundary
must match teacher forcing (entries wrap and expire in the ring), including
hymba's always-attendable meta tokens."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model


def _greedy_rollout(model, params, prompt, n_steps, max_cache):
    cache, logits, _ = model.prefill(params, {"tokens": prompt}, max_cache_len=max_cache)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    outs = [logits]
    for _ in range(n_steps - 1):
        logits, cache = model.decode_step(params, cache, cur)
        toks.append(int(jnp.argmax(logits[0])))
        outs.append(logits)
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
    return toks, outs


@pytest.mark.parametrize("arch", ["gemma3-1b", "hymba-1.5b"])
def test_decode_past_window_matches_teacher_forcing(arch):
    """Window W=16 (reduced); prefill 12 tokens then decode 12 more — the
    ring wraps around W during the rollout.  每 decode step's logits must
    match a fresh full prefill of the same prefix."""
    cfg = get_config(arch).reduced()  # window 16
    assert cfg.window_size == 16
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)

    n_extra = 12
    toks, step_logits = _greedy_rollout(model, params, prompt, n_extra + 1, max_cache=64)

    # teacher forcing: for a few checkpoints past the boundary, prefill the
    # full prefix and compare the final-position logits
    seq = list(np.asarray(prompt[0]))
    for i, t in enumerate(toks[:-1]):
        seq.append(t)
        if i in (5, 8, n_extra - 1):  # positions 17, 20, 23 — beyond W=16
            full = jnp.asarray([seq], jnp.int32)
            _, logits_tf, _ = model.prefill(params, {"tokens": full}, max_cache_len=64)
            np.testing.assert_allclose(
                np.asarray(step_logits[i + 1], np.float32),
                np.asarray(logits_tf, np.float32),
                rtol=0.1, atol=0.1,
            )


def test_ring_slots_wrap_and_expire():
    """Direct cache inspection: after decoding past W, ring positions hold
    the LAST W absolute positions only."""
    cfg = get_config("gemma3-1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(1))
    prompt = jnp.zeros((1, 8), jnp.int32)
    cache, _, _ = model.prefill(params, {"tokens": prompt}, max_cache_len=64)
    cur = jnp.zeros((1, 1), jnp.int32)
    for _ in range(20):
        _, cache = model.decode_step(params, cache, cur)
    # find a local-layer ring cache and check its positions
    seg = cache["segments"][-1]  # trailing unrolled locals for gemma3 reduced
    ring = seg[0] if isinstance(seg, list) else seg
    pos = np.asarray(jax.tree.leaves({"pos": ring["pos"]})[0])[0]
    live = sorted(p for p in pos.tolist() if p >= 0)
    total = 8 + 20
    assert live == list(range(total - cfg.window_size, total))
