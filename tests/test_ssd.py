"""SSD (mamba2) algebraic invariants: the chunked scan must be exactly
chunk-size invariant, and the decode recurrence must match the chunked form
step by step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models import ssm


def _inputs(rng, B=2, S=64, H=4, P=8, G=1, N=16):
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    a_bar = -jnp.asarray(rng.uniform(0.01, 0.5, size=(B, S, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    return x, a_bar, b, c


@pytest.mark.parametrize("chunk_a,chunk_b", [(8, 16), (8, 32), (16, 64)])
def test_ssd_chunk_size_invariance(rng, chunk_a, chunk_b):
    x, a_bar, b, c = _inputs(rng)
    ya, sa = ssm.ssd_chunked(x, a_bar, b, c, chunk_a)
    yb, sb = ssm.ssd_chunked(x, a_bar, b, c, chunk_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), rtol=2e-4, atol=2e-4)


def test_ssd_matches_sequential_recurrence(rng):
    """The chunked dual form == the literal per-step SSM recurrence."""
    x, a_bar, b, c = _inputs(rng, B=1, S=32, H=2, P=4, N=8)
    y_chunk, state_chunk = ssm.ssd_chunked(x, a_bar, b, c, chunk=8)

    B_, S, H, P = x.shape
    N = b.shape[-1]
    state = np.zeros((B_, H, P, N), np.float32)
    ys = np.zeros((B_, S, H, P), np.float32)
    xn, an, bn, cn = map(np.asarray, (x, a_bar, b, c))
    for t in range(S):
        decay = np.exp(an[:, t])  # [B,H]
        state = state * decay[..., None, None] + (
            xn[:, t][..., None] * bn[:, t, 0][:, None, None, :]
        )
        ys[:, t] = (state * cn[:, t, 0][:, None, None, :]).sum(-1)
    np.testing.assert_allclose(np.asarray(y_chunk), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), state, rtol=2e-4, atol=2e-4)


def test_mixer_prefill_state_matches_decode_chain(rng):
    """prefill final state == state after decoding the same tokens one by one."""
    cfg = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk_size=8)
    d_model = 16
    p = ssm.init_mamba2_params(jax.random.key(0), cfg, d_model, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 16, d_model)) * 0.3, jnp.float32)

    _, state_pf, conv_pf = ssm.mamba2_mixer_with_state(x, p, cfg, d_model)

    H = cfg.n_heads(d_model)
    state = jnp.zeros((1, H, cfg.head_dim, cfg.d_state), jnp.float32)
    conv = jnp.zeros((1, cfg.d_conv - 1, cfg.d_inner(d_model) + 2 * cfg.n_groups * cfg.d_state),
                     jnp.float32)
    for t in range(x.shape[1]):
        _, state, conv = ssm.mamba2_decode_step(x[:, t], state, conv, p, cfg, d_model)
    np.testing.assert_allclose(np.asarray(state_pf), np.asarray(state), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(conv_pf), np.asarray(conv), rtol=3e-3, atol=3e-3)


def test_seamless_decode_matches_teacher_forcing(rng):
    """Enc-dec: decoder prefill+decode == teacher forcing (cross-KV static)."""
    from repro.configs import get_config
    from repro.models.api import build_model, make_batch

    cfg = get_config("seamless-m4t-medium").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    S = 24
    batch = make_batch(cfg, 1, S, jax.random.key(5), kind="prefill")

    _, logits_full, _ = model.prefill(params, batch, max_cache_len=S + 4)

    short = dict(batch)
    short["tokens"] = batch["tokens"][:, : S - 1]
    cache, _, _ = model.prefill(params, short, max_cache_len=S + 4)
    logits_step, _ = model.decode_step(params, cache, batch["tokens"][:, S - 1 :])
    np.testing.assert_allclose(
        np.asarray(logits_step, np.float32), np.asarray(logits_full, np.float32),
        rtol=0.08, atol=0.08,
    )
