"""Statistical property tests for the open-loop traffic engine.

Each arrival process owns its rng (re-seeded per ``times()`` call), so the
assertions here are exact-repeatable: the same seed draws the same trace
whether this file runs alone, as a subset, or inside the full suite — the
determinism contract the overload soak and fig17 benchmark build on.  The
statistics are asserted through the same helpers (``interarrival_stats``,
``windowed_rates``, ``zipf_tail_slope``) the benchmark reports with.
"""
import numpy as np
import pytest

from repro.serving.traffic import (
    BurstyArrivals,
    DiurnalArrivals,
    OpenRequest,
    PoissonArrivals,
    TrafficGenerator,
    ZipfLengths,
    interarrival_stats,
    windowed_rates,
    zipf_tail_slope,
)


# --------------------------------------------------------------------------- poisson
def test_poisson_interarrival_mean_and_cv2():
    rate = 50.0
    times = list(PoissonArrivals(rate, seed=11).times(200.0))
    assert len(times) > 5000
    mean, cv2 = interarrival_stats(times)
    assert mean == pytest.approx(1.0 / rate, rel=0.05)
    # exponential gaps: CV^2 = 1 (the queueing-theory baseline)
    assert cv2 == pytest.approx(1.0, abs=0.1)


def test_arrival_times_strictly_increase_within_horizon():
    for proc in (PoissonArrivals(20.0, seed=3),
                 BurstyArrivals(on_rps=80.0, seed=3),
                 DiurnalArrivals(40.0, 4.0, period_s=5.0, seed=3)):
        ts = list(proc.times(30.0))
        assert len(ts) > 10, proc.name
        assert all(0.0 <= t < 30.0 for t in ts), proc.name
        assert all(b > a for a, b in zip(ts, ts[1:])), proc.name


def test_horizon_is_a_pure_truncation():
    """A shorter horizon yields a PREFIX of the longer trace: the draw
    sequence never depends on where the horizon lands."""
    for proc in (PoissonArrivals(30.0, seed=9),
                 BurstyArrivals(on_rps=60.0, mean_on_s=0.5, mean_off_s=0.5,
                                seed=9),
                 DiurnalArrivals(50.0, 5.0, period_s=4.0, seed=9)):
        short = list(proc.times(10.0))
        long = list(proc.times(25.0))
        assert long[: len(short)] == short, proc.name
        assert len(long) > len(short), proc.name


# --------------------------------------------------------------------------- bursty
def test_bursty_cv2_exceeds_poisson():
    proc = BurstyArrivals(on_rps=200.0, off_rps=0.0,
                          mean_on_s=0.5, mean_off_s=0.5, seed=5)
    times = list(proc.times(300.0))
    _, cv2 = interarrival_stats(times)
    assert cv2 > 1.5  # on-off modulation: markedly burstier than Poisson
    # empirical long-run rate tracks the analytic stationary mean
    assert len(times) / 300.0 == pytest.approx(proc.mean_rate(), rel=0.2)


def test_bursty_silent_off_state_still_terminates():
    ts = list(BurstyArrivals(on_rps=10.0, off_rps=0.0, mean_on_s=0.2,
                             mean_off_s=5.0, seed=1).times(20.0))
    # mostly-silent traffic: few arrivals, all inside the horizon
    assert all(0 <= t < 20.0 for t in ts)
    assert len(ts) < 10.0 * 20.0


# --------------------------------------------------------------------------- diurnal
def test_diurnal_rate_envelope():
    proc = DiurnalArrivals(100.0, 10.0, period_s=8.0, seed=2)
    assert proc.rate_at(0.0) == pytest.approx(10.0)     # trough at t=0
    assert proc.rate_at(4.0) == pytest.approx(100.0)    # peak at T/2
    assert proc.rate_at(8.0) == pytest.approx(10.0)     # periodic
    assert proc.mean_rate() == pytest.approx(55.0)


def test_diurnal_windowed_rates_track_the_ramp():
    proc = DiurnalArrivals(120.0, 6.0, period_s=10.0, seed=21)
    horizon = 40.0  # four full periods
    times = list(proc.times(horizon))
    centers, emp = windowed_rates(times, horizon, window_s=0.5)
    expect = np.array([proc.rate_at(t) for t in centers])
    # empirical per-window rate is strongly correlated with the intensity
    assert np.corrcoef(emp, expect)[0, 1] > 0.9
    # and peak windows carry much more traffic than trough windows
    peak_w = emp[expect > 100.0].mean()
    trough_w = emp[expect < 20.0].mean()
    assert peak_w > 4.0 * trough_w


# --------------------------------------------------------------------------- zipf lengths
def test_zipf_bounds_and_mean(rng):
    z = ZipfLengths(s=1.1, lo=8, hi=256)
    xs = z.sample(50_000, rng)
    assert xs.min() >= 8 and xs.max() <= 256
    assert xs.mean() == pytest.approx(z.mean(), rel=0.1)
    # rank-1 (= lo) dominates: heavier than any other single value
    vals, counts = np.unique(xs, return_counts=True)
    assert vals[counts.argmax()] == 8


def test_zipf_tail_slope_matches_exponent(rng):
    s = 1.3
    z = ZipfLengths(s=s, lo=1, hi=512)
    xs = z.sample(200_000, rng)
    slope = zipf_tail_slope(xs, lo=1)
    assert slope == pytest.approx(-s, abs=0.2)


def test_zipf_validation():
    with pytest.raises(ValueError):
        ZipfLengths(lo=0)
    with pytest.raises(ValueError):
        ZipfLengths(lo=10, hi=5)
    with pytest.raises(ValueError):
        ZipfLengths(s=0.0)


# --------------------------------------------------------------------------- determinism
def test_same_seed_identical_trace_despite_global_rng_noise():
    """The full-suite-vs-subset guarantee: traces depend ONLY on their own
    seeds, never on module-global or legacy-global numpy state."""
    gen = TrafficGenerator(PoissonArrivals(40.0, seed=17), seed=17)
    a = gen.trace(20.0)
    np.random.seed(0)
    np.random.normal(size=1000)  # pollute the legacy global stream
    b = TrafficGenerator(PoissonArrivals(40.0, seed=17), seed=17).trace(20.0)
    assert a == b  # OpenRequest is a frozen dataclass: field-exact equality
    c = TrafficGenerator(PoissonArrivals(40.0, seed=18), seed=17).trace(20.0)
    assert [r.arrival_s for r in c] != [r.arrival_s for r in a]


def test_class_mix_knob_does_not_perturb_arrivals_or_lengths():
    """Independent child streams: changing the class mix re-labels requests
    but never moves an arrival or resizes a prompt."""
    base = TrafficGenerator(PoissonArrivals(60.0, seed=4),
                            class_mix={"latency": 0.25, "bulk": 0.75},
                            seed=4).trace(15.0)
    skew = TrafficGenerator(PoissonArrivals(60.0, seed=4),
                            class_mix={"latency": 0.75, "bulk": 0.25},
                            seed=4).trace(15.0)
    assert [r.arrival_s for r in base] == [r.arrival_s for r in skew]
    assert [r.prompt_len for r in base] == [r.prompt_len for r in skew]
    assert [r.max_new_tokens for r in base] == [r.max_new_tokens for r in skew]
    assert [r.slo for r in base] != [r.slo for r in skew]
    # and the mix fractions land near their targets
    frac = sum(r.slo == "latency" for r in base) / len(base)
    assert frac == pytest.approx(0.25, abs=0.08)


def test_trace_req_ids_sequential_and_sorted():
    trace = TrafficGenerator(PoissonArrivals(30.0, seed=6), seed=6).trace(10.0)
    assert [r.req_id for r in trace] == list(range(len(trace)))
    assert all(b.arrival_s > a.arrival_s for a, b in zip(trace, trace[1:]))


def test_materialize_is_keyed_by_req_id():
    r = OpenRequest(req_id=7, arrival_s=1.0, slo="bulk",
                    prompt_len=32, max_new_tokens=4)
    a, b = r.materialize(vocab_size=64), r.materialize(vocab_size=64)
    assert (a.prompt == b.prompt).all() and len(a.prompt) == 32
    assert a.slo == "bulk" and a.arrival_s == 1.0 and a.max_new_tokens == 4
    other = OpenRequest(req_id=8, arrival_s=1.0, slo="bulk",
                        prompt_len=32, max_new_tokens=4).materialize(64)
    assert not (a.prompt == other.prompt).all()


def test_process_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(0.0)
    with pytest.raises(ValueError):
        BurstyArrivals(on_rps=0.0)
    with pytest.raises(ValueError):
        BurstyArrivals(on_rps=1.0, mean_on_s=0.0)
    with pytest.raises(ValueError):
        DiurnalArrivals(10.0, 20.0, period_s=5.0)  # trough > peak
    with pytest.raises(ValueError):
        DiurnalArrivals(10.0, 1.0, period_s=0.0)
    with pytest.raises(ValueError):
        TrafficGenerator(PoissonArrivals(1.0), class_mix={"a": 0.0})
    with pytest.raises(ValueError):
        interarrival_stats([0.0, 1.0])  # too few gaps


# --------------------------------------------------------------------------- hypothesis (optional)
def test_poisson_mean_property_hypothesis():
    """Property-test the Poisson mean across rates/seeds when hypothesis is
    available (it is not baked into every image — skip, don't fail)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(rate=st.floats(5.0, 200.0), seed=st.integers(0, 2**31 - 1))
    def check(rate, seed):
        times = list(PoissonArrivals(rate, seed=seed).times(2000.0 / rate))
        mean, _ = interarrival_stats(times)
        assert mean == pytest.approx(1.0 / rate, rel=0.15)

    check()
