"""Topology-aware fabric: link-model monotonicity (paper §4 / Fig. 13),
fabric construction, the ``numa_local`` policy's prefer-then-degrade
behaviour, buffer-locality stamping, per-node telemetry rollups, and the
NUMA-sharded KV pool's no-leak swap contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Device,
    Link,
    Node,
    NumaLocalPolicy,
    OpType,
    QueueFull,
    Topology,
    WorkDescriptor,
    make_device,
)
from repro.core.perfmodel import DEFAULT_MODEL as MODEL
from repro.core.telemetry import Telemetry
from repro.serving.kv_pool import PagedKVPool

SIZES = [256, 4096, 65536, 1 << 20, 16 << 20]
REMOTE_PLACEMENTS = [(0, 1, 0), (0, 0, 1), (1, 0, 0), (0, 1, 1)]


def _desc(shape=(8, 128), **kw):
    return WorkDescriptor(op=OpType.MEMCPY, src=jnp.zeros(shape, jnp.float32), **kw)


# --------------------------------------------------------------------------- topology model
def test_topology_validation():
    with pytest.raises(ValueError):
        Topology([])
    with pytest.raises(ValueError):
        Topology([Node(0), Node(2)])  # ids must be dense
    with pytest.raises(ValueError):
        Node(-1)
    with pytest.raises(ValueError):
        Node(0, n_engines=0)
    with pytest.raises(ValueError):
        Link(bw=0)
    with pytest.raises(ValueError):
        Link(lat_s=-1e-6)
    with pytest.raises(ValueError):
        Topology.symmetric(0)


def test_hop_arithmetic():
    topo = Topology.symmetric(2)
    assert topo.hops(0, 0, 0) == 0
    assert topo.hops(0, 1, 0) == 1  # remote source
    assert topo.hops(0, 0, 1) == 1  # remote destination
    assert topo.hops(1, 0, 0) == 2  # engine remote from both buffers
    assert topo.hops(0, 1, 1) == 2
    assert topo.link_charge(0, 0, 0) == {}
    charge = topo.link_charge(1, 0, 0)
    assert charge["link_hops"] == 2 and charge["link"] is topo.link
    # a single-node topology never charges the link
    assert Topology.single_node().link_charge(0, 0, 0) == {}


def test_cross_node_op_time_monotonic():
    """The paper's locality guideline: ANY cross-node placement is slower
    than all-local, at EVERY transfer size, and more hops cost more."""
    topo = Topology.symmetric(2)
    for size in SIZES:
        local = MODEL.op_time(size)
        one_hop = MODEL.op_time(size, **topo.link_charge(0, 1, 0))
        two_hop = MODEL.op_time(size, **topo.link_charge(1, 0, 0))
        for e, s, d in REMOTE_PLACEMENTS:
            assert MODEL.op_time(size, **topo.link_charge(e, s, d)) > local
        assert two_hop > one_hop > local


def test_engine_nodes_layout():
    topo = Topology([Node(0, n_engines=2), Node(1, n_engines=3)])
    assert topo.engine_nodes() == [0, 0, 1, 1, 1]
    assert topo.n_nodes == 2 and topo.node(1).n_engines == 3


# --------------------------------------------------------------------------- fabric device
def test_fabric_builds_engines_per_node():
    d = make_device(topology=Topology.symmetric(2, engines_per_node=2))
    assert [(e.name, e.node_id) for e in d.engines] == [
        ("n0dsa0", 0), ("n0dsa1", 0), ("n1dsa0", 1), ("n1dsa1", 1)]
    assert [e.name for e in d.engines_on(1)] == ["n1dsa0", "n1dsa1"]
    # the flat default keeps the legacy shape: one node, dsa{i} names
    flat = make_device(n_instances=2)
    assert flat.topology.n_nodes == 1
    assert [e.name for e in flat.engines] == ["dsa0", "dsa1"]


def test_registry_and_node_hint():
    d = make_device(topology=Topology.symmetric(2))
    x = jnp.ones((16, 128), jnp.float32)
    assert d.home(x) is None
    d.register(x, 1)
    assert d.home(x) == 1
    with pytest.raises(ValueError):
        d.register(x, 2)  # out of range for a 2-node fabric
    fut = d.memcpy_async(x)
    fut.result()
    assert fut.record.src_node == 1
    # node= hint stamps operands the registry doesn't know
    y = jnp.ones((16, 128), jnp.float32)
    fut2 = d.memcpy_async(y, node=0)
    fut2.result()
    assert fut2.record.src_node == 0 and fut2.record.dst_node == 0


def test_record_attribution_and_link_charge():
    d = make_device(topology=Topology.symmetric(2), policy="numa_local")
    x = jnp.ones((64, 128), jnp.float32)
    d.register(x, 1)
    # engine placed at the destination's home; the remote source costs 1 hop
    fut = d.submit(WorkDescriptor(op=OpType.MEMCPY, src=x, dst_node=0))
    fut.result()
    assert fut.engine.node_id == 0
    assert fut.record.engine_node == 0
    assert fut.record.src_node == 1 and fut.record.dst_node == 0
    assert fut.record.link_hops == 1
    # modeled time carries the link charge: same submission fully local
    local = d.memcpy_async(x)  # home node 1, engine follows -> 0 hops
    local.result()
    assert local.record.link_hops == 0
    assert fut.record.modeled_time_us > local.record.modeled_time_us


def test_single_node_never_charges_link():
    d = make_device(n_instances=2)
    x = jnp.ones((32, 128), jnp.float32)
    fut = d.memcpy_async(x, node=0)
    fut.result()
    assert fut.record.link_hops == 0 and fut.record.engine_node == 0


# --------------------------------------------------------------------------- numa_local policy
def test_numa_local_picks_home_node_when_free():
    d = make_device(topology=Topology.symmetric(2, engines_per_node=2),
                    policy="numa_local")
    for node in (0, 1, 1, 0):
        fut = d.memcpy_async(jnp.ones((8, 128), jnp.float32), node=node)  # dsalint: disable=DSA106 — per-descriptor path under test
        assert fut.engine.node_id == node
        fut.result()


def test_numa_local_degrades_when_saturated():
    d = make_device(topology=Topology.symmetric(2),
                    policy="numa_local", wqs_per_group=1, wq_size=2)
    home = d.engines_on(1)[0]
    # stuff the home node's only WQ without kicking: occupancy hits 1.0
    while home.wq(0, 0).submit(_desc()).name != "RETRY":
        pass
    policy = NumaLocalPolicy()
    picked = policy.select(d.engines, _desc(src_node=1), None)
    assert picked.node_id == 0  # graceful degrade: remote beats stalled
    # and with a free home engine it goes home again
    assert policy.select(d.engines, _desc(src_node=0), None).node_id == 0


def test_numa_local_composes_with_inner_policy():
    policy = NumaLocalPolicy(inner="sticky")
    d = make_device(topology=Topology.symmetric(2, engines_per_node=2),
                    policy=policy)
    picks = {d.policy.select(d.engines, _desc(src_node=1), f"p{i}").name
             for i in range(4)}
    assert all(n.startswith("n1") for n in picks)  # home node respected
    one = [d.policy.select(d.engines, _desc(src_node=1), "p0").name
           for _ in range(3)]
    assert len(set(one)) == 1  # sticky affinity inside the node


# --------------------------------------------------------------------------- telemetry rollups
def test_per_node_rollups_sum_to_device_totals():
    d = make_device(topology=Topology.symmetric(2), policy="numa_local")
    tel = Telemetry(d)
    x0 = jnp.ones((64, 128), jnp.float32)
    x1 = jnp.ones((64, 128), jnp.float32)
    d.register(x0, 0)
    d.register(x1, 1)
    futs = [d.memcpy_async(x0), d.memcpy_async(x1)]  # local on each node
    futs.append(d.submit(WorkDescriptor(op=OpType.MEMCPY, src=x1, dst_node=0)))
    d.wait_all(futs)
    d.drain()
    snap = tel.snapshot()
    assert set(snap["nodes"]) == {0, 1}
    local_b = sum(n["local_bytes"] for n in snap["nodes"].values())
    cross_b = sum(n["cross_bytes"] for n in snap["nodes"].values())
    assert local_b > 0 and cross_b > 0
    engine_total = sum(c["bytes"] for e in snap["engines"].values()
                       for c in e["ops"].values())
    assert local_b + cross_b == engine_total
    ops_total = sum(c["count"] for e in snap["engines"].values()
                    for c in e["ops"].values())
    node_ops = sum(n["local_ops"] + n["cross_ops"]
                   for n in snap["nodes"].values())
    assert node_ops == ops_total
    occ = [n["link_occupancy"] for n in snap["nodes"].values()]
    assert all(o >= 0.0 for o in occ) and max(occ) > 0.0
    assert "node" in tel.report() or cross_b == 0


# --------------------------------------------------------------------------- sharded KV pool
def test_kv_pool_shards_and_spills_across_nodes():
    d = make_device(topology=Topology.symmetric(2), policy="numa_local")
    pool = PagedKVPool(n_device_pages=6, n_host_pages=8, page_tokens=8,
                       kv_dim=32, device=d)
    assert pool.free_device_pages(0) == 3 and pool.free_device_pages(1) == 3
    assert pool.alloc(1, 5)  # must spill: no single shard holds 5
    nodes = {n for t, n, _ in pool.page_table[1] if t == "device"}
    assert nodes == {0, 1}
    for i in range(5):
        pool.write_page(1, i, jnp.ones((8, 32)) * (i + 1))
    before = np.asarray(pool.read_pages(1))
    assert pool.swap_out(1)  # one batch descriptor per source node
    assert pool.stats.batch_copies == 2
    assert pool.stats.device_pages_used == 0
    assert pool.swap_in(1, node=1) is False  # node 1 alone can't hold 5
    assert pool.swap_in(1)
    assert (np.asarray(pool.read_pages(1)) == before).all()
    assert pool.stats.cross_node_swaps > 0  # host tier lives on node 0
    pool.free(1)
    assert pool.free_device_pages() == 6


def test_kv_pool_multinode_swap_out_charges_link():
    """The node-1 -> host@node-0 leg of a multi-node swap-out must keep its
    link charge even though the chained host pool is a fresh intermediate
    array (regression: unregistered intermediates resolved engine-local)."""
    d = make_device(topology=Topology.symmetric(2), policy="numa_local")
    tel = Telemetry(d)
    pool = PagedKVPool(n_device_pages=4, n_host_pages=8, page_tokens=8,
                       kv_dim=32, device=d)
    assert pool.alloc(1, 2, node=0)
    assert pool.alloc(1, 2, node=1)
    assert pool.swap_out(1)
    d.drain()
    snap = tel.snapshot()
    assert sum(n["cross_bytes"] for n in snap["nodes"].values()) > 0


def test_kv_pool_rejects_bad_node_pin():
    pool = PagedKVPool(n_device_pages=4, n_host_pages=4, page_tokens=4,
                       kv_dim=8, topology=Topology.symmetric(2))
    with pytest.raises(ValueError):
        pool.alloc(1, 1, node=2)
    with pytest.raises(ValueError):
        pool.alloc(1, 1, node=-1)  # would alias node 1 via negative indexing
    assert pool.alloc(1, 2, node=1)
    assert pool.swap_out(1)
    with pytest.raises(ValueError):
        pool.swap_in(1, node=-1)
    assert pool.free_device_pages() == 4  # the rejects moved no state
    assert pool.stats.host_pages_used == 2
    assert pool.swap_in(1, node=0)


def test_server_rejects_device_and_topology():
    from repro.serving.pipeline import VhostStyleServer

    with pytest.raises(ValueError):
        VhostStyleServer(None, None, device=make_device(),
                         topology=Topology.symmetric(2))


def test_kv_pool_engine_failure_falls_back_to_sync():
    class BoomDevice:
        topology = Topology.symmetric(2)

        def register(self, arr, node):
            return arr

        def batch_copy_async(self, *a, **kw):
            raise QueueFull("dsa0", 3)

    pool = PagedKVPool(n_device_pages=4, n_host_pages=4, page_tokens=4,
                       kv_dim=8, device=BoomDevice())
    assert pool.alloc(1, 2)
    pool.write_page(1, 0, jnp.ones((4, 8)))
    before = np.asarray(pool.read_pages(1))
    assert pool.swap_out(1)  # engine path failed -> sync kops, swap still lands
    assert pool.stats.copy_fallbacks == 1
    assert pool.swap_in(1)
    assert (np.asarray(pool.read_pages(1)) == before).all()


def test_kv_pool_failed_swap_restores_free_lists(monkeypatch):
    pool = PagedKVPool(n_device_pages=4, n_host_pages=4, page_tokens=4, kv_dim=8)
    assert pool.alloc(1, 2)
    assert pool.swap_out(1)
    free_dev_before = pool.free_device_pages()
    free_host_before = len(pool._free_host)
    entries_before = list(pool.page_table[1])
    import repro.serving.kv_pool as kvmod

    def boom(*a, **kw):
        raise RuntimeError("kernel down")

    monkeypatch.setattr(kvmod.kops, "batch_copy", boom)
    with pytest.raises(RuntimeError):
        pool.swap_in(1)
    # the pops were restored: no leaked pages, no torn page table
    assert pool.free_device_pages() == free_dev_before
    assert len(pool._free_host) == free_host_before
    assert pool.page_table[1] == entries_before
    assert pool.stats.swaps_in == 0
