"""Descriptor-lifecycle tracing: span model, sampling, dependency edges,
critical path, host-free reconciliation, and the Perfetto export."""
import json

import jax.numpy as jnp
import pytest

from repro.core import OpType, QueueFull, WorkDescriptor, make_device
from repro.core.descriptor import BatchDescriptor
from repro.obs import (
    HOST_PHASES,
    PHASES,
    DescTrace,
    TraceConfig,
    Tracer,
    TraceRateError,
    critical_path,
    host_free_fraction,
    make_tracer,
    phase_breakdown,
    slowest,
    to_perfetto,
)


@pytest.fixture
def buf():
    return jnp.zeros((8, 128), jnp.float32)  # 4KB


def _traced_device(**kw):
    kw.setdefault("trace", 1.0)
    return make_device(n_instances=1, **kw)


# --------------------------------------------------------------------- config
def test_trace_rate_error_is_typed_and_coded():
    for bad in (1.5, -0.1, 2, -3.0):
        with pytest.raises(TraceRateError) as ei:
            TraceConfig(rate=bad)
        assert ei.value.code == "DSA105"
        assert ei.value.rate == bad
        assert isinstance(ei.value, ValueError)


def test_make_device_rejects_bad_rate():
    with pytest.raises(TraceRateError):
        make_device(trace=1.5)  # dsalint: disable=DSA105
    with pytest.raises(TraceRateError):
        make_device(trace=-0.5)  # dsalint: disable=DSA105


def test_make_tracer_spec_resolution():
    assert make_tracer(None) is None
    assert make_tracer(False) is None
    assert make_tracer(True).config.rate == 1.0
    assert make_tracer(0.25).config.rate == 0.25
    cfg = TraceConfig(rate=0.5, capacity=16)
    assert make_tracer(cfg).config is cfg
    t = Tracer()
    assert make_tracer(t) is t
    with pytest.raises(TypeError):
        make_tracer("yes")


def test_untraced_device_has_no_tracer(buf):
    device = make_device(n_instances=1)
    assert device.tracer is None
    fut = device.memcpy_async(buf)
    fut.wait()
    assert fut.trace is None
    device.drain()


# --------------------------------------------------------------------- lifecycle
def test_every_phase_present_on_traced_submit(buf):
    device = _traced_device()
    fut = device.memcpy_async(buf)
    fut.wait()
    device.drain()
    dt = fut.trace
    assert dt is not None
    durs = dt.phase_durations()
    assert set(durs) == set(PHASES)
    assert all(d >= 0.0 for d in durs.values())
    # marks are monotonic after cleaning
    marks = dt.clean_marks()
    ts = list(marks.values())
    assert ts == sorted(ts)


def test_batch_trace_starts_at_first_member_allocation(buf):
    device = _traced_device()
    descs = [WorkDescriptor(op=OpType.MEMCPY, src=buf) for _ in range(4)]
    batch = BatchDescriptor(descriptors=descs)
    fut = device.submit(batch)
    fut.wait()
    device.drain()
    dt = fut.trace
    assert dt.attrs["batch"] == 4
    assert dt.marks["create"] == min(d.created_t for d in descs)


def test_then_continuation_gets_child_trace_and_edge(buf):
    device = _traced_device()
    fut = device.memcpy_async(buf)
    chained = fut.then(lambda r: r)
    chained.wait()
    device.drain()
    child = chained.record.trace
    assert child is not None
    assert child.attrs["kind"] == "then"
    assert child.trace_id == fut.trace.trace_id  # same logical request
    assert child.desc_id != fut.trace.desc_id
    kinds = {(p, c): k for p, c, k in device.tracer.edges()}
    assert kinds[(fut.trace.desc_id, child.desc_id)] == "then"
    # then-traces reuse host_wait + callback only
    assert set(child.phase_durations()) == {"host_wait", "callback"}


def test_after_dependency_records_edge(buf):
    device = _traced_device()
    a = device.memcpy_async(buf)
    b = device.memcpy_async(buf, after=[a])
    device.wait_all([a, b])
    device.drain()
    assert (a.trace.desc_id, b.trace.desc_id, "after") in device.tracer.edges()


def test_spans_track_assignment(buf):
    device = _traced_device()
    fut = device.memcpy_async(buf)
    fut.wait()
    device.drain()
    for sp in fut.trace.spans():
        assert sp.track == ("host" if sp.phase in HOST_PHASES else "engine")
        assert sp.dur >= 0.0


# --------------------------------------------------------------------- sampling
def test_fractional_sampling_is_deterministic(buf):
    device = _traced_device(trace=0.25)
    futs = [device.memcpy_async(buf) for _ in range(32)]
    device.wait_all(futs)
    device.drain()
    sampled = [f for f in futs if f.trace is not None]
    assert len(sampled) == 8  # exactly floor/ceil(32 * 0.25), no RNG
    c = device.tracer.counters_snapshot()
    assert c["sampled"] >= 8
    assert c["skipped"] == 24


def test_rate_zero_samples_nothing(buf):
    device = _traced_device(trace=0.0)
    fut = device.memcpy_async(buf)
    fut.wait()
    device.drain()
    assert fut.trace is None
    assert device.tracer.traces() == []


def test_request_context_shares_trace_id_and_verdict(buf):
    device = _traced_device()
    tracer = device.tracer
    with tracer.request("req42"):
        assert tracer.current_trace_id() == "req42"
        a = device.memcpy_async(buf)
        with tracer.request("inner"):
            assert tracer.current_trace_id() == "inner"
        assert tracer.current_trace_id() == "req42"  # re-entrant restore
        b = device.memcpy_async(buf)
    assert tracer.current_trace_id() is None
    device.wait_all([a, b])
    device.drain()
    assert a.trace.trace_id == b.trace.trace_id == "req42"


def test_request_sampling_verdict_is_stable_per_id():
    tracer = Tracer(TraceConfig(rate=0.5))
    verdicts = {rid: tracer._sample_id(rid) for rid in map(str, range(200))}
    assert any(verdicts.values()) and not all(verdicts.values())
    for rid, v in verdicts.items():
        assert tracer._sample_id(rid) == v  # same id -> same answer


def test_ring_capacity_bounds_retention(buf):
    device = _traced_device(trace=TraceConfig(rate=1.0, capacity=8))
    futs = [device.memcpy_async(buf) for _ in range(20)]
    device.wait_all(futs)
    device.drain()
    tracer = device.tracer
    assert len(tracer.traces()) == 8
    # monotonic fold counters survive ring rotation: all 20 folded
    assert tracer.counters_snapshot()["phase.pe_exec_n"] == 20


def test_marks_are_write_once():
    dt = DescTrace("t", 1, "memcpy")
    t0 = dt.mark("create", 10.0)
    assert dt.mark("create", 99.0) == t0
    assert dt.marks["create"] == 10.0


# --------------------------------------------------------------------- analyzers
def _mk(tracer, desc_id, t0, t1, trace_id=None):
    dt = DescTrace(trace_id or f"d{desc_id}", desc_id, "memcpy", tracer=tracer)
    dt.marks["create"] = t0
    dt.marks["submit_enter"] = t1  # gives the trace one derived span
    dt.marks["observed"] = t1
    tracer._ring.append(dt)
    return dt


def test_critical_path_follows_edges_and_clips_overlap():
    tracer = Tracer()
    _mk(tracer, 1, 0.0, 1.0)
    _mk(tracer, 2, 0.5, 3.0)   # overlaps parent by 0.5s
    _mk(tracer, 3, 0.0, 1.5)   # longer standalone than either alone
    tracer.edge(1, 2, "after")
    cp = critical_path(tracer)
    assert cp["chain"] == [1, 2]
    # 1.0 (node 1) + (3.0 - max(0.5, 1.0)) = 3.0, not 1.0 + 2.5
    assert cp["total_s"] == pytest.approx(3.0)
    assert cp["total_s"] <= cp["elapsed_s"] + 1e-9
    assert cp["elapsed_s"] == pytest.approx(3.0)


def test_critical_path_empty_tracer():
    cp = critical_path(Tracer())
    assert cp == {"chain": [], "total_s": 0.0, "elapsed_s": 0.0,
                  "phases": {}, "shares": {}}


def test_phase_breakdown_shares_sum_to_one(buf):
    device = _traced_device()
    futs = [device.memcpy_async(buf) for _ in range(4)]
    device.wait_all(futs)
    device.drain()
    br = phase_breakdown(device.tracer)
    assert set(br) == set(PHASES)
    assert sum(s["share"] for s in br.values()) == pytest.approx(1.0)
    for s in br.values():
        assert s["count"] == 4
        assert s["p95_s"] >= 0.0


def test_slowest_orders_by_extent():
    tracer = Tracer()
    _mk(tracer, 1, 0.0, 1.0)
    _mk(tracer, 2, 0.0, 5.0)
    _mk(tracer, 3, 0.0, 2.0)
    assert [t.desc_id for t in slowest(tracer, k=2)] == [2, 3]


# --------------------------------------------------------------------- host-free
def test_host_free_fraction_matches_waitstats_exactly(buf):
    """ISSUE acceptance: span-derived host-free within 5% of WaitStats —
    by construction they are the SAME numbers, so demand equality."""
    device = _traced_device()
    futs = [device.memcpy_async(buf) for _ in range(8)]
    device.wait_all(futs)
    device.drain()
    spans_frac = host_free_fraction(device.tracer)
    busy = sum(s.busy_s for s in device.wait_stats.values())
    free = sum(s.free_s for s in device.wait_stats.values())
    assert busy + free > 0
    ws_frac = free / (busy + free)
    assert spans_frac == pytest.approx(ws_frac, rel=1e-9)
    assert abs(spans_frac - ws_frac) <= 0.05 * max(ws_frac, 1e-12)


def test_wait_spans_recorded_per_wait(buf):
    device = _traced_device()
    fut = device.memcpy_async(buf)
    fut.wait()
    device.drain()
    waits = device.tracer.wait_spans()
    assert waits
    for w in waits:
        assert w.t1 >= w.t0
        assert w.busy_s >= 0.0 and w.free_s >= 0.0


# --------------------------------------------------------------------- perfetto
def test_perfetto_valid_json_and_monotonic(buf, tmp_path):
    device = _traced_device()
    a = device.memcpy_async(buf)
    b = device.memcpy_async(buf, after=[a])
    c = b.then(lambda r: r)
    device.wait_all([a, b, c])
    device.drain()
    out = tmp_path / "trace.json"
    text = to_perfetto(device.tracer, str(out))
    assert out.read_text() == text
    doc = json.loads(text)  # strict JSON
    events = doc["traceEvents"]
    assert events
    for ev in events:
        if "ts" in ev:
            assert ev["ts"] >= 0
        if ev.get("ph") == "X":
            assert ev["dur"] >= 0
    slices = [ev for ev in events if ev.get("ph") == "X"]
    names = {ev["name"] for ev in slices}
    assert set(PHASES) <= names
    assert any(ev["name"].startswith("wait/") for ev in slices)
    # flow arrows for both edge kinds, start before finish
    flows = {}
    for ev in events:
        if ev.get("ph") in ("s", "f"):
            flows.setdefault(ev["id"], {})[ev["ph"]] = ev
    assert flows
    for pair in flows.values():
        assert set(pair) == {"s", "f"}
        assert pair["f"]["ts"] >= pair["s"]["ts"]
    assert {ev["name"] for ev in events if ev.get("ph") == "s"} == {
        "after", "then"}
    # one metadata process per track, host first
    meta = [ev for ev in events if ev.get("ph") == "M"
            and ev["name"] == "process_name"]
    assert {m["args"]["name"] for m in meta} >= {"dsa-repro/host"}


def test_perfetto_empty_tracer_is_valid():
    doc = json.loads(to_perfetto(Tracer()))
    names = {ev["name"] for ev in doc["traceEvents"]}
    assert names == {"process_name"}  # just the host track metadata


def test_perfetto_nonfinite_attrs_sanitized(tmp_path):
    tracer = Tracer()
    dt = _mk(tracer, 1, 0.0, 1.0)
    dt.attrs["weird"] = float("nan")
    dt.attrs["obj"] = object()
    text = to_perfetto(tracer)
    doc = json.loads(text)  # would raise on bare NaN tokens
    sl = next(ev for ev in doc["traceEvents"] if ev.get("ph") == "X")
    assert sl["args"]["weird"] is None
    assert isinstance(sl["args"]["obj"], str)


# --------------------------------------------------------------------- errors
def test_queuefull_trace_is_terminated_not_leaked(buf):
    device = _traced_device(wq_size=1, max_retries=0)
    futs = []
    saw_full = False
    try:
        for _ in range(64):
            futs.append(device.memcpy_async(buf))  # dsalint: disable=DSA106 — per-descriptor path under test
    except QueueFull:
        saw_full = True
    if futs:
        device.wait_all(futs)
    device.drain()
    if saw_full:
        errored = [dt for dt in device.tracer.traces()
                   if dt.attrs.get("error") == "QueueFull"]
        assert errored
        for dt in errored:
            assert "resolved" in dt.marks  # terminated, not dangling
