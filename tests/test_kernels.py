"""Per-kernel correctness: shape/dtype sweeps asserting bit-exact agreement
with the pure-jnp/zlib oracles in repro.kernels.ref."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dif, ops, ref

SHAPES = [(128,), (8, 128), (1000,), (64, 130), (3, 5, 7, 4)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.uint8]


def _rand(rng, shape, dtype):
    if dtype in (jnp.float32, jnp.bfloat16):
        return jnp.asarray(rng.normal(size=shape) * 3, dtype)
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-(2**30), 2**30, shape), jnp.int32)
    return jnp.asarray(rng.integers(0, 255, shape), jnp.uint8)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_memcpy_matches_identity(rng, shape, dtype):
    nbytes = int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    if nbytes % 4:
        pytest.skip("non-word-multiple buffer")
    x = _rand(rng, shape, dtype)
    for n_pe in (1, 2, 4):
        y = ops.memcpy(x, n_pe=n_pe)
        assert y.shape == x.shape and y.dtype == x.dtype
        assert (np.asarray(y) == np.asarray(x)).all()


@pytest.mark.parametrize("n_words", [7, 128, 1000, 8192])
@pytest.mark.parametrize("plen", [1, 2, 4])
def test_fill_matches_ref(n_words, plen):
    pat = jnp.asarray(np.arange(1, plen + 1) * 0x01010101, jnp.uint32)
    out = ops.fill(pat, n_words)
    want = ref.fill_ref((n_words,), pat)
    assert (np.asarray(out) == np.asarray(want)).all()


@pytest.mark.parametrize("n", [256, 1000, 4096])
def test_compare_finds_first_diff(rng, n):
    a = jnp.asarray(rng.integers(0, 2**31, n), jnp.uint32)
    eq, idx = ops.compare(a, a)
    assert bool(eq) and int(idx) == -1
    for pos in [0, n // 2, n - 1]:
        b = a.at[pos].add(1)
        eq, idx = ops.compare(a, b)
        weq, widx = ref.compare_ref(a, b)
        assert bool(eq) == bool(weq) and int(idx) == int(widx) == pos


def test_compare_pattern(rng):
    pat = jnp.asarray([0xAA55AA55, 0x12345678], jnp.uint32)
    buf = ref.fill_ref((2048,), pat)
    eq, idx = ops.compare_pattern(buf, pat)
    assert bool(eq)
    eq, idx = ops.compare_pattern(buf.at[99].add(1), pat)
    assert not bool(eq) and int(idx) == 99


@pytest.mark.parametrize("shape,dtype", [((512,), jnp.float32), ((33, 128), jnp.bfloat16)])
def test_dualcast(rng, shape, dtype):
    x = _rand(rng, shape, dtype)
    d1, d2 = ops.dualcast(x)
    assert (np.asarray(d1) == np.asarray(x)).all()
    assert (np.asarray(d2) == np.asarray(x)).all()


@pytest.mark.parametrize("n", [1, 2, 3, 17, 64, 256, 1000, 4096, 65536])
def test_crc32_matches_zlib(rng, n):
    x = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    got = int(ops.crc32(x))
    want = zlib.crc32(np.asarray(x, dtype="<u4").tobytes()) & 0xFFFFFFFF
    assert got == want


def test_crc32_over_dtypes(rng):
    x = jnp.asarray(rng.normal(size=(123, 4)), jnp.float32)
    got = int(ops.crc32(x))
    want = zlib.crc32(np.asarray(x, dtype="<f4").tobytes()) & 0xFFFFFFFF
    assert got == want


@pytest.mark.parametrize("n,k", [(512, 10), (4096, 100), (1024, 0)])
def test_delta_roundtrip(rng, n, k):
    base = jnp.asarray(rng.integers(0, 2**31, n), jnp.uint32)
    src = jnp.array(base)
    if k:
        pos = rng.choice(n, k, replace=False)
        src = src.at[pos].add(7)
    off, data, count, ovf = ops.delta_create(src, base, cap=max(k, 16))
    woff, wdata, wcount, wovf = ref.delta_create_ref(src, base, cap=max(k, 16))
    assert int(count) == int(wcount) == k and bool(ovf) == bool(wovf) is False
    out = ops.delta_apply(base, off, data)
    assert (np.asarray(out) == np.asarray(src)).all()
    out_jnp = ops.delta_apply(base, off, data, use_kernel=False)
    assert (np.asarray(out_jnp) == np.asarray(src)).all()


def test_delta_overflow_flag(rng):
    base = jnp.zeros(256, jnp.uint32)
    src = base + 1  # every word differs
    off, data, count, ovf = ops.delta_create(src, base, cap=16)
    assert bool(ovf) and int(count) == 256


def test_batch_copy_matches_ref(rng):
    P, page = 12, (8, 128)
    src_pool = jnp.asarray(rng.normal(size=(P,) + page), jnp.float32)
    dst_pool = jnp.asarray(rng.normal(size=(P,) + page), jnp.float32)
    src_idx = jnp.asarray([0, 3, 3, 11], jnp.int32)
    dst_idx = jnp.asarray([5, 2, 7, 0], jnp.int32)
    want = ref.batch_copy_ref(src_pool, dst_pool, src_idx, dst_idx)
    got = ops.batch_copy(src_pool, jnp.array(dst_pool), src_idx, dst_idx)
    assert (np.asarray(got) == np.asarray(want)).all()
    # untouched pages preserved
    untouched = sorted(set(range(P)) - set(np.asarray(dst_idx)))
    assert (np.asarray(got)[untouched] == np.asarray(dst_pool)[untouched]).all()


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_batch_copy_dtypes(rng, dtype):
    pool = jnp.asarray(rng.normal(size=(4, 16, 64)), dtype)
    out = ops.batch_copy(pool, jnp.zeros_like(pool), jnp.asarray([1], jnp.int32),
                         jnp.asarray([2], jnp.int32))
    assert (np.asarray(out[2]) == np.asarray(pool[1])).all()


def test_dif_roundtrip_and_detection(rng):
    w = jnp.asarray(rng.integers(0, 2**32, 128 * 6, dtype=np.uint32))
    framed = dif.dif_insert(w)
    assert (np.asarray(framed) == np.asarray(ref.dif_insert_ref(w))).all()
    assert bool(np.asarray(dif.dif_check(framed)).all())
    corrupted = framed.at[2, 64].add(1)
    okm = np.asarray(dif.dif_check(corrupted))
    assert not okm[2] and okm.sum() == 5
    assert (np.asarray(dif.dif_strip(framed)) == np.asarray(w)).all()
    # update recomputes a valid frame after mutation
    fixed = dif.dif_update(corrupted)
    assert bool(np.asarray(dif.dif_check(fixed)).all())
