"""Submit/complete hot path: fused submission (submit_many / SubmitRing),
kick() slot reuse, fused Pallas pairs (copy_crc / fill_verify), the DSA106
unbatched-submit-loop lint, and the bounded CRC shift-matrix cache."""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import desclint
from repro.analysis.apilint import lint_source
from repro.core import OpType, Status, WorkDescriptor, make_device
from repro.core.device import QueueFull
from repro.core.queues import WorkQueue
from repro.kernels import ops


def _bufs(rng, n=8, words=256):
    return [jnp.asarray(rng.integers(0, 2**32, words, dtype=np.uint32))
            for _ in range(n)]


def _copies(bufs):
    return [WorkDescriptor(op=OpType.MEMCPY, src=b) for b in bufs]


# --------------------------------------------------------------------------- fused kernels
def test_copy_crc_parity(rng):
    """copy_crc == (memcpy, crc32) bit-for-bit, including sizes that don't
    tile the 128-lane grid and multi-chunk splits."""
    for n in (4, 100, 512, 1000, 4096, 16384):
        x = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
        copy, crc = ops.copy_crc(x)
        assert np.array_equal(np.asarray(copy), np.asarray(x))
        ref = zlib.crc32(np.asarray(x).tobytes()) & 0xFFFFFFFF
        assert int(crc) == ref
        assert int(crc) == int(ops.crc32(x))


def test_copy_crc_non_u32_payload(rng):
    x = jnp.asarray(rng.standard_normal((16, 128)), jnp.float32)
    copy, crc = ops.copy_crc(x)
    assert copy.shape == x.shape and copy.dtype == x.dtype
    assert np.array_equal(np.asarray(copy), np.asarray(x))
    assert int(crc) == (zlib.crc32(np.asarray(x).tobytes()) & 0xFFFFFFFF)


def test_fill_verify_parity():
    """fill_verify == (fill, compare_pattern): same filled words and the
    all-clear verification record, across pattern widths and ragged sizes."""
    for n_words in (8, 128, 300, 1024, 5000):
        for width in (1, 2, 4):
            pat = jnp.asarray(
                [0xDEADBEEF, 0x12345678, 0xA5A5A5A5, 0x0F0F0F0F][:width],
                jnp.uint32)
            filled, (ok, idx) = ops.fill_verify(pat, n_words)
            ref = ops.fill(pat, n_words)
            assert np.array_equal(np.asarray(filled), np.asarray(ref))
            assert bool(ok) and int(idx) == -1


# --------------------------------------------------------------------------- WQ burst enqueue
def test_wq_submit_many_all_or_nothing():
    q = WorkQueue("swq", mode="shared", size=4)
    descs = _copies([jnp.zeros((8, 128), jnp.float32)] * 3)
    assert q.submit_many(descs) == Status.PENDING
    assert len(q) == 3
    # 3 + 2 > 4: the whole burst bounces, nothing is partially enqueued
    assert q.submit_many(descs[:2]) == Status.RETRY
    assert len(q) == 3
    assert q.submit_many(descs[:1]) == Status.PENDING


def test_wq_submit_many_owner_enforced():
    q = WorkQueue("dwq", mode="dedicated", size=8, owner="t0")
    descs = _copies([jnp.zeros((8, 128), jnp.float32)] * 2)
    assert q.submit_many(descs, producer="t0") == Status.PENDING
    with pytest.raises(PermissionError):
        q.submit_many(descs, producer="t1")  # dsalint: disable=DSA101 — raw WQ submit returns Status


# --------------------------------------------------------------------------- device.submit_many
def test_submit_many_equivalent_to_singles(rng):
    """A fused burst is observably identical to N single submits: same
    results, same WQ/engine byte totals, same per-descriptor trace spans."""
    bufs = _bufs(rng)
    d1 = make_device(wq_mode="shared", trace=1.0)
    d2 = make_device(wq_mode="shared", trace=1.0)

    futs1 = [d1.submit(desc) for desc in _copies(bufs)]  # dsalint: disable=DSA106 — the unbatched reference leg
    d1.wait_all(futs1)
    futs2 = d2.submit_many(_copies(bufs))
    d2.wait_all(futs2)

    for f1, f2 in zip(futs1, futs2):
        assert np.array_equal(np.asarray(f1.result()), np.asarray(f2.result()))
    c1 = d1.engines[0].counters_snapshot()
    c2 = d2.engines[0].counters_snapshot()
    assert c1["bytes"] == c2["bytes"]
    assert c1["completed"] == c2["completed"] == len(bufs)
    assert c2["submitted"] == len(bufs)
    assert c2["fused_batches"] == 1 and c2["fused_descs"] == len(bufs)
    assert c1["fused_batches"] == 0

    wq1 = d1.engines[0].wq(0, 0).stats
    wq2 = d2.engines[0].wq(0, 0).stats
    assert wq1["bytes_submitted"] == wq2["bytes_submitted"]

    marks1 = sorted(frozenset(t.marks) for t in d1.tracer.traces())
    marks2 = sorted(frozenset(t.marks) for t in d2.tracer.traces())
    assert marks1 == marks2  # same lifecycle span structure per descriptor


def test_submit_many_amortizes_enqcmd(rng):
    """On a shared WQ the ENQCMD round trip is charged once per fused
    doorbell: a b8 burst models 7/8 of the per-descriptor ENQCMD away."""
    bufs = _bufs(rng)
    d1 = make_device(wq_mode="shared")
    d2 = make_device(wq_mode="shared")
    futs1 = d1.wait_all([d1.submit(x) for x in _copies(bufs)])  # dsalint: disable=DSA106 — the unbatched reference leg
    futs2 = d2.wait_all(d2.submit_many(_copies(bufs)))
    m1 = sum(f.record.modeled_time_us for f in futs1)
    m2 = sum(f.record.modeled_time_us for f in futs2)
    enq_us = d2.engines[0].model.enqcmd_overhead_s * 1e6
    saved = enq_us * (len(bufs) - 1)
    assert m1 - m2 == pytest.approx(saved, rel=1e-6)


def test_submit_many_dedicated_no_enqcmd_delta(rng):
    """Dedicated WQs (posted MOVDIR64B) never charged ENQCMD, so fusion
    must not change the modeled time there."""
    bufs = _bufs(rng)
    d1 = make_device(wq_mode="dedicated")
    d2 = make_device(wq_mode="dedicated")
    futs1 = d1.wait_all([d1.submit(x) for x in _copies(bufs)])  # dsalint: disable=DSA106 — the unbatched reference leg
    futs2 = d2.wait_all(d2.submit_many(_copies(bufs)))
    m1 = sum(f.record.modeled_time_us for f in futs1)
    m2 = sum(f.record.modeled_time_us for f in futs2)
    assert m1 == pytest.approx(m2, rel=1e-9)


def test_submit_many_failed_fence_fails_all(rng):
    d = make_device()
    bad = d.promise()
    bad.set_error("upstream exploded")
    futs = d.submit_many(_copies(_bufs(rng, n=3)), after=[bad])
    assert len(futs) == 3
    assert all(f.status == Status.ERROR for f in futs)


def test_submit_many_pending_fence_defers_then_runs(rng):
    d = make_device()
    gate = d.promise()
    bufs = _bufs(rng, n=3)
    futs = d.submit_many(_copies(bufs), after=[gate])
    assert not any(f.done() for f in futs)
    gate.set_result(None)
    d.wait_all(futs)
    for f, b in zip(futs, bufs):
        assert f.status == Status.SUCCESS
        assert np.array_equal(np.asarray(f.result()), np.asarray(b))


def test_submit_many_queue_full_raises(rng):
    """A burst that can never fit bounces off every backoff attempt and
    surfaces as QueueFull — not a partial enqueue."""
    d = make_device(wq_size=2, max_retries=1, backoff_base_s=1e-5)
    gate = d.promise()  # hold the WQ full so retries can't drain it
    held = d.submit_many(_copies(_bufs(rng, n=2)), after=[gate])
    with pytest.raises(QueueFull):
        d.submit_many(_copies(_bufs(rng, n=4)), chunk=4)  # dsalint: disable=DSA101 — raises QueueFull
    gate.set_result(None)
    d.wait_all(held)


# --------------------------------------------------------------------------- slot reuse
def test_kick_reuses_slot_objects(rng):
    """The free-slot ring recycles the same _PESlot objects forever —
    inventory is conserved and nothing is reallocated per dispatch."""
    d = make_device()
    eng = d.engines[0]
    inventory = {id(s) for slots in eng._slots.values() for s in slots}
    for _ in range(3):
        d.wait_all(d.submit_many(_copies(_bufs(rng))))
    now = {id(s) for g in eng.config.groups
           for s in eng._free[g.name] + eng._active[g.name]}
    assert now == inventory
    # after the waits everything is retired back onto the free ring
    for g in eng.config.groups:
        assert not eng._active[g.name]
        assert len(eng._free[g.name]) == len(eng._slots[g.name])


# --------------------------------------------------------------------------- submit ring
def test_submit_ring_defers_until_kick(rng):
    d = make_device(wq_mode="shared")
    ring = d.submit_ring(depth=64)
    bufs = _bufs(rng)
    futs = [ring.add(desc) for desc in _copies(bufs)]
    assert len(ring) == len(bufs)
    assert not any(f.done() for f in futs)
    d.wait_all(futs)  # WaitPolicy pumps device.kick() -> ring flush
    assert len(ring) == 0
    for f, b in zip(futs, bufs):
        assert np.array_equal(np.asarray(f.result()), np.asarray(b))
    assert d.engines[0].counters_snapshot()["fused_descs"] == len(bufs)
    assert ring.stats["doorbells"] == 1


def test_submit_ring_auto_flush_at_depth(rng):
    d = make_device()
    ring = d.submit_ring(depth=4)
    futs = [ring.add(desc) for desc in _copies(_bufs(rng, n=4))]
    assert len(ring) == 0  # hit depth -> flushed without an explicit kick
    d.wait_all(futs)
    assert all(f.status == Status.SUCCESS for f in futs)


def test_submit_ring_context_manager_drains(rng):
    d = make_device()
    bufs = _bufs(rng, n=3)
    with d.submit_ring(depth=16) as ring:
        futs = [ring.add(desc) for desc in _copies(bufs)]
    d.wait_all(futs)
    for f, b in zip(futs, bufs):
        assert np.array_equal(np.asarray(f.result()), np.asarray(b))


# --------------------------------------------------------------------------- fused ops e2e
def test_copy_crc_async_device_path(rng):
    d = make_device()
    x = _bufs(rng, n=1, words=1000)[0]
    copy, crc = d.copy_crc_async(x).result()
    assert np.array_equal(np.asarray(copy), np.asarray(x))
    assert int(crc) == (zlib.crc32(np.asarray(x).tobytes()) & 0xFFFFFFFF)


def test_fill_verify_async_device_path():
    d = make_device()
    filled, (ok, idx) = d.fill_verify_async((0xABCD1234,), 1000).result()
    assert bool(ok) and int(idx) == -1
    assert filled.shape[0] == 1000
    assert int(filled[0]) == 0xABCD1234


def test_fused_ops_pass_desclint_strict(rng):
    d = make_device(validate="strict")
    f1 = d.copy_crc_async(_bufs(rng, n=1)[0])
    f2 = d.fill_verify_async((0x5A5A5A5A, 0xA5A5A5A5), 512)
    d.wait_all([f1, f2])
    assert f1.status == Status.SUCCESS and f2.status == Status.SUCCESS


# --------------------------------------------------------------------------- desclint
def test_desclint_copy_crc_missing_src():
    diags = desclint.check_descriptor(WorkDescriptor(op=OpType.COPY_CRC))
    assert any(x.code == "DESC101" for x in diags)


def test_desclint_fill_verify_contract():
    diags = desclint.check_descriptor(
        WorkDescriptor(op=OpType.FILL_VERIFY, n_words=64))
    assert any(x.code == "DESC101" and "pattern" in x.message for x in diags)
    diags = desclint.check_descriptor(
        WorkDescriptor(op=OpType.FILL_VERIFY,
                       pattern=jnp.asarray([1], jnp.uint32), n_words=0))
    assert any(x.code == "DESC101" and "n_words" in x.message for x in diags)
    ok = desclint.check_descriptor(
        WorkDescriptor(op=OpType.FILL_VERIFY,
                       pattern=jnp.asarray([1], jnp.uint32), n_words=64))
    assert ok == []


# --------------------------------------------------------------------------- DSA106 lint
def test_dsa106_flags_unbatched_loop():
    out = lint_source("for d in descs:\n    futs.append(dev.submit(d))\n")
    assert any(v.code == "DSA106" for v in out)


def test_dsa106_exemptions():
    clean = (
        # batched entry point in a loop is already amortized
        "for burst in bursts:\n    futs += dev.submit_many(burst)\n"
        # conditional submit: not a homogeneous fan-out
        "for d in descs:\n    if d.hot:\n        futs.append(dev.submit(d))\n"
        # retry wrapper: breaks out on success
        "for attempt in range(3):\n"
        "    f = dev.submit(d)\n"
        "    if f is not None:\n        break\n"
    )
    assert [v for v in lint_source(clean) if v.code == "DSA106"] == []


def test_dsa106_suppression():
    src = "for d in descs:\n    futs.append(dev.submit(d))  # dsalint: disable=DSA106\n"
    assert [v for v in lint_source(src) if v.code == "DSA106"] == []


# --------------------------------------------------------------------------- shift cache bound
def test_crc_shift_cache_bounded():
    from repro.kernels.ops import _SHIFT_CACHE, _SHIFT_CACHE_MAX, _shift_mat

    _SHIFT_CACHE.clear()
    for nbytes in range(4, 4 + 4 * (_SHIFT_CACHE_MAX + 40), 4):
        _shift_mat(nbytes)
    assert len(_SHIFT_CACHE) == _SHIFT_CACHE_MAX
    # LRU: the most recent keys survive, the oldest were evicted
    last = 4 + 4 * (_SHIFT_CACHE_MAX + 39)
    assert last in _SHIFT_CACHE
    assert 4 not in _SHIFT_CACHE
