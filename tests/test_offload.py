"""G4 optimizer-state offload: plan math + engine round trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_device
from repro.optim.adamw import AdamW
from repro.optim.offload import MomentOffloader, plan


def _state(rng):
    params = {"w": jnp.asarray(rng.normal(size=(128, 64)), jnp.bfloat16),
              "b": jnp.zeros((64,), jnp.bfloat16)}
    opt = AdamW()
    st = opt.init(params)
    st = st._replace(m=jax.tree.map(lambda x: x + 1.5, st.m))
    return params, opt, st


def test_plan_math(rng):
    _, _, st = _state(rng)
    p = plan(st)
    nbytes = 2 * (128 * 64 + 64) * 4
    assert p.hbm_freed_bytes == nbytes
    assert p.transfer_s_per_step > 0
    assert p.hides_under(1.0)  # a 1s step easily hides a few KB
    assert not p.hides_under(0.0)


def test_moment_roundtrip_through_engine(rng):
    _, _, st = _state(rng)
    off = MomentOffloader(make_device(n_instances=2, policy="least_loaded"))
    parked = off.offload(st)
    back = off.fetch(parked)
    for a, b in zip(jax.tree.leaves(st.m), jax.tree.leaves(back.m)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert off.stats["offloads"] == 1 and off.stats["fetches"] == 1
    assert off.stats["bytes_moved"] == 4 * (128 * 64 + 64) * 4  # m+v, twice
