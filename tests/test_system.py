"""End-to-end behaviour tests: train -> checkpoint -> crash -> restore ->
resume; data determinism; the dry-run path on a tiny mesh."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import SHAPES_BY_NAME, get_config
from repro.data.pipeline import Prefetcher, SyntheticLMDataset
from repro.launch.steps import make_decode_step, make_train_step
from repro.models.api import build_model
from repro.optim.adamw import AdamW


def test_data_pipeline_deterministic():
    cfg = get_config("tinyllama-1.1b").reduced()
    ds = SyntheticLMDataset(cfg, batch=4, seq_len=32, seed=3)
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    assert (a["tokens"] == b["tokens"]).all()
    c = ds.batch_at(8)
    assert (a["tokens"] != c["tokens"]).any()


def test_prefetcher_orders_steps():
    cfg = get_config("tinyllama-1.1b").reduced()
    ds = SyntheticLMDataset(cfg, batch=2, seq_len=16)
    pf = Prefetcher(ds, start_step=5, depth=2)
    try:
        steps = [next(pf)[0] for _ in range(4)]
        assert steps == [5, 6, 7, 8]
    finally:
        pf.stop()


def test_train_checkpoint_crash_resume(tmp_path):
    """The core fault-tolerance loop: training state after a crash+restore
    continues bit-compatibly from the checkpoint."""
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, remat=False)
    opt = AdamW(lr=1e-3)
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLMDataset(cfg, batch=4, seq_len=32)
    ckpt = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))

    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt_state, _ = step_fn(params, opt_state, batch)
        if i == 1:
            ckpt.save(2, {"params": params, "opt": opt_state})

    # crash: restore from step 2 and replay steps 2..3 -> must match
    step, tree = ckpt.restore(treedef_like={"params": params, "opt": opt_state})
    assert step == 2
    p2, o2 = tree["params"], tree["opt"]
    for i in range(2, 4):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        p2, o2, _ = step_fn(p2, o2, batch)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
        )


def test_decode_step_donation_in_jit():
    cfg = get_config("gemma3-1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(1))
    cache = model.init_cache(2, 32)
    fn = jax.jit(make_decode_step(model), donate_argnums=(1,))
    toks = jnp.zeros((2, 1), jnp.int32)
    toks, cache = fn(params, cache, toks)
    toks, cache = fn(params, cache, toks)
    assert int(cache["lengths"][0]) == 2


def test_dryrun_single_cell_tiny_mesh(tmp_path):
    """The dry-run machinery end-to-end on the 1-device host mesh: lower,
    compile, cost-walk, roofline terms."""
    from repro.distributed.annotate import use_rules
    from repro.distributed.params import tree_shardings
    from repro.distributed.sharding import rules_for_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.roofline.hlo_cost import analyze_hlo
    from repro.roofline.analysis import roofline_terms

    mesh = make_host_mesh()
    rules = rules_for_mesh(mesh)
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, mesh=mesh)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    params_sh = tree_shardings(params_abs, mesh, rules)
    params_in = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), params_abs, params_sh
    )
    batch_in = {
        "tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((4, 64), jnp.float32),
    }
    opt = AdamW()
    step = make_train_step(model, opt)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    with mesh, use_rules(mesh, rules):
        lowered = jax.jit(step).lower(params_in, opt_abs, batch_in)
    compiled = lowered.compile()
    cost = analyze_hlo(compiled.as_text())
    assert cost.flops > 0 and cost.bytes > 0
    terms = roofline_terms(cost.flops, cost.bytes, cost.coll_bytes)
    assert terms["bottleneck"] in ("compute", "memory", "collective")
    assert compiled.memory_analysis() is not None
