"""Engine behaviour: queues, arbitration, async completion, batch fusion,
DTO, and QoS semantics from the paper (§3.2-3.4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BatchDescriptor,
    DeviceConfig,
    OpType,
    Status,
    StreamEngine,
    WorkDescriptor,
    WorkQueue,
    dto,
    dto_enabled,
    make_device,
)


def test_swq_retry_when_full():
    q = WorkQueue("swq", mode="shared", size=2)
    d = lambda: WorkDescriptor(op=OpType.MEMCPY, src=jnp.zeros((8, 128), jnp.float32))
    assert q.submit(d()) == Status.PENDING
    assert q.submit(d()) == Status.PENDING
    assert q.submit(d()) == Status.RETRY  # ENQCMD retry
    assert q.pop() is not None
    assert q.submit(d()) == Status.PENDING


def test_dwq_owner_enforced():
    q = WorkQueue("dwq", mode="dedicated", size=4, owner="thread0")
    d = WorkDescriptor(op=OpType.MEMCPY, src=jnp.zeros((8, 128), jnp.float32))
    assert q.submit(d, producer="thread0") == Status.PENDING
    with pytest.raises(PermissionError):
        q.submit(d, producer="thread1")  # dsalint: disable=DSA101 — raw WQ submit returns Status


def test_async_submit_wait(rng):
    d = make_device()
    x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    fut = d.memcpy_async(x)
    out = fut.wait()
    assert np.allclose(np.asarray(out), np.asarray(x))
    assert fut.status == Status.SUCCESS
    assert fut.record.bytes_processed == x.size * 4
    assert fut.record.modeled_time_us > 0
    assert fut.op == "memcpy"


def test_engine_error_reported():
    d = make_device()
    bad = WorkDescriptor(op=OpType.DELTA_APPLY, src=None, src_idx=None, src2=None)
    fut = d.submit(bad)
    d.drain()
    assert fut.status == Status.ERROR and fut.error
    with pytest.raises(RuntimeError):
        fut.result()


def test_batch_fusion_equals_individual(rng):
    s = make_device()
    xs = [jnp.asarray(rng.normal(size=(8, 128)), jnp.float32) for _ in range(5)]
    descs = [WorkDescriptor(op=OpType.MEMCPY, src=x) for x in xs]
    outs = s.batch_async(descs).result()
    assert len(outs) == 5
    for o, x in zip(outs, xs):
        assert np.allclose(np.asarray(o), np.asarray(x))


def test_mixed_batch(rng):
    s = make_device()
    x = jnp.asarray(rng.integers(0, 2**31, 1024), jnp.uint32)
    descs = [
        WorkDescriptor(op=OpType.MEMCPY, src=x),
        WorkDescriptor(op=OpType.CRC32, src=x),
        WorkDescriptor(op=OpType.COMPARE, src=x, src2=x),
    ]
    outs = s.batch_async(descs).result()
    assert np.allclose(np.asarray(outs[0]), np.asarray(x))
    import zlib

    assert int(outs[1]) == zlib.crc32(np.asarray(x, "<u4").tobytes()) & 0xFFFFFFFF
    eq, idx = outs[2]
    assert bool(eq)


def test_priority_arbitration():
    """High-priority WQ is serviced preferentially; starvation guard still
    services the low-priority queue (paper F3)."""
    cfg = DeviceConfig.default(n_groups=1, wqs_per_group=2, pes_per_group=1, wq_size=64)
    eng = StreamEngine(cfg)
    eng.wq(0, 0).priority = 0
    eng.wq(0, 1).priority = 10
    x = jnp.zeros((8, 128), jnp.float32)
    lo = [WorkDescriptor(op=OpType.MEMCPY, src=x) for _ in range(6)]
    hi = [WorkDescriptor(op=OpType.MEMCPY, src=x) for _ in range(6)]
    for d in lo:
        eng.wq(0, 0).submit(d)  # dsalint: disable=DSA101,DSA106 — raw WQ submit returns Status
    for d in hi:
        eng.wq(0, 1).submit(d)  # dsalint: disable=DSA101,DSA106 — raw WQ submit returns Status
    eng.drain()
    assert eng.wq(0, 1).stats["dispatched"] == 6
    assert eng.wq(0, 0).stats["dispatched"] == 6  # no starvation


def test_multi_instance_round_robin(rng):
    s = make_device(n_instances=3, policy="round_robin")
    x = jnp.zeros((8, 128), jnp.float32)
    for _ in range(6):
        s.memcpy_async(x).wait()  # dsalint: disable=DSA106 — per-descriptor path under test
    used = [e for e in s.engines if any(w.stats["submitted"] for g in e.config.groups for w in g.wqs)]
    assert len(used) == 3  # load balanced


def test_dto_threshold(rng):
    s = make_device()
    small = jnp.zeros((4,), jnp.float32)  # 16B < threshold
    big = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    with dto_enabled(s, min_bytes=1024):
        assert np.allclose(np.asarray(dto.memcpy(small)), 0)
        assert np.allclose(np.asarray(dto.memcpy(big)), np.asarray(big))
        assert dto.memcmp(big, big)
        z = dto.memset(big, 0)
        assert (np.asarray(z) == 0).all()
    submitted = sum(w.stats["submitted"] for e in s.engines for g in e.config.groups for w in g.wqs)
    assert submitted >= 3  # big ops offloaded; small stayed on "core"


def test_completion_record_timing_fields(rng):
    s = make_device()
    x = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    fut = s.memcpy_async(x)
    s.drain()
    assert fut.record.modeled_time_us > 0
    assert fut.record.wall_time_us >= 0


def test_stream_shim_removed_with_pointer():
    """The deprecated Stream/make_stream shims are gone after their one
    grace release; residual imports fail with a migration-guide pointer."""
    import repro.core
    import repro.core.api

    for module in (repro.core, repro.core.api):
        for name in ("Stream", "make_stream"):
            with pytest.raises(AttributeError, match="docs/api.md"):
                getattr(module, name)
    # the from-import form fails too (the import machinery rewraps the
    # AttributeError, so the pointer text is only on the attribute path)
    with pytest.raises(ImportError):
        from repro.core import make_stream  # noqa: F401


def test_batch_fusion_respects_flags(rng):
    """Mixed cache hints in a copy batch must NOT take the fused path with
    shared flags — results still match the per-descriptor semantics."""
    from repro.core import CacheHint

    s = make_device()
    xs = [jnp.asarray(rng.normal(size=(8, 128)), jnp.float32) for _ in range(4)]
    descs = [
        WorkDescriptor(
            op=OpType.MEMCPY, src=x,
            cache_hint=CacheHint.TO_CACHE if i % 2 else CacheHint.TO_MEMORY,
        )
        for i, x in enumerate(xs)
    ]
    outs = s.batch_async(descs).result()
    assert len(outs) == 4
    for o, x in zip(outs, xs):
        assert np.allclose(np.asarray(o), np.asarray(x))
