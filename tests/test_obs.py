"""Observability subsystem (repro.obs): deterministic-clock Sampler ticks,
ring-buffer bounds, delta-vs-snapshot reconciliation, exporters, and the
telemetry record-pruning fix the subsystem rides on."""
import csv
import io
import json

import jax.numpy as jnp
import pytest

from repro.core import Topology, make_device
from repro.core.telemetry import Telemetry
from repro.obs import Sampler, Series, percentile


class FakeClock:
    """Injectable monotonic clock: tests advance it explicitly."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


# ---------------------------------------------------------------- series
def test_percentile_nearest_rank():
    vals = [float(v) for v in range(1, 101)]  # 1..100
    assert percentile(vals, 50) == 50.0
    assert percentile(vals, 95) == 95.0
    assert percentile(vals, 100) == 100.0
    assert percentile([7.0], 50) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50)


def test_series_ring_buffer_bounds():
    s = Series("m", capacity=8)
    for i in range(20):
        s.append(float(i), float(i))
    assert len(s) == 8
    assert s.values == [float(i) for i in range(12, 20)]  # oldest rotated out
    assert s.last() == 19.0
    # trailing window selects by time, not count
    assert [v for _, v in s.window(3.0)] == [16.0, 17.0, 18.0, 19.0]


def test_series_summary_known_values():
    s = Series("m")
    for i, v in enumerate([1.0, 2.0, 3.0, 4.0, 100.0]):
        s.append(float(i), v)
    out = s.summary()
    assert out["n"] == 5
    assert out["p50"] == 3.0
    assert out["max"] == 100.0
    assert out["mean"] == pytest.approx(22.0)
    assert out["last"] == 100.0
    assert Series("empty").summary() == {
        "n": 0, "p50": 0.0, "p95": 0.0, "max": 0.0, "mean": 0.0, "last": 0.0}


# ---------------------------------------------------------------- sampler
def _burst(device, buf, n):
    futs = [device.memcpy_async(buf) for _ in range(n)]
    device.wait_all(futs)
    return futs


def test_sampler_deltas_reconcile_with_snapshot(rng):
    """Acceptance criterion: the summed delta series equal the final
    Telemetry.snapshot() totals — both count the same resolved records."""
    clock = FakeClock()
    d = make_device(n_instances=2)
    tel = Telemetry(d)
    sampler = Sampler(d, clock=clock)
    buf = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)  # 128KB
    for _ in range(3):
        _burst(d, buf, 4)
        clock.advance(1.0)
        sampler.tick()
    d.drain()
    clock.advance(1.0)
    sampler.tick()

    snap = tel.snapshot()
    snap_bytes = sum(c["bytes"] for e in snap["engines"].values()
                     for c in e["ops"].values())
    snap_ops = sum(c["count"] for e in snap["engines"].values()
                   for c in e["ops"].values())
    assert snap_ops == 12
    assert snap_bytes == 12 * buf.size * 4

    series_bytes = sum(sampler.series[f"engine.{e.name}.bytes"].sum()
                       for e in d.engines)
    series_ops = sum(sampler.series[f"engine.{e.name}.ops"].sum()
                     for e in d.engines)
    assert series_bytes == snap_bytes
    assert series_ops == snap_ops
    # the never-rotating totals agree too
    assert sum(t["bytes"] for t in sampler.totals["engines"].values()) == snap_bytes
    assert sampler.totals["device"]["ticks"] == 4

    # ...and so does the exported CSV, parsed back column by column
    reader = csv.DictReader(io.StringIO(sampler.to_csv()))
    csv_bytes = sum(float(row[f"engine.{e.name}.bytes"] or 0)
                    for row in reader for e in d.engines)
    assert csv_bytes == snap_bytes


def test_sampler_row_ring_bounded(rng):
    clock = FakeClock()
    d = make_device()
    sampler = Sampler(d, capacity=8, clock=clock)
    for _ in range(20):
        clock.advance(0.1)
        sampler.tick()
    assert len(sampler.rows()) == 8
    for s in sampler.series.values():
        assert len(s) <= 8
    # totals still count every tick, including the rotated-out ones
    assert sampler.totals["device"]["ticks"] == 20


def test_sampler_per_node_series_match_rollup(rng):
    """On a 2-node fabric the per-node delta series sum to the same node
    rollup Telemetry reports (local vs cross bytes attribution)."""
    clock = FakeClock()
    topo = Topology.symmetric(2, engines_per_node=1)
    d = make_device(topology=topo, policy="numa_local")
    tel = Telemetry(d)
    sampler = Sampler(d, clock=clock)
    buf = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)  # 32KB
    d.register(buf, node=0)
    # local on node 0, then cross: engine on node 1 reads the node-0 buffer
    d.wait_all([d.memcpy_async(buf, node=0) for _ in range(3)])
    d.wait_all([d.memcpy_async(buf, node=1) for _ in range(2)])
    d.drain()
    clock.advance(1.0)
    sampler.tick()

    nodes = tel.snapshot()["nodes"]
    for nid, rollup in nodes.items():
        assert sampler.totals["nodes"][nid]["local_bytes"] == rollup["local_bytes"]
        assert sampler.totals["nodes"][nid]["cross_bytes"] == rollup["cross_bytes"]
        assert sampler.totals["nodes"][nid]["link_bytes"] == rollup["link_bytes"]
    assert nodes[1]["cross_bytes"] == 2 * buf.size * 4
    # cross traffic shows up in the per-tick rate series with dt=1s
    assert sampler.series["node.1.cross_gbps"].last() == pytest.approx(
        2 * buf.size * 4 / 1e9)
    assert sampler.series["node.1.link_occupancy"].last() > 0


def test_sampler_thread_lifecycle_and_observer_registration(rng):
    d = make_device()
    sampler = Sampler(d, interval_s=0.01)
    assert not sampler.running
    sampler.start()
    assert sampler.running
    assert sampler in d.observers
    buf = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    _burst(d, buf, 3)
    sampler.stop()
    assert not sampler.running
    assert sampler not in d.observers
    # the final stop() tick guarantees the tail was sampled
    assert sum(t["ops"] for t in sampler.totals["engines"].values()) == 3


def test_device_observe_convenience(rng):
    d = make_device()
    with d.observe(interval_s=0.01) as sampler:
        assert sampler.running
        assert sampler in d.observers
    assert not sampler.running


def test_gauges_fold_into_next_tick(rng):
    clock = FakeClock()
    d = make_device()
    sampler = Sampler(d, clock=clock)
    sampler.gauge("serving.queue_depth", 5)
    sampler.gauge("serving.queue_depth", 7)  # last write wins within a tick
    clock.advance(1.0)
    row = sampler.tick()
    assert row["serving.queue_depth"] == 7.0
    assert "serving.queue_depth" in sampler.columns()
    assert sampler.series["serving.queue_depth"].values == [5.0, 7.0]
    clock.advance(1.0)
    assert "serving.queue_depth" not in sampler.tick()  # not sticky


def test_wait_policy_host_free_fraction_series(rng):
    clock = FakeClock()
    d = make_device(wait_policy="umwait")
    sampler = Sampler(d, clock=clock)
    buf = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)
    _burst(d, buf, 4)
    clock.advance(1.0)
    sampler.tick()
    s = sampler.series.get("wait.umwait.host_free_frac")
    assert s is not None and len(s) == 1
    assert 0.0 <= s.last() <= 1.0


# ---------------------------------------------------------------- exporters
def test_csv_and_jsonl_round_trip(rng, tmp_path):
    clock = FakeClock()
    d = make_device()
    sampler = Sampler(d, clock=clock)
    buf = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    _burst(d, buf, 2)
    clock.advance(0.5)
    sampler.tick()
    clock.advance(0.5)
    sampler.tick()

    csv_path = tmp_path / "obs" / "trace.csv"
    text = sampler.to_csv(str(csv_path))
    assert csv_path.read_text() == text
    rows = list(csv.DictReader(io.StringIO(text)))
    assert len(rows) == 2
    assert rows[0]["dt_s"] == "0.5"
    # wide form: every metric that ever appeared is a column in every row
    assert set(sampler.columns()) <= set(rows[0].keys())

    jsonl_path = tmp_path / "obs" / "trace.jsonl"
    jtext = sampler.to_jsonl(str(jsonl_path))
    objs = [json.loads(line) for line in jtext.splitlines()]
    assert len(objs) == 2
    assert objs[0]["dt_s"] == 0.5
    assert [o["time_s"] for o in objs] == [0.5, 1.0]


def test_summary_windowed(rng):
    clock = FakeClock()
    d = make_device()
    sampler = Sampler(d, clock=clock)
    for _ in range(5):
        clock.advance(1.0)
        sampler.tick()
    summ = sampler.summary()
    assert summ["engine.dsa0.bytes"]["n"] == 5
    # a 2s trailing window keeps t in [3, 5] (inclusive cutoff): 3 ticks
    assert sampler.summary(window_s=2.0)["engine.dsa0.bytes"]["n"] == 3


# ---------------------------------------------------------------- leak fix
def test_telemetry_prunes_completion_records(rng):
    """The former unbounded-growth leak: resolved records must leave
    engine.records once sampled, keeping memory O(in-flight)."""
    d = make_device(n_instances=2)
    tel = Telemetry(d)
    buf = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    for _ in range(5):
        _burst(d, buf, 10)
        tel.sample()
    d.drain()
    tel.sample()
    assert sum(len(e.records) for e in d.engines) == 0
    assert all(len(s) == 0 for s in tel.store._seen.values())
    # pruning must not lose counts
    assert tel.store.totals() == {"count": 50, "bytes": 50 * buf.size * 4}


def test_telemetry_prune_false_keeps_records_bounded(rng):
    d = make_device()
    tel_a = Telemetry(d, prune=False)
    tel_b = Telemetry(d, prune=False)  # two record-walkers coexist
    buf = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    _burst(d, buf, 6)
    d.drain()
    tel_a.sample()
    tel_b.sample()
    assert tel_a.store.totals() == tel_b.store.totals()
    assert tel_a.store.totals()["count"] == 6
    # records survive (prune=False) but the seen-set is clipped to them
    live = sum(len(e.records) for e in d.engines)
    assert live == 6
    assert sum(len(s) for s in tel_a.store._seen.values()) == live


# ---------------------------------------------------------------- monitor
def test_pcm_repro_render_frame(rng):
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                           / "tools"))
    try:
        import pcm_repro
    finally:
        sys.path.pop(0)
    clock = FakeClock()
    topo = Topology.symmetric(2, engines_per_node=1)
    d = make_device(topology=topo, policy="numa_local")
    sampler = Sampler(d, clock=clock)
    buf = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    _burst(d, buf, 2)
    d.drain()
    clock.advance(1.0)
    sampler.tick()
    text = pcm_repro.render_frame(sampler, d, numa=True, frame=1)
    assert "ENGINE" in text and "GB/s" in text
    for e in d.engines:
        assert e.name in text
    assert "NODE" in text and "CROSS-GB/s" in text
    assert "pressure:" in text


# ---------------------------------------------------------------- exporter edge cases
def test_export_empty_sampler_round_trips(tmp_path):
    """Zero ticks: CSV is a lone header, JSONL is empty — both re-parse."""
    d = make_device()
    sampler = Sampler(d, clock=FakeClock())
    text = sampler.to_csv(str(tmp_path / "empty.csv"))
    assert list(csv.DictReader(io.StringIO(text))) == []
    assert text.splitlines()[0]  # header line present
    jtext = sampler.to_jsonl(str(tmp_path / "empty.jsonl"))
    assert jtext == ""
    assert (tmp_path / "empty.jsonl").read_text() == ""


def test_export_nonfinite_values_stay_parseable():
    """NaN/inf gauges must not produce bare NaN tokens (invalid JSON) or
    poisoned CSV cells: JSONL writes null, CSV an empty cell."""
    clock = FakeClock()
    d = make_device()
    sampler = Sampler(d, clock=clock)
    clock.advance(1.0)
    sampler.gauge("weird.nan", float("nan"))
    sampler.gauge("weird.inf", float("inf"))
    sampler.gauge("weird.ok", 3.0)
    sampler.tick()
    for line in sampler.to_jsonl().splitlines():
        obj = json.loads(line)  # raises on bare NaN/Infinity tokens
        assert obj["weird.nan"] is None
        assert obj["weird.inf"] is None
        assert obj["weird.ok"] == 3.0
    row = next(csv.DictReader(io.StringIO(sampler.to_csv())))
    assert row["weird.nan"] == ""
    assert row["weird.inf"] == ""
    assert row["weird.ok"] == "3"


def test_export_after_ring_wraparound(rng):
    """Exports see only the retained window, with consistent columns."""
    clock = FakeClock()
    d = make_device()
    sampler = Sampler(d, capacity=4, clock=clock)
    buf = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    for _ in range(10):
        _burst(d, buf, 1)
        clock.advance(1.0)
        sampler.tick()
    d.drain()
    rows = list(csv.DictReader(io.StringIO(sampler.to_csv())))
    assert len(rows) == 4
    assert [float(r["time_s"]) for r in rows] == [7.0, 8.0, 9.0, 10.0]
    objs = [json.loads(line) for line in sampler.to_jsonl().splitlines()]
    assert [o["time_s"] for o in objs] == [7.0, 8.0, 9.0, 10.0]


# ---------------------------------------------------------------- teardown races
def test_sampler_tick_error_is_stored_not_raised():
    """A reader racing device teardown must not kill the monitor thread
    with a traceback: the error lands on sampler.error and stop() still
    detaches cleanly (tools/pcm_repro.py exits 0 and reports it)."""
    d = make_device()
    sampler = Sampler(d, clock=FakeClock())

    def boom():
        raise RuntimeError("engine torn down mid-read")

    for e in d.engines:
        e.counters_snapshot = boom
    sampler.start()
    sampler._thread.join(timeout=5.0)  # _run swallows the error and stops
    assert not sampler._thread.is_alive()
    sampler.stop()  # second stop with the device broken: still no raise
    assert isinstance(sampler.error, RuntimeError)


def test_sampler_stop_survives_final_tick_failure():
    d = make_device()
    sampler = Sampler(d, clock=FakeClock())
    sampler.tick()

    def boom():
        raise RuntimeError("device drained under the sampler")

    for e in d.engines:
        e.counters_snapshot = boom
    sampler.stop()  # final flush tick fails internally; no traceback
    assert isinstance(sampler.error, RuntimeError)
    assert len(sampler.rows()) == 1  # pre-failure data survives


# ---------------------------------------------------------------- trace series
def test_sampler_ticks_trace_phase_occupancy(rng):
    """With make_device(trace=...), each tick derives per-phase occupancy
    (folded phase seconds per wall second) from the tracer's monotonic
    counters — the pcm_repro live phase line."""
    clock = FakeClock()
    d = make_device(trace=1.0)
    sampler = Sampler(d, clock=clock)
    buf = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    _burst(d, buf, 4)
    d.drain()
    clock.advance(2.0)
    sampler.tick()
    s = sampler.series.get("trace.sampled")
    assert s is not None and s.sum() == 4
    occ = sampler.series["trace.phase.pe_exec.occupancy"]
    folded = d.tracer.counters_snapshot()["phase.pe_exec_s"]
    assert occ.last() == pytest.approx(folded / 2.0)
    # idle second tick: occupancy falls to zero, counters stay monotonic
    clock.advance(2.0)
    sampler.tick()
    assert occ.last() == 0.0
