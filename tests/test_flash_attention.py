"""Flash-attention Pallas kernel vs the pure-jnp chunked-attention oracle:
shape/GQA/window/meta sweeps + block-size robustness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import attention


CASES = [
    # B, Sq, Skv, H, KV, hd, causal, window, n_meta
    (2, 128, 128, 4, 2, 32, True, 0, 0),
    (1, 256, 256, 8, 8, 64, True, 0, 0),
    (2, 128, 128, 4, 1, 32, True, 64, 0),
    (1, 256, 256, 4, 2, 32, True, 64, 16),
    (2, 128, 128, 4, 4, 64, False, 0, 0),
    (1, 64, 64, 2, 2, 128, True, 0, 0),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_reference(rng, case):
    B, Sq, Skv, H, KV, hd, causal, window, n_meta = case
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, KV, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window, n_meta=n_meta,
                          q_blk=64, kv_blk=64)
    want = attention(q, k, v, causal=causal, window=window, n_meta=n_meta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("q_blk,kv_blk", [(32, 32), (64, 128), (128, 64)])
def test_flash_block_size_invariance(rng, q_blk, kv_blk):
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    a = flash_attention(q, k, v, q_blk=q_blk, kv_blk=kv_blk)
    b = flash_attention(q, k, v, q_blk=64, kv_blk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, q_blk=64, kv_blk=64)
    want = attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2, rtol=3e-2
    )


def test_flash_trainable_gradients(rng):
    """custom-vjp wrapper: flash fwd, reference bwd — grads match AD of ref."""
    from repro.models.layers import attention_trainable

    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)

    def loss_flash(q, k, v):
        return attention_trainable(q, k, v, impl="flash").sum()

    def loss_ref(q, k, v):
        return attention(q, k, v).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)
