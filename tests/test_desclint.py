"""Descriptor validity checking (repro.analysis.desclint) and the
make_device(validate=) submit-time wiring.

One strict-mode test per malformed-descriptor family (fill / compare /
delta / DIF / batch) asserting the SPECIFIC typed error and code, plus
warn-mode counter assertions surfaced through the obs Sampler, locality
checks against the buffer registry, and the WorkDescriptor.nbytes
degenerate-input regressions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import desclint
from repro.analysis.desclint import (
    DescriptorError,
    IndexShapeError,
    LocalityError,
    MissingOperandError,
    OperandMismatchError,
)
from repro.core import make_device
from repro.core.descriptor import BatchDescriptor, CacheHint, OpType, WorkDescriptor
from repro.core.topology import Topology
from repro.obs import Sampler


@pytest.fixture
def strict():
    return make_device(validate="strict")


def _arr(n=64, dtype=jnp.float32):
    return jnp.arange(n, dtype=jnp.int32).astype(dtype)


# --------------------------------------------------------------------------- strict: five op families
def test_strict_fill_missing_pattern(strict):
    with pytest.raises(MissingOperandError) as ei:
        _ = strict.submit(WorkDescriptor(op=OpType.FILL, n_words=0))
    assert ei.value.code == "DESC101"
    assert any(d.code == "DESC101" for d in ei.value.diagnostics)


def test_strict_compare_shape_mismatch(strict):
    with pytest.raises(OperandMismatchError) as ei:
        _ = strict.submit(WorkDescriptor(op=OpType.COMPARE,
                                     src=_arr(64), src2=_arr(32)))
    assert ei.value.code == "DESC102"


def test_strict_compare_dtype_mismatch(strict):
    with pytest.raises(OperandMismatchError):
        _ = strict.submit(WorkDescriptor(op=OpType.COMPARE,
                                     src=_arr(64, jnp.float32),
                                     src2=_arr(64, jnp.int32)))


def test_strict_delta_bad_cap_and_ref(strict):
    # family check: ref/src disagreement is DESC102...
    with pytest.raises(OperandMismatchError) as ei:
        _ = strict.submit(WorkDescriptor(op=OpType.DELTA_CREATE,
                                     src=_arr(64), src2=_arr(128), cap=16))
    assert ei.value.code == "DESC102"
    # ...and so is a nonsensical capacity
    with pytest.raises(OperandMismatchError):
        _ = strict.submit(WorkDescriptor(op=OpType.DELTA_CREATE,
                                     src=_arr(64), src2=_arr(64), cap=0))
    # missing reference entirely is the DESC101 family
    with pytest.raises(MissingOperandError):
        _ = strict.submit(WorkDescriptor(op=OpType.DELTA_CREATE,
                                     src=_arr(64), cap=16))


def test_strict_dif_wrong_dtype_and_framing(strict):
    words = jnp.arange(256, dtype=jnp.uint32)
    # wrong word dtype
    with pytest.raises(OperandMismatchError) as ei:
        _ = strict.submit(WorkDescriptor(op=OpType.DIF_INSERT,
                                     src=_arr(256, jnp.float32)))
    assert ei.value.code == "DESC102"
    # dif_check wants framed 2-D blocks, not a flat stream
    with pytest.raises(OperandMismatchError):
        _ = strict.submit(WorkDescriptor(op=OpType.DIF_CHECK, src=words))


def test_strict_batch_copy_index_shape(strict):
    pool = jnp.zeros((8, 32), jnp.float32)
    with pytest.raises(IndexShapeError) as ei:
        _ = strict.submit(WorkDescriptor(
            op=OpType.BATCH_COPY, src=pool, dst_pool=pool,
            src_idx=jnp.arange(4), dst_idx=jnp.arange(3)))
    assert ei.value.code == "DESC103"
    # missing dst_pool is the DESC101 family
    with pytest.raises(MissingOperandError):
        _ = strict.submit(WorkDescriptor(
            op=OpType.BATCH_COPY, src=pool,
            src_idx=jnp.arange(4), dst_idx=jnp.arange(4)))


def test_strict_locality_conflict():
    topo = Topology.symmetric(2, engines_per_node=1)
    dev = make_device(topology=topo, validate="strict")
    buf = jnp.ones((64,), jnp.float32)
    dev.register(buf, node=1)
    # explicit stamp contradicting the registry
    with pytest.raises(LocalityError) as ei:
        _ = dev.submit(WorkDescriptor(op=OpType.MEMCPY, src=buf, src_node=0))
    assert ei.value.code == "DESC104"
    # node hint outside the topology
    with pytest.raises(LocalityError):
        _ = dev.submit(WorkDescriptor(op=OpType.MEMCPY,
                                  src=jnp.ones((8,), jnp.float32),
                                  src_node=7))


def test_strict_clean_descriptors_pass(strict):
    buf = _arr(128)
    assert strict.memcpy(buf).shape == buf.shape
    rec = strict.submit(WorkDescriptor(op=OpType.COMPARE,
                                       src=buf, src2=buf)).result()
    strict.drain()


# --------------------------------------------------------------------------- warn mode + sampler
def test_warn_mode_counts_instead_of_raising():
    dev = make_device()  # validate="warn" is the default
    assert dev.validate == "warn"
    fut = dev.submit(WorkDescriptor(op=OpType.COMPARE,
                                    src=_arr(64), src2=_arr(32)))
    assert dev.policy_stats["desclint_warnings"] >= 1
    dev.drain()


def test_warn_counter_surfaces_in_sampler_series():
    dev = make_device()
    sampler = Sampler(dev)
    sampler.tick()
    before = dev.policy_stats["desclint_warnings"]
    _ = dev.submit(WorkDescriptor(op=OpType.COMPARE, src=_arr(64), src2=_arr(32)))
    dev.drain()
    sampler.tick()
    emitted = dev.policy_stats["desclint_warnings"] - before
    assert emitted >= 1
    assert sampler.series["device.desclint_warnings"].sum() == emitted
    # a clean tick records a zero delta, not a repeat
    sampler.tick()
    assert sampler.series["device.desclint_warnings"].sum() == emitted


def test_validate_off_skips_checks():
    dev = make_device(validate="off")
    _ = dev.submit(WorkDescriptor(op=OpType.COMPARE, src=_arr(64), src2=_arr(32)))
    assert dev.policy_stats["desclint_warnings"] == 0
    dev.drain()


def test_validate_rejects_unknown_mode():
    with pytest.raises(ValueError):
        make_device(validate="loud")


# --------------------------------------------------------------------------- batch homogeneity (DESC105)
def test_batch_homogeneity_warning_is_warn_severity():
    a = jnp.ones((64,), jnp.float32)
    b = jnp.ones((32,), jnp.float32)
    batch = BatchDescriptor(descriptors=[
        WorkDescriptor(op=OpType.MEMCPY, src=a, cache_hint=CacheHint.TO_CACHE),
        WorkDescriptor(op=OpType.MEMCPY, src=b, cache_hint=CacheHint.TO_MEMORY),
    ])
    diags = desclint.check(batch)
    codes = {d.code for d in diags}
    assert "DESC105" in codes
    assert all(d.severity == "warn" for d in diags if d.code == "DESC105")
    # strict mode does NOT raise for warn-only findings, it counts them
    dev = make_device(validate="strict")
    dev.wait(dev.submit(batch))
    assert dev.policy_stats["desclint_warnings"] >= 1


def test_homogeneous_batch_is_clean():
    a = jnp.ones((64,), jnp.float32)
    batch = BatchDescriptor(descriptors=[
        WorkDescriptor(op=OpType.MEMCPY, src=a),
        WorkDescriptor(op=OpType.MEMCPY, src=a),
    ])
    assert desclint.check(batch) == []


# --------------------------------------------------------------------------- nbytes regressions (satellite)
def test_nbytes_empty_batch_copy_returns_zero():
    d = WorkDescriptor(op=OpType.BATCH_COPY,
                       src=np.zeros((0, 16), np.float32),
                       dst_pool=np.zeros((4, 16), np.float32),
                       src_idx=np.arange(0), dst_idx=np.arange(0))
    assert d.nbytes == 0  # was: ZeroDivisionError
    assert any(x.code == "DESC106" for x in desclint.check(d))


def test_nbytes_batch_copy_missing_index_returns_zero():
    d = WorkDescriptor(op=OpType.BATCH_COPY,
                       src=np.zeros((4, 16), np.float32))
    assert d.nbytes == 0  # was: AttributeError on src_idx=None


def test_nbytes_dtypeless_operand_returns_zero():
    class Duck:
        size = 64
        shape = (64,)

    d = WorkDescriptor(op=OpType.MEMCPY, src=Duck())
    assert d.nbytes == 0  # was: AttributeError on .dtype
    assert any(x.code == "DESC102" for x in desclint.check(d))


def test_nbytes_normal_paths_unchanged():
    src = np.zeros((4, 16), np.float32)
    d = WorkDescriptor(op=OpType.BATCH_COPY, src=src, dst_pool=src.copy(),
                       src_idx=np.arange(2), dst_idx=np.arange(2))
    assert d.nbytes == 2 * 16 * 4
    assert WorkDescriptor(op=OpType.FILL, pattern=np.uint32(7),
                          n_words=10).nbytes == 40
