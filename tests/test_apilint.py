"""Seeded-bug fixtures proving each apilint rule fires (and only on the
bug), plus suppression-comment and CLI behavior."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import apilint

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _codes(src):
    return [v.code for v in apilint.lint_source(textwrap.dedent(src))]


# --------------------------------------------------------------------------- DSA101
def test_dsa101_dropped_future_fires():
    assert _codes("""
        def f(dev, buf):
            dev.submit(buf)
    """) == ["DSA101"]


def test_dsa101_async_helper_fires():
    assert _codes("""
        def f(dev, buf):
            dev.memcpy_async(buf)
    """) == ["DSA101"]


def test_dsa101_bound_future_clean():
    assert _codes("""
        def f(dev, buf):
            fut = dev.submit(buf)
            return fut.result()
    """) == []


# --------------------------------------------------------------------------- DSA102
def test_dsa102_blocking_result_in_lambda_callback():
    assert _codes("""
        def f(dev, fut, other):
            fut.add_done_callback(lambda _: other.result())
    """) == ["DSA102"]


def test_dsa102_blocking_wait_in_named_callback():
    assert _codes("""
        def f(dev, fut, other):
            def on_done(_):
                dev.wait_all([other])
            fut.then(on_done)
    """) == ["DSA102"]


def test_dsa102_zero_timeout_poll_is_exempt():
    assert _codes("""
        def f(dev, fut, other):
            fut.add_done_callback(lambda _: other.wait(timeout=0))
    """) == []


def test_dsa102_blocking_outside_callback_clean():
    assert _codes("""
        def f(dev, fut):
            return fut.result()
    """) == []


# --------------------------------------------------------------------------- DSA103
def test_dsa103_raw_kick_loop_fires():
    assert _codes("""
        def f(dev, rec):
            while not rec.is_done():
                dev.kick()
    """) == ["DSA103"]


def test_dsa103_wait_policy_clean():
    assert _codes("""
        def f(dev, futs):
            dev.wait_all(futs)
    """) == []


# --------------------------------------------------------------------------- DSA104
def test_dsa104_swallowed_queuefull_fires():
    assert _codes("""
        def f(dev, buf):
            try:
                fut = dev.submit(buf)
            except Exception:
                pass
    """) == ["DSA104"]


def test_dsa104_bare_except_fires():
    assert _codes("""
        def f(dev, buf):
            try:
                fut = dev.submit(buf)
            except:
                return None
    """) == ["DSA104"]


def test_dsa104_handler_naming_queuefull_clean():
    assert _codes("""
        def f(dev, buf, QueueFull):
            try:
                fut = dev.submit(buf)
            except QueueFull:
                return None
    """) == []


def test_dsa104_broad_handler_reraising_clean():
    assert _codes("""
        def f(dev, buf):
            try:
                fut = dev.submit(buf)
            except Exception:
                raise
    """) == []


# --------------------------------------------------------------------------- suppression
def test_suppression_comment_single_code():
    assert _codes("""
        def f(dev, buf):
            dev.submit(buf)  # dsalint: disable=DSA101
    """) == []


def test_suppression_comment_all_codes():
    assert _codes("""
        def f(dev, rec):
            while not rec.is_done():  # dsalint: disable
                dev.kick()
    """) == []


def test_suppression_of_other_code_does_not_mask():
    assert _codes("""
        def f(dev, buf):
            dev.submit(buf)  # dsalint: disable=DSA103
    """) == ["DSA101"]


# --------------------------------------------------------------------------- DSA105
def test_dsa105_trace_rate_literal_out_of_range_fires():
    assert _codes("""
        dev = make_device(trace=1.5)
    """) == ["DSA105"]


def test_dsa105_negative_literal_fires():
    # -0.5 parses as UnaryOp(USub, Constant), not a Constant
    assert _codes("""
        cfg = TraceConfig(rate=-0.5)
    """) == ["DSA105"]


def test_dsa105_dotted_callee_and_device_kwarg_fire():
    assert _codes("""
        d = repro.Device(topo, trace=2)
    """) == ["DSA105"]


def test_dsa105_in_range_bool_and_variable_clean():
    assert _codes("""
        a = make_device(trace=0.5)
        b = make_device(trace=True)
        c = make_device(trace=1)
        r = 99.0
        d = make_device(trace=r)
        e = TraceConfig(rate=0.0)
    """) == []


def test_dsa105_unrelated_callee_clean():
    # only make_device/Device/TraceConfig call sites carry a rate
    assert _codes("""
        x = configure(trace=5.0)
        y = Device2(rate=5.0)
    """) == []


def test_dsa105_suppression_comment():
    assert _codes("""
        dev = make_device(trace=1.5)  # dsalint: disable=DSA105
    """) == []


# --------------------------------------------------------------------------- entry points / CLI
def test_lint_source_reports_position_and_message():
    vs = apilint.lint_source("def f(d, b):\n    d.submit(b)\n", path="x.py")
    assert len(vs) == 1
    v = vs[0]
    assert (v.path, v.line, v.code) == ("x.py", 2, "DSA101")
    assert "discarded" in v.message
    assert str(v).startswith("x.py:2:")


def test_lint_paths_walks_trees(tmp_path):
    (tmp_path / "pkg").mkdir()
    (tmp_path / "pkg" / "bad.py").write_text("def f(d, b):\n    d.submit(b)\n")
    (tmp_path / "pkg" / "ok.py").write_text("x = 1\n")
    vs = apilint.lint_paths([tmp_path])
    assert [v.code for v in vs] == ["DSA101"]


def test_select_filters_rules():
    src = "def f(d, b, r):\n    d.submit(b)\n    while not r.is_done():\n        d.kick()\n"
    assert [v.code for v in apilint.lint_source(src, select=["DSA103"])] == [
        "DSA103"]


def test_syntax_error_reported_not_raised():
    vs = apilint.lint_source("def f(:\n")
    assert vs[0].code == "DSA100"


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(d, b):\n    d.submit(b)\n")
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "dsalint.py"), str(bad)],
        capture_output=True, text=True)
    assert r.returncode == 1
    assert "DSA101" in r.stdout
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "dsalint.py"), str(good)],
        capture_output=True, text=True)
    assert r.returncode == 0


def test_repo_tree_is_clean():
    """The ratchet: the repo's own source must stay dsalint-clean."""
    paths = [ROOT / p for p in
             ("src", "tests", "benchmarks", "examples", "tools")
             if (ROOT / p).exists()]
    vs = apilint.lint_paths(paths)
    assert vs == [], "\n".join(str(v) for v in vs)
