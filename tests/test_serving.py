"""Serving: paged KV pool tier moves, reorder-array in-order commit, and the
end-to-end Vhost-style continuous batching loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_device
from repro.models.api import build_model
from repro.serving.kv_pool import PagedKVPool
from repro.serving.pipeline import ReorderArray, Request, VhostStyleServer


def test_paged_pool_swap_roundtrip(rng):
    pool = PagedKVPool(n_device_pages=8, n_host_pages=8, page_tokens=16, kv_dim=64)
    assert pool.alloc(seq_id=1, n_pages=3)
    data = [jnp.asarray(rng.normal(size=(16, 64)), jnp.bfloat16) for _ in range(3)]
    for i, d in enumerate(data):
        pool.write_page(1, i, d)
    before = np.asarray(pool.read_pages(1))
    assert pool.swap_out(1)
    assert pool.stats.device_pages_used == 0
    assert pool.swap_in(1)
    after = np.asarray(pool.read_pages(1))
    assert (before == after).all()
    assert pool.stats.batch_copies == 2 and pool.stats.pages_moved == 6
    pool.free(1)
    assert pool.stats.device_pages_used == 0 and pool.stats.host_pages_used == 0


def test_pool_capacity_limits():
    pool = PagedKVPool(n_device_pages=2, n_host_pages=1, page_tokens=8, kv_dim=32)
    assert pool.alloc(1, 2)
    assert not pool.alloc(2, 1)  # device full
    assert not pool.swap_out(1)  # host too small for 2 pages
    pool.free(1)
    assert pool.alloc(2, 1)


class _FakeRecord:
    def __init__(self):
        self.done = False

    def is_done(self):
        return self.done


def test_reorder_array_commits_in_order():
    ra = ReorderArray()
    recs = [_FakeRecord() for _ in range(4)]
    for i, r in enumerate(recs):
        ra.push(i, r, payload=i)
    recs[1].done = True
    recs[3].done = True
    assert ra.pop_completed() == []  # head incomplete -> nothing commits
    recs[0].done = True
    out = ra.pop_completed()
    assert [t for t, _ in out] == [0, 1]  # stops at 2
    recs[2].done = True
    out = ra.pop_completed()
    assert [t for t, _ in out] == [2, 3]
    assert len(ra) == 0


class _ReentrantRecord:
    """A future whose ``is_done()`` re-enters ``pop_completed`` — the shape
    of the real race: polling a completion record pumps the engine, and the
    engine's completion callback lands back in the commit path while the
    outer drain sits between its done-check and its pop."""

    def __init__(self, ra):
        self.ra = ra
        self.done = False
        self.fired = False
        self.inner_commits = []

    def is_done(self):
        if self.done and not self.fired:
            self.fired = True  # re-enter exactly once, mid-drain
            self.inner_commits.append(self.ra.pop_completed())
        return self.done


def test_reorder_array_reentrant_drain_commits_each_tag_once():
    """Regression for the double/premature-commit race: with an unguarded
    check-then-pop, the reentrant inner call pops the head the outer drain
    just checked, so the outer ``popleft`` takes the NEXT (incomplete)
    entry — head committed twice, successor committed early.  The guard
    makes the inner call a no-op ([]) and the outer drain atomic."""
    ra = ReorderArray()
    head = _ReentrantRecord(ra)
    mid, tail = _FakeRecord(), _FakeRecord()
    ra.push(0, head, payload="head")
    ra.push(1, mid, payload="mid")
    ra.push(2, tail, payload="tail")
    head.done = True
    tail.done = True  # out-of-order completion: tail done, mid not

    out = ra.pop_completed()
    assert head.inner_commits == [[]]          # reentrant call committed nothing
    assert out == [(0, "head")]                # head committed exactly once
    assert len(ra) == 2                        # mid NOT popped prematurely

    mid.done = True
    assert ra.pop_completed() == [(1, "mid"), (2, "tail")]
    assert len(ra) == 0


@pytest.mark.slow
def test_vhost_server_end_to_end(rng):
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    server = VhostStyleServer(model, params, slots=3, max_cache_len=64,
                              device=make_device(n_instances=2))
    n_req = 7
    for i in range(n_req):
        server.enqueue(Request(req_id=i,
                               prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                               max_new_tokens=4))
    steps = server.run_until_drained(max_steps=500)
    assert server.metrics["completed"] == n_req
    assert steps < 500
    assert server.metrics["decoded_tokens"] >= n_req * 3
    # in-order admission: all copy bursts went through the reorder array
    assert server.metrics["copy_bursts"] == n_req


def test_vhost_decode_consistency(rng):
    """A sequence decoded through the server matches direct greedy decode."""
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)

    # direct greedy
    cache, logits, _ = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                     max_cache_len=64)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(3):
        lg, cache = model.decode_step(params, cache, cur)
        toks.append(int(jnp.argmax(lg[0])))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)

    server = VhostStyleServer(model, params, slots=1, max_cache_len=64)
    req = Request(req_id=0, prompt=prompt, max_new_tokens=4)
    server.enqueue(req)
    server.run_until_drained(max_steps=100)
    assert req.output == toks
