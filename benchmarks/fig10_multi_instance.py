"""Paper Fig. 10: throughput with multiple engine instances.

Claims validated: linear scaling with instances until the shared memory
system limits (paper: 4 instances hit the DDR/DDIO wall at large sizes; on
TPU the shared wall is HBM bandwidth).  Measured: round-robin over N
StreamEngine instances.
"""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp

from benchmarks.common import MODEL, Row, gbps
from repro.core import make_device

HBM_BW = 819e9
SIZES = [65536, 1 << 20]
INSTANCES = [1, 2, 3, 4]


def rows() -> List[Row]:
    out: List[Row] = []
    for size in SIZES:
        for n in INSTANCES:
            per = size / MODEL.op_time(size, async_depth=32)
            agg = min(n * per, HBM_BW / 2)  # copies: rd+wr share HBM
            out.append(
                (f"fig10/model/{size}B/x{n}", 0.0,
                 f"{agg/1e9:.1f}GB/s{' (hbm-limited)' if n*per > HBM_BW/2 else ''}")
            )
    # measured: engine fan-out really goes to distinct instances
    src = jnp.zeros((256, 128), jnp.float32)
    for n in INSTANCES:
        d = make_device(n_instances=n)
        t0 = time.perf_counter()
        futs = [d.memcpy_async(src) for _ in range(8)]  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
        for f in futs:
            f.wait()
        used = sum(
            1 for e in d.engines
            if any(w.stats["submitted"] for g in e.config.groups for w in g.wqs)
        )
        out.append((f"fig10/measured/x{n}", (time.perf_counter() - t0) * 1e6,
                    f"instances_used={used}"))
    return out
