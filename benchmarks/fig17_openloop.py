"""Fig. 17 (extension of the §6 case study): open-loop sustained arrival.

The paper benchmarks the Vhost datapath under sustained packet arrival —
traffic keeps coming whether or not the server keeps up — where DSA's win
is that offload holds latency while the host would have collapsed.  This
module drives the VhostStyleServer the same way: a seeded open-loop
``TrafficGenerator`` on the virtual clock, SLO classes mapped onto the
priority WQs, and the ``AdmissionController`` shedding at watermarks /
``QueueFull`` backpressure.  The decode slot runs the NullDecoder (the null
PMD analogue) so rows measure the datapath, not model FLOPs.

Claims validated:
  * graceful overload — at 2x offered load, goodput degrades gently (stays
    within a factor of the 1x goodput) instead of collapsing toward zero;
    the excess is SHED, visibly, not silently queued into latency heat
    death (``fig17/claim/graceful_overload``);
  * SLO isolation — the latency class's p99 stays strictly below bulk's
    under overload: priority admission + the high-priority DWQ + shed-first
    bulk (``fig17/claim/slo_isolation``);
  * burstiness costs tail, not goodput — MMPP traffic at the same mean
    rate keeps throughput but fattens p99 vs Poisson.

Row value (``us_per_call``) is the latency-class p99 end-to-end latency in
VIRTUAL microseconds — deterministic enough to eyeball across runs, but
machine-load dependent at the margin, so CI gates these rows by PRESENCE
(``--require '^fig17/'``), not value.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional

from benchmarks.common import Row

#: virtual-clock step; capacity below derives from it
STEP_S = 0.02


def _make_server(sampler=None):
    from repro.serving.kv_pool import PagedKVPool
    from repro.serving.nullmodel import NullDecoder
    from repro.serving.pipeline import VhostStyleServer
    from repro.serving.slo import (
        DEFAULT_SLO_CLASSES,
        AdmissionController,
        LatencyTracker,
    )

    pool = PagedKVPool(n_device_pages=64, n_host_pages=4,
                       page_tokens=32, kv_dim=8)
    server = VhostStyleServer(
        NullDecoder(64), {}, slots=4, max_cache_len=128, kv_pool=pool,
        admission=AdmissionController(DEFAULT_SLO_CLASSES, queue_watermark=24),
        tracker=LatencyTracker(DEFAULT_SLO_CLASSES),
        observer=sampler,
    )
    return server


def _traffic(arrivals):
    from repro.serving.traffic import TrafficGenerator, ZipfLengths

    return TrafficGenerator(
        arrivals,
        prompt_lengths=ZipfLengths(s=1.2, lo=8, hi=64),
        output_lengths=ZipfLengths(s=1.2, lo=2, hi=16),
        class_mix={"latency": 0.25, "bulk": 0.75},
        seed=7,
    )


def _capacity_rps() -> float:
    """Analytic service capacity: ``slots`` requests in flight, each costing
    ~(mean output tokens + admission overhead) virtual steps."""
    from repro.serving.traffic import ZipfLengths

    mean_steps = ZipfLengths(s=1.2, lo=2, hi=16).mean() + 2.0
    return 4 / (mean_steps * STEP_S)


def _run(arrivals, horizon_s: float, label: str,
         trace_dir: Optional[str] = None) -> dict:
    server = _make_server()
    sampler = None
    if trace_dir is not None:
        from repro.obs import Sampler

        sampler = Sampler(server.device)  # manual ticks: deterministic trace
        server.observer = sampler
    report = server.run_open_loop(_traffic(arrivals), horizon_s,
                                  step_s=STEP_S, vocab_size=64)
    if sampler is not None:
        sampler.tick()
        sampler.to_csv(str(Path(trace_dir) / f"fig17_{label}.csv"))
    return report


def rows(quick: bool = False, trace_dir: Optional[str] = None) -> List[Row]:
    from repro.serving.traffic import BurstyArrivals, PoissonArrivals

    cap = _capacity_rps()
    horizon = 4.0 if quick else 10.0
    out: List[Row] = []
    reports = {}
    for x in (0.5, 1.0, 2.0):
        r = _run(PoissonArrivals(x * cap, seed=int(10 * x)), horizon,
                 f"poisson_{x:g}x", trace_dir=trace_dir)
        reports[x] = r
        lat = r["latency"]["latency"]
        bulk = r["latency"]["bulk"]
        out.append((
            f"fig17/poisson/{x:g}x",
            lat["p99_s"] * 1e6,  # latency-class virtual p99 in us
            f"offered={r['offered_rps']:.1f}rps sustained={r['sustained_rps']:.1f}rps "
            f"goodput={r['goodput_rps']:.1f}rps shed={r['shed']} "
            f"lat_p99={lat['p99_s']*1e3:.0f}ms bulk_p99={bulk['p99_s']*1e3:.0f}ms",
        ))
    if not quick:
        r = _run(BurstyArrivals(on_rps=2.0 * cap, off_rps=0.0,
                                mean_on_s=0.5, mean_off_s=0.5, seed=23),
                 horizon, "bursty_1x", trace_dir=trace_dir)
        lat = r["latency"]["latency"]
        out.append((
            "fig17/bursty/1x_mean",
            lat["p99_s"] * 1e6,
            f"offered={r['offered_rps']:.1f}rps sustained={r['sustained_rps']:.1f}rps "
            f"goodput={r['goodput_rps']:.1f}rps shed={r['shed']}",
        ))

    # -- claims -------------------------------------------------------------
    g1, g2 = reports[1.0]["goodput_rps"], reports[2.0]["goodput_rps"]
    graceful = g2 >= 0.5 * g1 and reports[2.0]["shed"] > 0
    out.append((
        "fig17/claim/graceful_overload", 0.0,
        f"goodput@2x={g2:.1f}rps vs @1x={g1:.1f}rps (>=50% kept: {graceful}) "
        f"shed@2x={reports[2.0]['shed']} in_flight=0",
    ))
    lat99 = reports[2.0]["latency"]["latency"]["p99_s"]
    bulk99 = reports[2.0]["latency"]["bulk"]["p99_s"]
    out.append((
        "fig17/claim/slo_isolation", 0.0,
        f"latency_p99={lat99*1e3:.0f}ms < bulk_p99={bulk99*1e3:.0f}ms "
        f"under 2x overload: {lat99 < bulk99}",
    ))
    if not graceful or not lat99 < bulk99:
        raise AssertionError(
            f"fig17 claims failed: graceful={graceful} "
            f"slo_isolation={lat99 < bulk99}")
    return out
