"""Paper Fig. 11: fraction of CPU cycles spent in UMWAIT (host free) while
offloading, vs transfer size and batch size.

Adaptation: host-free fraction = (t_total - t_submit_prep) / t_total — the
cycles the host can spend on other work while the engine streams.  Claims
validated: fraction -> ~1 for >=4KB transfers; batching pushes even small
transfers into the mostly-waiting regime.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row

SIZES = [256, 1024, 4096, 65536, 1 << 20]
BATCHES = [1, 8, 128]


def rows() -> List[Row]:
    out: List[Row] = []
    for size in SIZES:
        for bs in BATCHES:
            total = MODEL.op_time(size, batch_size=bs, n_pe=4)
            busy = MODEL.submit_overhead_s * bs + MODEL.completion_poll_s
            frac = max(0.0, 1.0 - busy / total)
            out.append((f"fig11/ts{size}B/bs{bs}", total * 1e6, f"umwait_frac={frac:.3f}"))
    return out
