"""Paper Fig. 11: fraction of CPU cycles the host spends parked (UMWAIT /
interrupt — free for other work) vs busy (spin/PAUSE polling) while the
engine streams, vs transfer size and in-flight depth.

Unlike the closed-form formula this module used to print, every row now
drives the REAL engine through the completion subsystem and reports the
host-free fraction from ``Telemetry`` measurements: a device is built with
the wait policy under test, ``depth`` copies are submitted, and ONE
``wait_all`` retires them while the policy accounts host-busy (pump/poll
wall time + modeled wake/IRQ costs) vs host-free (parked-in-block wall
time) cycles.

Claims validated (paper Fig. 11 + "choose your wait scheme"):
  * spin/pause never free the host (host_free_frac = 0);
  * umwait/interrupt free-cycle fraction grows with transfer size — large
    transfers park the host for most of the wait;
  * in-flight depth (the batching analogue) pushes even small transfers
    toward the mostly-parked regime, and interrupt coalescing retires many
    completions per IRQ (irqs << completions).
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import List, Optional

from benchmarks.common import Row, words_for_bytes
from repro.core import make_device
from repro.core.telemetry import Telemetry

SIZES = [4096, 65536, 1 << 20]
DEPTHS = [1, 8]
POLICIES = ["spin", "pause", "umwait", "interrupt"]

QUICK_SIZES = [65536]
QUICK_DEPTHS = [8]
QUICK_POLICIES = ["spin", "umwait", "interrupt"]


def _measure(policy: str, size: int, depth: int,
             trace_dir: Optional[str] = None) -> Row:
    device = make_device(wait_policy=policy)
    tel = Telemetry(device)
    sampler = None  # reads monotonic counters, not records — no conflict
    if trace_dir is not None:
        from repro.obs import Sampler
        sampler = Sampler(device)  # manual ticks: deterministic trace
    w = words_for_bytes(size)
    t0 = time.perf_counter()
    futs = [device.memcpy_async(w) for _ in range(depth)]
    device.wait_all(futs)
    wall = time.perf_counter() - t0
    if sampler is not None:
        sampler.tick()
        sampler.to_csv(str(Path(trace_dir) /
                           f"fig11_{policy}_ts{size}B_d{depth}.csv"))
    ws = tel.snapshot()["wait"][policy]
    return (
        f"fig11/ts{size}B/d{depth}/{policy}",
        wall / depth * 1e6,
        f"host_free_frac={ws['host_free_frac']:.3f} "
        f"polls={ws['polls']} wakes={ws['wakes']} irqs={ws['irqs']} "
        f"completions={ws['completions']}",
    )


def rows(quick: bool = False, trace_dir: Optional[str] = None) -> List[Row]:
    sizes = QUICK_SIZES if quick else SIZES
    depths = QUICK_DEPTHS if quick else DEPTHS
    policies = QUICK_POLICIES if quick else POLICIES
    # warm the jit caches per shape so compile time doesn't pollute the
    # first policy's busy/free split
    for size in sizes:
        make_device().memcpy_async(words_for_bytes(size)).wait()  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
    out: List[Row] = []
    for size in sizes:
        for depth in depths:
            for policy in policies:
                out.append(_measure(policy, size, depth, trace_dir=trace_dir))
    return out
