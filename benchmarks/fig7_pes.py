"""Paper Fig. 7: Memory Copy throughput vs number of PEs in the group,
varying transfer and batch size.

Claims validated: PEs scale small transfers (latency-bound regime); large
transfers level off because one PE already saturates HBM (G5).  The
measured part runs our memcpy kernel with n_pe grid lanes.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row, gbps, time_call, words_for_bytes
from repro.kernels import ops

SIZES = [1024, 16384, 1 << 20]
PES = [1, 2, 4]


def rows() -> List[Row]:
    out: List[Row] = []
    for size in SIZES:
        for pe in PES:
            t = MODEL.op_time(size, n_pe=pe, batch_size=8)
            out.append((f"fig7/ts{size}B/pe{pe}", t * 1e6, f"{gbps(size*8, t):.2f}GB/s"))
    small_gain = MODEL.throughput(1024, n_pe=4, batch_size=8) / MODEL.throughput(
        1024, n_pe=1, batch_size=8
    )
    big_gain = MODEL.throughput(1 << 20, n_pe=4, batch_size=8) / MODEL.throughput(
        1 << 20, n_pe=1, batch_size=8
    )
    out.append(("fig7/claim/small_ts_scales_more", 0.0,
                f"gain1KB={small_gain:.2f}x gain1MB={big_gain:.2f}x"))
    # measured: PE lanes on the real kernel
    w = words_for_bytes(1 << 20)
    for pe in PES:
        t = time_call(lambda w=w, pe=pe: ops.memcpy(w, n_pe=pe))
        out.append((f"fig7/measured/1MB/pe{pe}", t * 1e6, "interpret"))
    return out
