"""Paper Fig. 4: async Memory Copy throughput vs WQ size (in-flight depth).

Claim validated: throughput rises with queue depth until the launch
overhead is fully hidden, then saturates (paper: WQS 32 ~= max).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row, gbps

SIZES = [1024, 16384, 262144]
DEPTHS = [1, 2, 4, 8, 16, 32, 64, 128]


def rows() -> List[Row]:
    out: List[Row] = []
    for size in SIZES:
        base = None
        for d in DEPTHS:
            t = MODEL.op_time(size, async_depth=d, n_pe=4)
            bw = gbps(size, t)
            base = base or bw
            out.append((f"fig4/ts{size}B/wqs{d}", t * 1e6, f"{bw:.2f}GB/s"))
        sat = MODEL.op_time(size, async_depth=32, n_pe=4)
        sat128 = MODEL.op_time(size, async_depth=128, n_pe=4)
        out.append(
            (f"fig4/claim/ts{size}B_saturated_by_32", 0.0,
             f"ratio={sat128 / sat:.4f}")
        )
    return out
