"""§Perf hillclimb driver: lowers named variants of the three chosen cells
and records roofline terms per variant.

    PYTHONPATH=src python -m benchmarks.perf_iterations [--cell A] [--variant name]

Each variant is one hypothesis -> change -> re-lower -> re-analyse cycle;
results land in results/perf/<cell>__<variant>.json and the narrative lives
in EXPERIMENTS.md §Perf.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time
from pathlib import Path

import jax

from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import model_flops_for_cell, roofline_terms
from repro.roofline.hlo_cost import analyze_hlo
from repro.configs import SHAPES_BY_NAME, get_config

# cell -> (arch, shape)
CELLS = {
    "A": ("deepseek-67b", "train_4k"),
    "B": ("deepseek-moe-16b", "train_4k"),
    "C": ("llama4-maverick-400b-a17b", "decode_32k"),
}

# variant name -> lower_cell kwargs
VARIANTS = {
    "A": {
        "baseline": {},
        # iter 1: bf16 attention operands (preferred_element_type accumulate)
        # — applied in-code; relower to measure
        "bf16_attn": {},
        "bf16_micro16": {"micro_steps": 16},
        "bf16_micro4": {"micro_steps": 4},
        "bf16_wire_tp": {"tp_comm": "manual_bf16"},
        "bf16_rematgroup5": {"remat_group": 5},
        "bf16_rematgroup5_micro16": {"remat_group": 5, "micro_steps": 16},
        "final_zero2": {"remat_group": 5, "micro_steps": 16, "zero2": True},
        "flash_attn": {"attn_impl": "flash"},
    },
    "B": {
        "baseline": {},
        "ep_dispatch": {"moe_dispatch": "a2a"},
        "ep_flash": {"moe_dispatch": "a2a", "attn_impl": "flash"},
    },
    "C": {
        "baseline": {},
        "ep_dispatch": {"moe_dispatch": "a2a"},
        "ep_ff_tp": {
            # weights fully resident: experts over model x expert-FF over
            # data (dense-layer MLPs stay TP over model); the 1.3MB token
            # batch replicates into the MoE block
            "moe_dispatch": "a2a",
            "no_fsdp": True,
            "rules_overrides": {"expert_ff": ("data",)},
        },
        "dense_tp_ff": {
            "no_fsdp": True,
            "rules_overrides": {"expert_ff": ("data",)},
        },
    },
}


def run_variant(cell: str, variant: str, out_dir: Path) -> dict:
    arch, shape_name = CELLS[cell]
    kwargs = VARIANTS[cell][variant]
    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, **kwargs)
    compiled = lowered.compile()
    dt = time.time() - t0
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    import gzip

    out_dir.mkdir(parents=True, exist_ok=True)
    with gzip.open(out_dir / f"{cell}__{variant}.hlo.gz", "wt") as f:
        f.write(hlo)
    cost = analyze_hlo(hlo)
    terms = roofline_terms(cost.flops, cost.bytes, cost.coll_bytes)
    mf = model_flops_for_cell(cfg, shape, shape.kind)
    rec = {
        "cell": cell, "arch": arch, "shape": shape_name, "variant": variant,
        "kwargs": {k: str(v) for k, v in kwargs.items()},
        "compile_s": round(dt, 1),
        "flops_per_dev": cost.flops,
        "bytes_per_dev": cost.bytes,
        "collective_bytes_per_dev": cost.coll_bytes,
        "collective_ops": {k: dict(v) for k, v in cost.coll_ops.items()},
        "useful_flops_ratio": round(mf / (cost.flops * 256), 4) if cost.flops else 0,
        "hbm_per_dev_gb": round(
            ((ma.argument_size_in_bytes or 0) + (ma.temp_size_in_bytes or 0)) / 1e9, 2
        ),
        **terms,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}__{variant}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    out = Path(args.out)
    for cell, variants in VARIANTS.items():
        if args.cell and cell != args.cell:
            continue
        for v in variants:
            if args.variant and v != args.variant:
                continue
            try:
                r = run_variant(cell, v, out)
                print(
                    f"[{cell}/{v}] compute={r['compute_s']:.2f}s memory={r['memory_s']:.2f}s "
                    f"collective={r['collective_s']:.2f}s bottleneck={r['bottleneck']} "
                    f"hbm={r['hbm_per_dev_gb']}GB useful={r['useful_flops_ratio']}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                print(f"[{cell}/{v}] ERROR {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
