"""Paper Fig. 9: WQ configurations — one DWQ with batching (BS:N) vs N DWQs
(one thread each) vs one SWQ with N submitters.

Claims validated (G6): batching-to-one-DWQ ~= multi-DWQ; SWQ trails at small
sizes because of the non-posted ENQCMD round trip (modeled as per-submit
overhead x contention), and catches up when many threads keep it full.
Measured: our engine runs all three topologies for real.
"""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp

from benchmarks.common import MODEL, Row, gbps
from repro.core import DeviceConfig, OpType, Status, StreamEngine, WorkDescriptor
from repro.core.descriptor import BatchDescriptor

N = 4
SIZE = 16384  # 16KB descriptors


def _modeled() -> List[Row]:
    out = []
    for size in (1024, 8192, 65536):
        # a batch to ONE DWQ still dispatches to every free PE in the group
        # (paper: "a descriptor at the head of a WQ is eligible for any free
        # PE") — hence batch-to-one-DWQ ~= N DWQs, as Fig. 9 shows.
        t_batch = MODEL.op_time(size, batch_size=N, async_depth=8, n_pe=min(N, 4))
        t_multi = MODEL.op_time(size, batch_size=N, async_depth=8, n_pe=min(N, 4))
        # SWQ: ENQCMD round trip ~3x submit cost at low thread counts
        t_swq = t_batch + 3 * MODEL.submit_overhead_s * N
        out.append((f"fig9/model/dwq_batch/{size}B", t_batch * 1e6, f"{gbps(size*N, t_batch):.1f}GB/s"))
        out.append((f"fig9/model/multi_dwq/{size}B", t_multi * 1e6, f"{gbps(size*N, t_multi):.1f}GB/s"))
        out.append((f"fig9/model/swq/{size}B", t_swq * 1e6, f"{gbps(size*N, t_swq):.1f}GB/s"))
    return out


def _measured() -> List[Row]:
    src = jnp.zeros((SIZE // 512, 128), jnp.float32)
    out = []

    # (1) one DWQ, batch of N (run twice; report the warm pass)
    eng = StreamEngine(DeviceConfig.default(wqs_per_group=1, pes_per_group=4))
    for rep in range(2):
        t0 = time.perf_counter()
        b = BatchDescriptor([WorkDescriptor(op=OpType.MEMCPY, src=src) for _ in range(N)])
        eng.submit(b)
        eng.drain()
        dt = time.perf_counter() - t0
    out.append((f"fig9/measured/dwq_batch", dt * 1e6, "interpret,warm"))

    # (2) N DWQs, one descriptor each
    eng = StreamEngine(DeviceConfig.default(wqs_per_group=N, pes_per_group=4))
    for rep in range(2):
        t0 = time.perf_counter()
        for i in range(N):
            eng.submit(WorkDescriptor(op=OpType.MEMCPY, src=src), wq=i)
        eng.drain()
        dt = time.perf_counter() - t0
    out.append((f"fig9/measured/multi_dwq", dt * 1e6, "interpret,warm"))

    # (3) one SWQ (1 PE so the queue actually backs up), N submitters w/ retry
    eng = StreamEngine(DeviceConfig.default(wqs_per_group=1, pes_per_group=1,
                                            wq_mode="shared", wq_size=2))
    t0 = time.perf_counter()
    for i in range(2 * N):
        st, _ = eng.submit(WorkDescriptor(op=OpType.MEMCPY, src=src))
        tries = 0
        while st == Status.RETRY and tries < 100:
            eng.kick()
            st, _ = eng.submit(WorkDescriptor(op=OpType.MEMCPY, src=src))
            tries += 1
    eng.drain()
    retries = eng.wq(0, 0).stats["retried"]
    out.append((f"fig9/measured/swq", (time.perf_counter() - t0) * 1e6, f"retries={retries}"))
    return out


def rows() -> List[Row]:
    return _modeled() + _measured()
