"""Paper Fig. 9: WQ configurations — one DWQ with batching (BS:N) vs N DWQs
(one thread each) vs one SWQ with N submitters, plus the WQConfig QoS sweep
(priority partition and ENQCMD vs MOVDIR64B submission cost).

Claims validated (G6): batching-to-one-DWQ ~= multi-DWQ; SWQ trails at small
sizes because of the non-posted ENQCMD round trip (modeled as per-submit
overhead x contention), and catches up when many threads keep it full.
QoS: under contention a dedicated WQ outperforms a shared one (the engine
charges the ENQCMD round trip per shared submission and the SWQ retries),
and a higher-priority WQ sees lower queueing delay under the group
arbiter's priority-weighted draining.
Measured: our engine runs all topologies for real via make_device(wq_configs).
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import List, Optional

import jax.numpy as jnp

from benchmarks.common import MODEL, Row, gbps
from repro.core import (
    DeviceConfig,
    OpType,
    Status,
    StreamEngine,
    WorkDescriptor,
    WQConfig,
    make_device,
)
from repro.core.descriptor import BatchDescriptor

N = 4
SIZE = 16384  # 16KB descriptors


def _modeled() -> List[Row]:
    out = []
    for size in (1024, 8192, 65536):
        # a batch to ONE DWQ still dispatches to every free PE in the group
        # (paper: "a descriptor at the head of a WQ is eligible for any free
        # PE") — hence batch-to-one-DWQ ~= N DWQs, as Fig. 9 shows.
        t_batch = MODEL.op_time(size, batch_size=N, async_depth=8, n_pe=min(N, 4))
        t_multi = MODEL.op_time(size, batch_size=N, async_depth=8, n_pe=min(N, 4))
        # SWQ: ENQCMD round trip ~3x submit cost at low thread counts
        t_swq = t_batch + N * MODEL.enqcmd_overhead_s
        out.append((f"fig9/model/dwq_batch/{size}B", t_batch * 1e6, f"{gbps(size*N, t_batch):.1f}GB/s"))
        out.append((f"fig9/model/multi_dwq/{size}B", t_multi * 1e6, f"{gbps(size*N, t_multi):.1f}GB/s"))
        out.append((f"fig9/model/swq/{size}B", t_swq * 1e6, f"{gbps(size*N, t_swq):.1f}GB/s"))
    return out


def _measured() -> List[Row]:
    src = jnp.zeros((SIZE // 512, 128), jnp.float32)
    out = []

    # (1) one DWQ, batch of N (run twice; report the warm pass)
    eng = StreamEngine(DeviceConfig.default(wqs_per_group=1, pes_per_group=4))
    for rep in range(2):
        t0 = time.perf_counter()
        b = BatchDescriptor([WorkDescriptor(op=OpType.MEMCPY, src=src) for _ in range(N)])
        eng.submit(b)  # dsalint: disable=DSA101,DSA106 — engine submit returns (Status, rec); drain() below retires it
        eng.drain()
        dt = time.perf_counter() - t0
    out.append((f"fig9/measured/dwq_batch", dt * 1e6, "interpret,warm"))

    # (2) N DWQs, one descriptor each
    eng = StreamEngine(DeviceConfig.default(wqs_per_group=N, pes_per_group=4))
    for rep in range(2):
        t0 = time.perf_counter()
        for i in range(N):
            eng.submit(WorkDescriptor(op=OpType.MEMCPY, src=src), wq=i)  # dsalint: disable=DSA101,DSA106 — drain() below retires
        eng.drain()
        dt = time.perf_counter() - t0
    out.append((f"fig9/measured/multi_dwq", dt * 1e6, "interpret,warm"))

    # (3) one SWQ (1 PE so the queue actually backs up), N submitters w/ retry
    eng = StreamEngine(DeviceConfig.default(wqs_per_group=1, pes_per_group=1,
                                            wq_mode="shared", wq_size=2))
    t0 = time.perf_counter()
    for i in range(2 * N):
        st, _ = eng.submit(WorkDescriptor(op=OpType.MEMCPY, src=src))  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
        tries = 0
        while st == Status.RETRY and tries < 100:  # dsalint: disable=DSA103 — models raw ENQCMD retry deliberately
            eng.kick()
            st, _ = eng.submit(WorkDescriptor(op=OpType.MEMCPY, src=src))  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
            tries += 1
    eng.drain()
    retries = eng.wq(0, 0).stats["retried"]
    out.append((f"fig9/measured/swq", (time.perf_counter() - t0) * 1e6, f"retries={retries}"))
    return out


def _qos_dedicated_vs_shared() -> List[Row]:
    """Same offered load through a dedicated vs a shared WQ (WQConfig knob).
    The shared queue pays the non-posted ENQCMD round trip per descriptor in
    the modeled completion time — dedicated wins under contention."""
    src = jnp.zeros((SIZE // 512, 128), jnp.float32)
    out = []
    modeled = {}
    for mode in ("dedicated", "shared"):
        dev = make_device(wq_configs=[WQConfig("wq", mode=mode, size=32, priority=8)])
        futs = [dev.memcpy_async(src, wq="wq") for _ in range(2 * N)]  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
        dev.drain()
        total_us = sum(f.record.modeled_time_us for f in futs)
        modeled[mode] = total_us
        nbytes = 2 * N * SIZE
        out.append((f"fig9/qos/{mode}", total_us,
                    f"{gbps(nbytes, total_us * 1e-6):.1f}GB/s modeled"))
    out.append(("fig9/qos/dwq_vs_swq", 0.0,
                f"dedicated {modeled['shared'] / modeled['dedicated']:.2f}x "
                f"faster modeled (ENQCMD round trip)"))
    return out


def _qos_priority_sweep(trace_dir: Optional[str] = None) -> List[Row]:
    """Two WQs on one group, equal backlog, 1 PE: the higher-priority WQ is
    drained preferentially, so its descriptors see lower queueing delay."""
    src = jnp.zeros((SIZE // 512, 128), jnp.float32)
    out = []
    for hi_pri in (4, 8, 15):
        dev = make_device(wq_configs=[
            WQConfig("hi", size=32, priority=hi_pri),
            WQConfig("lo", size=32, priority=1),
        ], pes_per_group=1)
        sampler = None
        if trace_dir is not None:
            from repro.obs import Sampler
            sampler = Sampler(dev)  # manual ticks: deterministic trace
        dev.memcpy_async(src).wait()  # warm the jit cache off the clock  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
        # backlog both queues before any dispatch: park behind a promise so
        # the arbiter sees both WQs full when the fence releases
        gate = dev.promise()
        futs = [dev.memcpy_async(src, wq=w, after=[gate])  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
                for _ in range(8) for w in ("hi", "lo")]
        gate.set_result()
        dev.drain()
        if sampler is not None:
            sampler.tick()
            sampler.to_csv(str(Path(trace_dir) /
                               f"fig9_priority{hi_pri}_vs_1.csv"))
        assert all(f.status == Status.SUCCESS for f in futs)
        by_wq = {"hi": [], "lo": []}
        for f in futs:  # per-future attribution excludes the warmup copy
            by_wq[f.wq].append(f.queue_delay_us)
        d_hi = sum(by_wq["hi"]) / len(by_wq["hi"])
        d_lo = sum(by_wq["lo"]) / len(by_wq["lo"])
        out.append((f"fig9/qos/priority{hi_pri}_vs_1", 0.0,
                    f"qdelay hi={d_hi:.0f}us lo={d_lo:.0f}us "
                    f"({d_lo / max(d_hi, 1e-9):.1f}x)"))
    return out


def rows(trace_dir: Optional[str] = None) -> List[Row]:
    return (_modeled() + _measured() + _qos_dedicated_vs_shared()
            + _qos_priority_sweep(trace_dir=trace_dir))
