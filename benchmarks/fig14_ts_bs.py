"""Paper Fig. 14 (G1): equal total transfer, trading transfer size against
batch size.

Claims validated: for a fixed total, fewer/larger descriptors win
(per-descriptor overhead); modest batching (4-8) is the sync sweet spot
when the data is already chunked.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row, gbps

TOTALS = [65536, 1 << 20, 16 << 20]


def rows() -> List[Row]:
    out: List[Row] = []
    for total in TOTALS:
        best = None
        for bs in (1, 2, 4, 8, 16, 64, 256):
            ts = total // bs
            if ts < 256:
                continue
            for mode, depth in (("sync", 1), ("async", 32)):
                t = MODEL.op_time(ts, batch_size=bs, async_depth=depth, n_pe=4)
                bw = gbps(total, t)
                out.append((f"fig14/{mode}/total{total>>10}KB/ts{ts}:bs{bs}",
                            t * 1e6, f"{bw:.2f}GB/s"))
                if mode == "sync" and (best is None or bw > best[1]):
                    best = (bs, bw)
        out.append((f"fig14/claim/total{total>>10}KB_best_sync_bs", 0.0,
                    f"bs={best[0]} ({best[1]:.2f}GB/s)"))
    return out
