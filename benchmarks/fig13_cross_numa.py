"""Paper Fig. 13 / §4: cross-NUMA placement of engine, source, and
destination.

The paper's cross-socket sweep shows throughput collapsing whenever any leg
of the transfer leaves the socket: remote source or remote destination caps
at the UPI link, and an engine remote from both buffers is worst (two
crossings).  The resulting guideline — keep the accelerator and BOTH
buffers NUMA-local — is what `Topology` + the `numa_local` policy encode.

Claims validated:
  (a) model: every cross-node placement is strictly slower than all-local,
      with the gap widening at large transfers (bandwidth-capped) and
      remote-engine (2 hops) the worst — the paper's Fig. 13 shape;
  (b) measured: a 2-node fabric serving run + NUMA-sharded `PagedKVPool`
      completes with per-node telemetry attributing both local and
      cross-node bytes, and the modeled link occupancy is nonzero.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import MODEL, Row, gbps
from repro.core import Topology, make_device
from repro.core.telemetry import Telemetry
from repro.serving.kv_pool import PagedKVPool

#: (name, engine_node, src_node, dst_node) — the paper's placement sweep
PLACEMENTS = [
    ("local", 0, 0, 0),
    ("remote_src", 0, 1, 0),
    ("remote_dst", 0, 0, 1),
    ("remote_engine", 1, 0, 0),  # both buffers foreign: 2 link crossings
]
SIZES = [4096, 65536, 1 << 20, 16 << 20]
QUICK_SIZES = [65536, 4 << 20]


def _model_rows(sizes) -> List[Row]:
    topo = Topology.symmetric(2, engines_per_node=2)
    out: List[Row] = []
    worst_ratio = 1.0
    for size in sizes:
        t_local = None
        for name, e, s, d in PLACEMENTS:
            t = MODEL.op_time(size, n_pe=4, async_depth=8,
                              **topo.link_charge(e, s, d))
            if t_local is None:
                t_local = t
            ratio = t / t_local
            worst_ratio = max(worst_ratio, ratio)
            out.append((f"fig13/model/{name}/{size}B", t * 1e6,
                        f"{gbps(size, t):.1f}GB/s x{ratio:.2f}_vs_local"))
    # claim (a): at the LARGEST size every remote placement is strictly
    # slower, and 2-hop remote_engine is the slowest of all
    big = sizes[-1]
    ts = {name: MODEL.op_time(big, n_pe=4, async_depth=8,
                              **topo.link_charge(e, s, d))
          for name, e, s, d in PLACEMENTS}
    strictly_slower = all(ts[n] > ts["local"] for n in ts if n != "local")
    out.append(("fig13/claim/cross_strictly_slower", 0.0,
                f"all_remote>{ts['local']*1e6:.0f}us={strictly_slower} "
                f"worst=remote_engine={ts['remote_engine'] == max(ts.values())} "
                f"x{worst_ratio:.2f}_max"))
    return out


def _e2e_rows(quick: bool, trace_dir: Optional[str] = None) -> List[Row]:
    """Measured: one 2-node fabric shared by the serving pipeline (requests
    admitted to their home node's engine group) and a NUMA-sharded KV pool
    whose swaps cross from the node-0 host tier to node-1 shards."""
    from repro.configs import get_config
    from repro.models.api import build_model
    from repro.serving.pipeline import Request, VhostStyleServer

    topo = Topology.symmetric(2, engines_per_node=1)
    device = make_device(topology=topo, policy="numa_local")
    # telemetry opens BEFORE the measured work so link occupancy is
    # normalized over the window that actually carried the traffic
    telemetry = Telemetry(device)
    sampler = None
    if trace_dir is not None:
        # live time series of the run: per-node traffic + per-stage serving
        # gauges in one trace (the sampler reads monotonic counters, so it
        # coexists with the record-walking Telemetry above)
        sampler = device.observe(interval_s=0.05)
    out: List[Row] = []

    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    server = VhostStyleServer(model, params, slots=2, max_cache_len=64,
                              device=device, observer=sampler)
    rng = np.random.default_rng(0)
    n_req = 3 if quick else 6
    for i in range(n_req):
        server.enqueue(Request(req_id=i,
                               prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                               max_new_tokens=3))
    t0 = time.perf_counter()
    server.run_until_drained(max_steps=1000)
    dt = time.perf_counter() - t0
    out.append(("fig13/e2e/serving_2node", dt * 1e6,
                f"completed={server.metrics['completed']} "
                f"by_node={dict(server.metrics['admitted_by_node'])}"))

    pool = PagedKVPool(n_device_pages=8, n_host_pages=8, page_tokens=16,
                       kv_dim=64, device=device)
    pool.alloc(0, 4)  # greedy: lands whole on the freest node's shard
    for p in range(4):
        pool.write_page(0, p, jnp.ones((16, 64)) * (p + 1))
    pool.swap_out(0)           # per-node batch descriptors -> node-0 host tier
    pool.swap_in(0, node=1)    # force the cross-node leg (host@0 -> shard@1)
    out.append(("fig13/e2e/pool_swaps", 0.0,
                f"pages_moved={pool.stats.pages_moved} "
                f"batch_copies={pool.stats.batch_copies} "
                f"cross_node_swaps={pool.stats.cross_node_swaps}"))

    device.drain()
    if sampler is not None:
        sampler.stop()
        sampler.to_csv(str(Path(trace_dir) / "fig13_e2e.csv"))
    nodes = telemetry.snapshot()["nodes"]
    local_b = sum(n["local_bytes"] for n in nodes.values())
    cross_b = sum(n["cross_bytes"] for n in nodes.values())
    link_occ = max(n["link_occupancy"] for n in nodes.values())
    out.append(("fig13/e2e/node_traffic", 0.0,
                f"local={local_b}B cross={cross_b}B link_occ={link_occ:.2%}"))
    out.append(("fig13/claim/fabric_attribution", 0.0,
                f"local_bytes>0={local_b > 0} cross_bytes>0={cross_b > 0}"))
    return out


def rows(quick: bool = False, trace_dir: Optional[str] = None) -> List[Row]:
    out = _model_rows(QUICK_SIZES if quick else SIZES)
    out.extend(_e2e_rows(quick, trace_dir=trace_dir))
    return out
