"""Paper Fig. 16 (case study): DPDK-Vhost-style serving with engine offload.

Measured end-to-end on our VhostStyleServer (3-stage async pipeline + batch
descriptors + reorder array) against a SYNCHRONOUS offload variant (submit
and wait inline — the naive memcpy()->DSA substitution the paper warns
about).  Claims validated: async pipeline sustains higher request/token
throughput; in-order delivery is preserved (reorder array drains to zero).
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core import make_device
from repro.serving.pipeline import Request, VhostStyleServer


def _run(async_pipeline: bool, n_req: int = 6) -> dict:
    cfg = get_config("tinyllama-1.1b").reduced()
    from repro.models.api import build_model

    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    server = VhostStyleServer(model, params, slots=3, max_cache_len=64,
                              device=make_device(n_instances=2))
    rng = np.random.default_rng(0)
    for i in range(n_req):
        server.enqueue(Request(req_id=i,
                               prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                               max_new_tokens=4))
    t0 = time.perf_counter()
    if async_pipeline:
        steps = server.run_until_drained(max_steps=1000)
    else:
        # sync variant: wait for every copy burst before anything else runs
        steps = 0
        while server.queue or server.active or len(server.reorder):
            server._stage_submit_copies()
            server.device.drain()
            server._stage_poll_commit()
            server._stage_decode()
            steps += 1
            if steps > 1000:
                break
    dt = time.perf_counter() - t0
    m = dict(server.metrics)
    m["wall_s"] = dt
    m["steps"] = steps
    m["reorder_drained"] = len(server.reorder) == 0
    return m


def rows() -> List[Row]:
    out: List[Row] = []
    a = _run(async_pipeline=True)
    s = _run(async_pipeline=False)
    out.append(("fig16/async_pipeline", a["wall_s"] * 1e6,
                f"tok/s={a['decoded_tokens']/a['wall_s']:.2f} steps={a['steps']}"))
    out.append(("fig16/sync_offload", s["wall_s"] * 1e6,
                f"tok/s={s['decoded_tokens']/s['wall_s']:.2f} steps={s['steps']}"))
    out.append(("fig16/claim/in_order_delivery", 0.0,
                f"async_drained={a['reorder_drained']} sync_drained={s['reorder_drained']}"))
    # On this CPU host both variants serialize (interpret-mode python drives
    # everything), so the overlap benefit is reported from the model: the
    # async pipeline hides copy time under decode, sync adds them (paper
    # Fig 16: 1.14-2.29x).  t_copy from the engine model at a 32x64B burst;
    # t_decode nominal one batched decode step on v5e (~2ms).
    from benchmarks.common import MODEL

    t_copy = MODEL.op_time(64 * 4, batch_size=32, n_pe=4)
    t_decode = 2e-3
    overlap = (t_copy + t_decode) / max(t_copy, t_decode)
    out.append(("fig16/claim/modeled_overlap_speedup", 0.0,
                f"{overlap:.3f}x (copy fully hidden under decode)"))
    return out
