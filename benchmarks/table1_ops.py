"""Paper Table 1: coverage benchmark — every supported streaming operation
measured (interpret mode) + modeled at 1MB, via the engine path.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MODEL, Row, time_call, words_for_bytes
from repro.kernels import dif, ops

SIZE = 1 << 20  # 1MB


def rows() -> List[Row]:
    out: List[Row] = []
    w = words_for_bytes(SIZE)
    w2 = w.at[123].add(1)
    pat = jnp.asarray([0xDEADBEEF, 0x12345678], jnp.uint32)
    off, data, _, _ = ops.delta_create(w2, w, cap=256)
    pool = w.reshape(-1, 8, 128)[:16]

    cases = [
        ("memcpy", lambda: ops.memcpy(w), 1.0),
        ("dualcast", lambda: ops.dualcast(w), 1.5),
        ("fill", lambda: ops.fill(pat, SIZE // 4), 0.5),
        ("compare", lambda: ops.compare(w, w2), 1.0),
        ("compare_pattern", lambda: ops.compare_pattern(w, pat), 0.5),
        ("crc32", lambda: ops.crc32(w), 0.5),
        ("delta_create", lambda: ops.delta_create(w2, w, cap=256), 1.0),
        ("delta_apply", lambda: ops.delta_apply(w, off, data, use_kernel=False), 1.0),
        ("dif_insert", lambda: dif.dif_insert(w), 1.0),
        ("dif_check", lambda: dif.dif_check(dif.dif_insert(w)), 0.5),
        ("dif_strip", lambda: dif.dif_strip(dif.dif_insert(w)), 1.0),
        ("batch_copy_x16", lambda: ops.batch_copy(
            pool, jnp.zeros_like(pool), jnp.arange(16, dtype=jnp.int32),
            jnp.arange(16, dtype=jnp.int32)), 1.0),
        # fused pairs: one launch where the unfused pair takes two
        ("copy_crc", lambda: ops.copy_crc(w), 1.0),
        ("fill_verify", lambda: ops.fill_verify(pat, SIZE // 4), 0.5),
    ]
    for name, fn, rf in cases:
        t = time_call(fn, iters=3, warmup=1)
        t_model = MODEL.op_time(SIZE, read_factor=rf, async_depth=32)
        out.append((f"table1/{name}", t * 1e6,
                    f"modeled_tpu={SIZE/t_model/1e9:.1f}GB/s"))
    return out
