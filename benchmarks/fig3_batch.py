"""Paper Fig. 3: Memory Copy throughput, sync vs async, varying transfer
size x batch size.

Claims validated: batching raises small-transfer throughput superlinearly
in the sync regime; async streaming at depth ~32 reaches peak without
batching (BS:1); everything saturates at the copy roofline.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row, gbps
from repro.kernels import ops
import jax.numpy as jnp

SIZES = [256, 4096, 65536, 1 << 20]
BATCHES = [1, 4, 16, 64, 128]


def rows() -> List[Row]:
    out: List[Row] = []
    for size in SIZES:
        for bs in BATCHES:
            for mode, depth in (("sync", 1), ("async", 32)):
                t = MODEL.op_time(size, batch_size=bs, async_depth=depth, n_pe=4)
                out.append(
                    (
                        f"fig3/{mode}/ts{size}B/bs{bs}",
                        t * 1e6,
                        f"{gbps(size * bs, t):.2f}GB/s",
                    )
                )
    # peak check: async BS1 at 1MB reaches >90% of copy roofline
    t = MODEL.op_time(1 << 20, async_depth=32, n_pe=4)
    frac = ((1 << 20) / t) / MODEL.pe_peak_bw
    out.append(("fig3/claim/async_bs1_peak_fraction", t * 1e6, f"{frac:.3f}"))
    return out
