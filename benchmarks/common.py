"""Shared benchmark plumbing.

Every benchmark reports BOTH:
  * measured — wall time of our actual kernels/engine (interpret mode on this
    CPU host; compiled on a real TPU), and
  * modeled  — the perfmodel projection for TPU v5e (DESIGN.md §5), which is
    what maps onto the paper's absolute numbers.

Output rows: (name, us_per_call, derived) — derived is GB/s or a ratio.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import DEFAULT_MODEL, EngineModel

Row = Tuple[str, float, str]

MODEL: EngineModel = DEFAULT_MODEL


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def words_for_bytes(nbytes: int, seed: int = 0) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, max(nbytes // 4, 1), dtype=np.uint32))


def gbps(nbytes: float, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def fmt_gbps(nbytes: float, seconds: float) -> str:
    return f"{gbps(nbytes, seconds):.2f}GB/s"
