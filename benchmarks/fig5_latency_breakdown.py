"""Paper Fig. 5: breakdown of offload latency into allocate / prepare /
submit / wait, vs batch size (transfer size 4KB).

Measured on OUR engine: descriptor allocation (python object), preparation
(field assignment), submission (queue + arbiter dispatch), and wait
(completion record).  Claims validated: allocation dominates and is
amortizable (pre-allocation); prepare is negligible; larger batches spend
relatively more time in wait (= engine busy, host free).
"""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import Device, OpType, WorkDescriptor
from repro.core.descriptor import BatchDescriptor

BATCHES = [1, 4, 16, 64]


def rows() -> List[Row]:
    out: List[Row] = []
    s = Device()
    src = jnp.zeros((8, 128), jnp.float32)  # 4KB
    for bs in BATCHES:
        t0 = time.perf_counter()
        descs = [WorkDescriptor(op=OpType.MEMCPY, src=src) for _ in range(bs)]
        t_alloc = time.perf_counter() - t0

        t0 = time.perf_counter()
        for d in descs:
            d.priority = 0  # field assignment = preparation
        batch = BatchDescriptor(descriptors=descs) if bs > 1 else descs[0]
        t_prep = time.perf_counter() - t0

        t0 = time.perf_counter()
        fut = s.submit(batch)
        t_submit = time.perf_counter() - t0

        t0 = time.perf_counter()
        fut.wait()
        t_wait = time.perf_counter() - t0

        total = t_alloc + t_prep + t_submit + t_wait
        out.append((f"fig5/bs{bs}/allocate", t_alloc * 1e6, f"{t_alloc/total:.2%}"))
        out.append((f"fig5/bs{bs}/prepare", t_prep * 1e6, f"{t_prep/total:.2%}"))
        out.append((f"fig5/bs{bs}/submit", t_submit * 1e6, f"{t_submit/total:.2%}"))
        out.append((f"fig5/bs{bs}/wait", t_wait * 1e6, f"{t_wait/total:.2%}"))
    return out
