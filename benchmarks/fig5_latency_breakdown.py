"""Paper Fig. 5: breakdown of offload latency into allocate / prepare /
submit / wait, vs batch size (transfer size 4KB).

Measured from REAL descriptor-lifecycle spans (repro.obs.trace): the
device runs with ``trace=1.0`` and each stage is read off the submitted
batch's span marks instead of stopwatch brackets around the call sites —
the breakdown is now the same data path ``tools/trace_view.py`` and the
Perfetto export show.  Stage mapping:

  allocate  descriptor construction -> end of allocation (the benchmark
            stamps the boundary; the trace's ``create`` mark is the
            dataclass construction time itself)
  prepare   field assignment + batch wrap -> Device.submit entry
            (``submit_enter`` mark)
  submit    submit entry -> WQ accept (``accept`` mark: validation +
            policy selection + enqueue — the ENQCMD/MOVDIR64B analogue)
  wait      accept -> host observes completion (``observed`` mark:
            wq_wait + engine_dispatch + pe_exec + completion_write +
            host_wait)

Claims validated: allocation dominates and is amortizable
(pre-allocation); prepare is negligible; larger batches spend relatively
more time in wait (= engine busy, host free).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import Device, OpType, WorkDescriptor
from repro.core.descriptor import BatchDescriptor

BATCHES = [1, 4, 16, 64]
STAGES = ("allocate", "prepare", "submit", "wait")


def _stage_seconds(device: Device, bs: int) -> Dict[str, float]:
    """One traced submit; stage seconds from the batch's span marks."""
    src = jnp.zeros((8, 128), jnp.float32)  # 4KB
    descs = [WorkDescriptor(op=OpType.MEMCPY, src=src) for _ in range(bs)]
    t_alloc_end = time.perf_counter()

    for d in descs:
        d.priority = 0  # field assignment = preparation
    batch = BatchDescriptor(descriptors=descs) if bs > 1 else descs[0]

    fut = device.submit(batch)
    fut.wait()

    marks = fut.trace.clean_marks()
    # "create" is the first member's construction time (BatchDescriptor
    # traces start at min(member created_t))
    return {
        "allocate": max(t_alloc_end - marks["create"], 0.0),
        "prepare": max(marks["submit_enter"] - t_alloc_end, 0.0),
        "submit": max(marks["accept"] - marks["submit_enter"], 0.0),
        "wait": max(marks["observed"] - marks["accept"], 0.0),
    }


def rows(quick: bool = False) -> List[Row]:
    iters = 3 if quick else 7
    out: List[Row] = []
    device = Device(trace=1.0)
    for bs in BATCHES:
        samples = [_stage_seconds(device, bs) for _ in range(iters)]
        med = {s: float(np.median([x[s] for x in samples])) for s in STAGES}
        total = sum(med.values()) or 1e-12
        for stage in STAGES:
            out.append((f"fig5/bs{bs}/{stage}", med[stage] * 1e6,
                        f"{med[stage] / total:.2%}"))
    device.drain()
    return out
