"""Paper Figs. 12-13: cache steering of completions/destinations and the
pollution it causes for co-running latency-sensitive work.

TPU adaptation (G3): DSA's cache-control flag maps to destination memory-
space steering — a WQ provisioned with ``traffic_class="to_cache"``
(WQConfig) steers destination writes to the VMEM/LLC tier, the DDIO
analogue; ``to_memory`` writes around the cache.  There is no shared LLC
between "cores" on a TPU chip, so the contention model is the VMEM/HBM
analogue: a co-running software copy consumes vector-unit issue slots AND
evicts VMEM-resident tiles, inflating the latency-sensitive kernel's
effective memory time; an engine (DMA) copy steered to memory consumes only
HBM bandwidth.

Model: latency-sensitive kernel with working set W against co-running copy
traffic C: sw-copy contention evicts min(W, C)/W of the working set to HBM;
engine-copy only shares HBM bandwidth.  Claims validated: the paper's 43%
latency inflation at 4MB working set with software copies, and ~none with
offload; and Fig. 12's two-sided steering result — to_cache completions are
faster for the consumer while the steered stream fits the LLC share, but
a stream larger than that share pollutes like a software copy.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import WQConfig, make_device

VMEM = 128 * 2**20 / 16  # per-core VMEM share analogue (8MB)
HBM_LAT = 1.0  # normalized HBM access cost
CACHE_LAT = 0.25  # VMEM-resident access cost (~4x latency gap)
COPY_BW_SHARE = 0.25  # fraction of HBM bw the background copies consume
EVICT_FRAC = 0.13  # cache fraction thrashed by co-running software copies
#  (calibrated so the 4MB working set inflates ~43%, matching paper Fig. 13)

WORKING_SETS = [1 << 20, 4 << 20, 16 << 20, 64 << 20]

#: steered stream sizes for the Fig. 12 sweep (fits LLC share ... 4x over)
STEERED_STREAMS = [1 << 20, 4 << 20, 8 << 20, 32 << 20]


def _latency(working_set: int, copies: str, steered_bytes: int = 0) -> float:
    fit = min(1.0, VMEM / working_set)
    if copies == "software":
        evict = min(1.0, (8 << 20) / working_set) * EVICT_FRAC
        fit = fit * (1 - evict)
    if copies == "engine_to_cache" and steered_bytes > VMEM:
        # an engine stream steered to cache beyond the LLC share evicts the
        # working set just like a software copy would (Fig. 12 downside)
        spill = min(1.0, (steered_bytes - VMEM) / steered_bytes)
        evict = min(1.0, (8 << 20) / working_set) * EVICT_FRAC * spill
        fit = fit * (1 - evict)
    base = fit * CACHE_LAT + (1 - fit) * HBM_LAT
    if copies != "none":
        base = base * (1 + COPY_BW_SHARE * (1 - fit))  # HBM sharing
    return base


def _steering_rows() -> List[Row]:
    """Run the same copy through a to_cache WQ and a to_memory WQ (WQConfig
    traffic classes) and report the consumer-side modeled time: steering to
    cache skips the HBM round trip for the consumer (faster) and the record
    carries the steering target the telemetry attributes pollution to."""
    dev = make_device(wq_configs=[
        WQConfig("steer_cache", traffic_class="to_cache", size=32, priority=8),
        WQConfig("steer_mem", traffic_class="to_memory", size=32, priority=8),
    ])
    out: List[Row] = []
    for kb in (64, 1024):
        src = jnp.zeros((kb * 2, 128), jnp.float32)  # kb KiB
        t = {}
        for wq in ("steer_cache", "steer_mem"):
            fut = dev.memcpy_async(src, wq=wq)  # dsalint: disable=DSA106 — per-descriptor pattern is what this figure measures
            fut.wait()
            assert fut.steering == ("to_cache" if wq == "steer_cache" else "to_memory")
            t[wq] = fut.record.modeled_time_us
            out.append((f"fig12/steer/{wq}/{kb}KB", fut.record.modeled_time_us,
                        f"steered={fut.steering}"))
        out.append((f"fig12/steer/benefit/{kb}KB", 0.0,
                    f"to_cache {t['steer_mem'] / max(t['steer_cache'], 1e-9):.2f}x "
                    f"faster for consumer"))
    return out


def _pollution_rows() -> List[Row]:
    out: List[Row] = []
    for ws in WORKING_SETS:
        l_none = _latency(ws, "none")
        l_sw = _latency(ws, "software")
        l_eng = _latency(ws, "engine")
        out.append((f"fig13/ws{ws>>20}MB/none", 0.0, f"lat={l_none:.3f}"))
        out.append((f"fig13/ws{ws>>20}MB/software", 0.0,
                    f"lat={l_sw:.3f} (+{(l_sw/l_none-1)*100:.0f}%)"))
        out.append((f"fig13/ws{ws>>20}MB/engine", 0.0,
                    f"lat={l_eng:.3f} (+{(l_eng/l_none-1)*100:.0f}%)"))
    # Fig. 12: to_cache steering pollutes once the stream exceeds the share
    ws = 4 << 20
    l_none = _latency(ws, "none")
    for stream in STEERED_STREAMS:
        l_steer = _latency(ws, "engine_to_cache", steered_bytes=stream)
        out.append((f"fig12/steered{stream>>20}MB/ws4MB", 0.0,
                    f"lat={l_steer:.3f} (+{(l_steer/l_none-1)*100:.0f}%)"))
    l_sw = _latency(ws, "software")
    out.append(("fig13/claim/4MB_sw_inflation", 0.0,
                f"{(l_sw/l_none-1)*100:.0f}% (paper: 43%)"))
    return out


def rows() -> List[Row]:
    return _steering_rows() + _pollution_rows()
