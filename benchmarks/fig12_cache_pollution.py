"""Paper Figs. 12-13: cache pollution from co-running streaming copies.

TPU adaptation (G3): DSA's cache-control flag maps to destination memory-
space steering — streaming data held out of VMEM working sets.  There is no
shared LLC between "cores" on a TPU chip, so the contention model is the
VMEM/HBM analogue: a co-running software copy consumes vector-unit issue
slots AND evicts VMEM-resident tiles, inflating the latency-sensitive
kernel's effective memory time; an engine (DMA) copy consumes only HBM
bandwidth.

Model: latency-sensitive kernel with working set W against co-running copy
traffic C: sw-copy contention evicts min(W, C)/W of the working set to HBM;
engine-copy only shares HBM bandwidth.  Claims validated: the paper's 43%
latency inflation at 4MB working set with software copies, and ~none with
offload.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row

VMEM = 128 * 2**20 / 16  # per-core VMEM share analogue (8MB)
HBM_LAT = 1.0  # normalized HBM access cost
CACHE_LAT = 0.25  # VMEM-resident access cost (~4x latency gap)
COPY_BW_SHARE = 0.25  # fraction of HBM bw the background copies consume
EVICT_FRAC = 0.13  # cache fraction thrashed by co-running software copies
#  (calibrated so the 4MB working set inflates ~43%, matching paper Fig. 13)

WORKING_SETS = [1 << 20, 4 << 20, 16 << 20, 64 << 20]


def _latency(working_set: int, copies: str) -> float:
    fit = min(1.0, VMEM / working_set)
    if copies == "software":
        evict = min(1.0, (8 << 20) / working_set) * EVICT_FRAC
        fit = fit * (1 - evict)
    base = fit * CACHE_LAT + (1 - fit) * HBM_LAT
    if copies != "none":
        base = base * (1 + COPY_BW_SHARE * (1 - fit))  # HBM sharing
    return base


def rows() -> List[Row]:
    out: List[Row] = []
    for ws in WORKING_SETS:
        l_none = _latency(ws, "none")
        l_sw = _latency(ws, "software")
        l_eng = _latency(ws, "engine")
        out.append((f"fig13/ws{ws>>20}MB/none", 0.0, f"lat={l_none:.3f}"))
        out.append((f"fig13/ws{ws>>20}MB/software", 0.0,
                    f"lat={l_sw:.3f} (+{(l_sw/l_none-1)*100:.0f}%)"))
        out.append((f"fig13/ws{ws>>20}MB/engine", 0.0,
                    f"lat={l_eng:.3f} (+{(l_eng/l_none-1)*100:.0f}%)"))
    l_none = _latency(4 << 20, "none")
    l_sw = _latency(4 << 20, "software")
    out.append(("fig13/claim/4MB_sw_inflation", 0.0,
                f"{(l_sw/l_none-1)*100:.0f}% (paper: 43%)"))
    return out
