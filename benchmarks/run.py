"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig16]

Prints ``name,us_per_call,derived`` CSV rows and writes
results/bench/bench.json.  Each module's docstring names the paper claims it
validates; EXPERIMENTS.md §Paper-validation summarizes the outcomes.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
from pathlib import Path

MODULES = [
    "table1_ops",
    "fig2_transfer_size",
    "fig3_batch",
    "fig4_wq_depth",
    "fig5_latency_breakdown",
    "fig6_memory_tiers",
    "fig7_pes",
    "fig9_wq_config",
    "fig10_multi_instance",
    "fig11_umwait",
    "fig12_cache_pollution",
    "fig14_ts_bs",
    "fig16_vhost",
    "appendix_checkpoint",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    all_rows = []
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        t0 = time.time()
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        try:
            rows = mod.rows()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{mod_name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}", flush=True)
            all_rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "bench.json").write_text(json.dumps(all_rows, indent=1))


if __name__ == "__main__":
    main()
