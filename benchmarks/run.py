"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig16] \
        [--quick] [--json BENCH.json] [--trace DIR]

Prints ``name,us_per_call,derived`` CSV rows and writes
results/bench/bench.json (``--json PATH`` writes the same machine-readable
rows to PATH — what the CI bench-smoke job archives).  ``--quick`` asks
modules that support it (``rows(quick=True)``) for a reduced sweep.  Any
module that raises fails the run (non-zero exit), so benchmark drift fails
the build instead of scrolling by.  Each module's docstring names the paper
claims it validates; EXPERIMENTS.md §Paper-validation summarizes the
outcomes.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import sys
import time
from pathlib import Path

MODULES = [
    "table1_ops",
    "fig2_transfer_size",
    "fig3_batch",
    "fig4_wq_depth",
    "fig5_latency_breakdown",
    "fig6_memory_tiers",
    "fig7_pes",
    "fig9_wq_config",
    "fig10_multi_instance",
    "fig11_umwait",
    "fig12_cache_pollution",
    "fig13_cross_numa",
    "fig14_ts_bs",
    "fig16_vhost",
    "fig17_openloop",
    "appendix_checkpoint",
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for modules whose rows() takes quick=")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the machine-readable rows to PATH")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="modules whose rows() takes trace_dir= attach an "
                         "obs.Sampler and drop per-run time-series CSVs here")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    all_rows = []
    errors = 0
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        t0 = time.time()
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        try:
            kwargs = {}
            params = inspect.signature(mod.rows).parameters
            if args.quick and "quick" in params:
                kwargs["quick"] = True
            if args.trace and "trace_dir" in params:
                kwargs["trace_dir"] = args.trace
            rows = mod.rows(**kwargs)
        except Exception as e:  # noqa: BLE001 — report, fail the run at exit
            print(f"{mod_name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            errors += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}", flush=True)
            all_rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "bench.json").write_text(json.dumps(all_rows, indent=1))
    if args.json:
        Path(args.json).write_text(json.dumps(all_rows, indent=1))
    if errors:
        print(f"# {errors} benchmark module(s) failed", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
