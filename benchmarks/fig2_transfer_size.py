"""Paper Fig. 2: speedup of streaming ops over their software counterparts
vs transfer size, sync (a) and async (b).

Validated claims (TPU-constants analogue):
  * sync offload wins only above a crossover (paper: ~4KB on DSA);
  * async offload pulls the crossover down ~an order of magnitude
    (paper: ~256B);
  * speedup saturates at the engine/software bandwidth ratio.
Measured interpret-mode kernel times are reported for the small sizes to
show the ops are real; the crossover itself is a device-constant question,
so it comes from the calibrated model.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row, time_call, words_for_bytes
from repro.kernels import ops

SIZES = [256, 1024, 4096, 16384, 65536, 262144, 1 << 20, 4 << 20]
OPS = ["memcpy", "fill", "compare", "crc32", "dualcast"]


def rows(quick: bool = False) -> List[Row]:
    out: List[Row] = []
    for size in SIZES:
        for sync, depth in (("sync", 1), ("async", 32)):
            t_eng = MODEL.op_time(size, async_depth=depth, n_pe=4)
            t_sw = MODEL.sw_time(size)
            out.append(
                (
                    f"fig2/{sync}/memcpy/{size}B",
                    t_eng * 1e6,
                    f"speedup={t_sw / t_eng:.2f}x",
                )
            )
    for mode, depth in (("sync", 1), ("async", 32)):
        x = MODEL.crossover_bytes(async_depth=depth, n_pe=4)
        out.append((f"fig2/crossover/{mode}", 0.0, f"crossover={x / 1024:.2f}KB"))
    # measured sanity at two sizes (interpret mode; absolute numbers are
    # host-CPU, shapes only); one size in quick mode (CI bench-smoke)
    for size in (4096,) if quick else (4096, 262144):
        w = words_for_bytes(size)
        t = time_call(lambda w=w: ops.memcpy(w))
        out.append((f"fig2/measured/memcpy/{size}B", t * 1e6, "interpret"))
        t = time_call(lambda w=w: ops.crc32(w))
        out.append((f"fig2/measured/crc32/{size}B", t * 1e6, "interpret"))
    return out
