"""Paper Fig. 6 + Fig. 15: throughput/latency across memory tiers
([src, dst] in local DRAM / remote socket / CXL), adapted to
HBM / remote-pod-ICI / host-DRAM / VMEM (G4).

Claims validated: (a) the engine hides remote latency at large transfers
(remote ~= local once pipelined); (b) mixed placements beat symmetric slow
placements; (c) the faster-WRITE tier is the better destination (paper:
CXL reads cheaper than writes -> DRAM destination preferred); (d) cache
(VMEM) destinations win for consumer-soon data (Fig. 15 / G3).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import MODEL, Row, gbps

TIER_PAIRS = [
    ("hbm", "hbm"), ("hbm", "remote"), ("remote", "hbm"), ("remote", "remote"),
    ("hbm", "host"), ("host", "hbm"), ("host", "host"), ("vmem", "hbm"), ("hbm", "vmem"),
]
SIZES = [4096, 262144, 4 << 20]


def rows() -> List[Row]:
    out: List[Row] = []
    for src, dst in TIER_PAIRS:
        for size in SIZES:
            t_sync = MODEL.op_time(size, src_tier=src, dst_tier=dst)
            t_async = MODEL.op_time(size, src_tier=src, dst_tier=dst, async_depth=32)
            out.append(
                (f"fig6/[{src}->{dst}]/{size}B", t_sync * 1e6,
                 f"sync={gbps(size, t_sync):.1f} async={gbps(size, t_async):.1f}GB/s")
            )
    # claim (a): the engine hides remote LATENCY once pipelined.  On DSA,
    # remote also matched local bandwidth (UPI ~ DDR); on TPU, cross-pod ICI
    # << HBM, so the claim transfers only in the latency-bound regime
    # (<= ~32KB) — an explicit adaptation difference (DESIGN.md §5).
    loc = MODEL.throughput(16384, async_depth=32, n_pe=4)
    rem = MODEL.throughput(16384, async_depth=32, n_pe=4, src_tier="remote", dst_tier="hbm")
    out.append(("fig6/claim/remote_hides_latency_16KB", 0.0, f"remote/local={rem/loc:.3f}"))
    loc4m = MODEL.throughput(4 << 20, async_depth=32, n_pe=4)
    rem4m = MODEL.throughput(4 << 20, async_depth=32, n_pe=4, src_tier="remote", dst_tier="hbm")
    out.append(("fig6/claim/remote_bw_bound_4MB", 0.0,
                f"remote/local={rem4m/loc4m:.3f} (TPU ICI<HBM: expected <1)"))
    # claim (c): faster-write tier as destination
    h2d = MODEL.throughput(1 << 20, src_tier="host", dst_tier="hbm", async_depth=32)
    d2h = MODEL.throughput(1 << 20, src_tier="hbm", dst_tier="host", async_depth=32)
    out.append(("fig6/claim/fast_write_dst_preferred", 0.0, f"host->hbm/hbm->host={h2d/d2h:.3f}"))
    return out
