"""Appendix-style application benchmark: incremental checkpointing with the
engine ops (our SPDK/CacheLib analogue — CRC-framed storage + delta).

Measures: full snapshot vs delta save bytes and time for a model whose
weights drift a little per step (late-training regime), CRC verification
cost, and restore time.  Claims validated: deltas cut checkpoint bytes
roughly by the drift fraction; CRC catches corruption (counted).
"""
from __future__ import annotations

import tempfile
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.checkpoint import CheckpointConfig, CheckpointManager


def rows() -> List[Row]:
    out: List[Row] = []
    rng = np.random.default_rng(0)
    tree = {
        f"layer{i}": jnp.asarray(rng.normal(size=(256, 256)), jnp.float32) for i in range(8)
    }
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(CheckpointConfig(directory=d, async_save=False, full_every=100))
        t0 = time.perf_counter()
        m.save(1, tree)
        t_full = time.perf_counter() - t0
        full_bytes = m.stats["bytes_written"]

        # late-training drift: 1% of weights change
        tree2 = {}
        for k, v in tree.items():
            idx = rng.choice(v.size, v.size // 100, replace=False)
            flat = np.asarray(v).reshape(-1).copy()
            flat[idx] += 0.01
            tree2[k] = jnp.asarray(flat.reshape(v.shape))
        before = m.stats["bytes_written"]
        t0 = time.perf_counter()
        m.save(2, tree2)
        t_delta = time.perf_counter() - t0
        delta_bytes = m.stats["bytes_written"] - before

        t0 = time.perf_counter()
        step, restored = m.restore(treedef_like=tree)
        t_restore = time.perf_counter() - t0
        ok = all(
            np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(tree2), jax.tree.leaves(restored))
        )
        out.append(("appendix/ckpt/full_save", t_full * 1e6, f"{full_bytes}B"))
        out.append(("appendix/ckpt/delta_save", t_delta * 1e6,
                    f"{delta_bytes}B ({delta_bytes/full_bytes:.1%} of full)"))
        out.append(("appendix/ckpt/restore+crc", t_restore * 1e6, f"roundtrip_ok={ok}"))
        out.append(("appendix/ckpt/delta_leaves", 0.0,
                    f"{m.stats['delta_leaves']} overflows={m.stats['delta_overflows']}"))
    return out
