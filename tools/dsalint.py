#!/usr/bin/env python
"""dsalint: Future/Device API lint over the repo's own source.

Runs the ``repro.analysis.apilint`` AST rules (DSA1xx) over files and
directory trees and prints ``path:line:col: CODE message`` per finding.
Exit status 1 if any violations, 0 on a clean tree.

    python tools/dsalint.py                   # default: src tests benchmarks examples tools
    python tools/dsalint.py src/repro/core    # specific trees/files
    python tools/dsalint.py --list-rules      # rule catalogue (see docs/analysis.md)
    python tools/dsalint.py --select DSA101,DSA103 src

Suppress a finding in place with ``# dsalint: disable=DSA103`` (or a bare
``# dsalint: disable`` for all rules) on the offending line.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import apilint  # noqa: E402

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "tools")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="dsalint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", help="files or directory trees "
                    f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to enable (default all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in sorted(apilint.RULES):
            print(f"{code}  {apilint.RULES[code]}")
        return 0

    paths = args.paths or [str(ROOT / p) for p in DEFAULT_PATHS
                           if (ROOT / p).exists()]
    select = ([c.strip() for c in args.select.split(",") if c.strip()]
              if args.select else None)
    violations = apilint.lint_paths(paths, select=select)
    for v in violations:
        print(v)
    if violations:
        print(f"dsalint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
