"""Docs checker (CI): every ```python code block in README.md and docs/*.md
must execute cleanly against the current sources, so the documentation can
never drift from the API.

    PYTHONPATH=src python tools/check_docs.py

Each block runs in its own namespace; a failure prints the offending file,
block index, and traceback, and exits non-zero.  Non-executable snippets
should use a different fence language (```bash, ```text, ...).
"""
from __future__ import annotations

import re
import subprocess
import sys
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# match ```python / ```py fences, tolerating info strings and CRLF endings
BLOCK_RE = re.compile(r"```py(?:thon)?[^\n]*\r?\n(.*?)```", re.DOTALL)
#: bytecode artifacts that must never be committed — directories or files
BYTECODE_RE = re.compile(r"(^|/)__pycache__(/|$)|\.py[cod]$")


def check_bytecode() -> int:
    """Fail when bytecode artifacts are GIT-TRACKED.  Deliberately scoped to
    ``git ls-files`` (not the working tree): running the test suite or this
    very script compiles ``__pycache__`` locally, so an on-disk scan would
    always fail — only committed artifacts are the defect.  Also verifies
    .gitignore actually covers them, so they cannot sneak back in via
    ``git add .``."""
    try:
        tracked = subprocess.run(
            ["git", "ls-files"], cwd=ROOT, check=True,
            capture_output=True, text=True).stdout.splitlines()
    except (OSError, subprocess.CalledProcessError) as e:
        print(f"note bytecode check skipped (git unavailable: {e})")
        return 0
    bad = [f for f in tracked if BYTECODE_RE.search(f)]
    for f in bad:
        print(f"FAIL tracked bytecode artifact: {f}", file=sys.stderr)
    ignored = subprocess.run(
        ["git", "check-ignore", "-q", "src/__pycache__/x.cpython-310.pyc"],
        cwd=ROOT).returncode == 0
    if not ignored:
        print("FAIL .gitignore does not cover __pycache__/*.pyc",
              file=sys.stderr)
    if bad or not ignored:
        return len(bad) + (0 if ignored else 1)
    print("ok   no tracked bytecode artifacts; .gitignore covers them")
    return 0


def check_dsalint() -> int:
    """Run the repro.analysis.apilint rules over every GIT-TRACKED python
    file — the ratchet that keeps Future/Device API misuse (dropped
    futures, raw kick() loops, swallowed QueueFull) out of the tree.  Same
    git-scoped rationale as check_bytecode: scratch files in the working
    tree are not the defect, committed ones are."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.analysis import apilint

    try:
        tracked = subprocess.run(
            ["git", "ls-files", "*.py"], cwd=ROOT, check=True,
            capture_output=True, text=True).stdout.splitlines()
    except (OSError, subprocess.CalledProcessError) as e:
        print(f"note dsalint check skipped (git unavailable: {e})")
        return 0
    violations = apilint.lint_paths([ROOT / f for f in tracked])
    for v in violations:
        print(f"FAIL {v}", file=sys.stderr)
    if violations:
        return len(violations)
    print(f"ok   dsalint clean over {len(tracked)} tracked python files")
    return 0


def doc_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(path: Path) -> int:
    failures = 0
    text = path.read_text()
    matches = list(BLOCK_RE.finditer(text))
    if not matches:
        print(f"note {path.relative_to(ROOT)}: no python blocks found")
    for i, m in enumerate(matches, 1):
        code = m.group(1).replace("\r\n", "\n")
        line = text[: m.start()].count("\n") + 2  # first line inside the fence
        try:
            exec(compile(code, f"{path.name}:block{i}", "exec"), {"__name__": "__docs__"})
        except Exception:
            failures += 1
            print(f"FAIL {path.relative_to(ROOT)} block {i} (line {line}):",
                  file=sys.stderr)
            traceback.print_exc()
        else:
            print(f"ok   {path.relative_to(ROOT)} block {i} (line {line})")
    return failures


def main() -> int:
    failures = check_bytecode()  # repo hygiene first: cheap and unambiguous
    failures += check_dsalint()
    files = doc_files()
    if not files:
        print("no documentation files found", file=sys.stderr)
        return 1
    failures += sum(check_file(f) for f in files)
    if failures:
        print(f"{failures} documentation code block(s) failed", file=sys.stderr)
        return 1
    print(f"all python blocks in {len(files)} file(s) executed cleanly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
