"""Regression gate over benchmark JSON rows.

    python tools/bench_compare.py CURRENT.json BASELINE.json \
        [--tolerance 0.20] [--match REGEX] [--require REGEX ...]

Compares ``us_per_call`` per row name and exits 1 when any compared row is
more than ``tolerance`` slower than the committed baseline (default 20%).
Rows with ``us_per_call <= 0`` carry derived-only claims and are skipped;
``--match`` restricts the comparison (CI uses ``^fig13/model`` — the
analytical-model rows are machine-independent, so the gate is deterministic
on any runner).  Rows present on only one side are reported but do not
fail: new benchmarks land before their baselines.

``--require REGEX`` (repeatable) is a PRESENCE gate for rows whose timings
are machine-dependent and therefore can't be value-compared: the current
run must contain at least one row matching each pattern, with a finite
non-negative ``us_per_call``.  CI uses ``--require '^fig11/'`` so the wait
sweep silently vanishing (module error, rename) fails the build even
though its wall times aren't gated.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys
from pathlib import Path


def load_rows(path: str) -> dict:
    rows = json.loads(Path(path).read_text())
    return {r["name"]: float(r["us_per_call"]) for r in rows}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed slowdown fraction (default 0.20 = +20%%)")
    ap.add_argument("--match", default="",
                    help="regex restricting which row names are compared")
    ap.add_argument("--require", action="append", default=[], metavar="REGEX",
                    help="current run must contain >=1 row matching REGEX "
                         "with a finite us_per_call >= 0 (repeatable)")
    args = ap.parse_args()

    cur, base = load_rows(args.current), load_rows(args.baseline)
    pat = re.compile(args.match) if args.match else None
    compared = regressed = 0
    for name in sorted(base):
        if pat and not pat.search(name):
            continue
        if base[name] <= 0:
            continue  # derived-only row: no timing to gate
        if name not in cur:
            print(f"MISSING {name} (in baseline, not in current run)")
            continue
        compared += 1
        ratio = cur[name] / base[name]
        if ratio > 1.0 + args.tolerance:
            regressed += 1
            print(f"REGRESSED {name}: {base[name]:.2f}us -> {cur[name]:.2f}us "
                  f"(x{ratio:.2f} > x{1.0 + args.tolerance:.2f})")
        else:
            print(f"ok {name}: {base[name]:.2f}us -> {cur[name]:.2f}us (x{ratio:.2f})")
    for name in sorted(set(cur) - set(base)):
        if pat and not pat.search(name):
            continue
        print(f"NEW {name} (no baseline yet)")
    missing_required = 0
    for req in args.require:
        rp = re.compile(req)
        hits = [n for n, us in cur.items()
                if rp.search(n) and us >= 0 and math.isfinite(us)]
        if hits:
            print(f"required {req!r}: {len(hits)} row(s) present")
        else:
            missing_required += 1
            print(f"MISSING-REQUIRED {req!r}: no valid row in current run",
                  file=sys.stderr)
    if compared == 0:
        print("error: no rows compared — check --match and the baseline file",
              file=sys.stderr)
        return 1
    print(f"{compared} rows compared, {regressed} regressed "
          f"(tolerance +{args.tolerance:.0%})")
    return 1 if regressed or missing_required else 0


if __name__ == "__main__":
    sys.exit(main())
