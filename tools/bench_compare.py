"""Regression gate over benchmark JSON rows.

    python tools/bench_compare.py CURRENT.json BASELINE.json \
        [--tolerance 0.20] [--figure-tolerance FIG=TOL ...] \
        [--match REGEX] [--require REGEX ...]

Compares ``us_per_call`` per row name and exits 1 when any compared row is
more than its tolerance slower than the committed baseline (default 20%).
Rows with ``us_per_call <= 0`` carry derived-only claims and are skipped;
``--match`` restricts the comparison.  Rows present on only one side are
reported but do not fail: new benchmarks land before their baselines.

``--figure-tolerance FIG=TOL`` (repeatable) overrides the tolerance per
figure, where a row's figure is the prefix before the first ``/`` in its
name (``fig5/bs4/wait`` -> ``fig5``).  This is how CI gates the WHOLE
suite with one call: deterministic model rows get a tight bound, noisy
wall-clock figures get a loose one (e.g. ``--tolerance 3.0
--figure-tolerance fig13=0.25`` — shared-runner wall times routinely
jitter 2x, the analytical rows must not).

``--require REGEX`` (repeatable) is a PRESENCE gate for rows whose timings
are machine-dependent and therefore can't be value-compared: the current
run must contain at least one row matching each pattern, with a finite
non-negative ``us_per_call``.  CI uses ``--require '^fig11/'`` so the wait
sweep silently vanishing (module error, rename) fails the build even
though its wall times aren't gated.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys
from pathlib import Path


def load_rows(path: str) -> dict:
    rows = json.loads(Path(path).read_text())
    return {r["name"]: float(r["us_per_call"]) for r in rows}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed slowdown fraction (default 0.20 = +20%%)")
    ap.add_argument("--figure-tolerance", action="append", default=[],
                    metavar="FIG=TOL",
                    help="per-figure tolerance override, figure = row name "
                         "before the first '/' (repeatable)")
    ap.add_argument("--match", default="",
                    help="regex restricting which row names are compared")
    ap.add_argument("--require", action="append", default=[], metavar="REGEX",
                    help="current run must contain >=1 row matching REGEX "
                         "with a finite us_per_call >= 0 (repeatable)")
    args = ap.parse_args()

    fig_tol = {}
    for spec in args.figure_tolerance:
        fig, sep, tol = spec.partition("=")
        if not sep or not fig:
            print(f"error: --figure-tolerance wants FIG=TOL, got {spec!r}",
                  file=sys.stderr)
            return 2
        try:
            fig_tol[fig] = float(tol)
        except ValueError:
            print(f"error: --figure-tolerance {spec!r}: {tol!r} is not a "
                  f"number", file=sys.stderr)
            return 2

    cur, base = load_rows(args.current), load_rows(args.baseline)
    pat = re.compile(args.match) if args.match else None
    compared = regressed = 0
    for name in sorted(base):
        if pat and not pat.search(name):
            continue
        if base[name] <= 0:
            continue  # derived-only row: no timing to gate
        if name not in cur:
            print(f"MISSING {name} (in baseline, not in current run)")
            continue
        compared += 1
        tol = fig_tol.get(name.split("/", 1)[0], args.tolerance)
        ratio = cur[name] / base[name]
        if ratio > 1.0 + tol:
            regressed += 1
            print(f"REGRESSED {name}: {base[name]:.2f}us -> {cur[name]:.2f}us "
                  f"(x{ratio:.2f} > x{1.0 + tol:.2f})")
        else:
            print(f"ok {name}: {base[name]:.2f}us -> {cur[name]:.2f}us "
                  f"(x{ratio:.2f} <= x{1.0 + tol:.2f})")
    for name in sorted(set(cur) - set(base)):
        if pat and not pat.search(name):
            continue
        print(f"NEW {name} (no baseline yet)")
    missing_required = 0
    for req in args.require:
        rp = re.compile(req)
        hits = [n for n, us in cur.items()
                if rp.search(n) and us >= 0 and math.isfinite(us)]
        if hits:
            print(f"required {req!r}: {len(hits)} row(s) present")
        else:
            missing_required += 1
            print(f"MISSING-REQUIRED {req!r}: no valid row in current run",
                  file=sys.stderr)
    if compared == 0:
        print("error: no rows compared — check --match and the baseline file",
              file=sys.stderr)
        return 1
    print(f"{compared} rows compared, {regressed} regressed "
          f"(tolerance +{args.tolerance:.0%})")
    return 1 if regressed or missing_required else 0


if __name__ == "__main__":
    sys.exit(main())
