"""pcm_repro — live accelerator monitor, mirroring Intel pcm-accel's CLI.

    PYTHONPATH=src python tools/pcm_repro.py [target] [options]

target (one, like pcm-accel):
    -dsa            monitor the DSA-analogue stream engines (default)

options:
    -numa           lay the fabric out over 2 NUMA nodes and print the
                    per-node table (local vs cross traffic, link occupancy)
    -i <interval>   refresh interval in seconds (default 1.0)
    -n <frames>     stop after N refreshes (default: run for --duration)
    -csv [<path>]   also write the sampled time series as CSV (default
                    path results/obs/pcm_repro.csv); the file is rewritten
                    every frame so a crash keeps the tail
    -silent         print only the measurement frames (no banner)
    --once          take a single sample of a short burst and exit — the
                    CI smoke mode (no live refresh, implies one frame)
    --duration S    workload length in seconds (default 5.0)
    --instances N   engine instances (per node when -numa; default 2)
    --trace [RATE]  attach a descriptor-lifecycle tracer (docs/tracing.md)
                    at the given sampling rate (default 1.0 when the flag
                    is bare); each frame then shows live per-phase
                    occupancy (seconds of phase time folded per wall
                    second) next to the engine table

Shutdown is exception-safe: stopping the workload / sampler during a
device teardown race prints a one-line note instead of a traceback and
the exit code stays 0 — monitors must never fail the run they observe.

Without an external workload the monitor drives its own: a fig2-style
mixed-size copy/CRC loop submitted through the device, so every frame has
traffic to show.  The display refreshes an engine x metric table in place
(ANSI home+clear), pcm-accel style; on exit the windowed p50/p95/max
summary is printed for the headline metrics.
"""
from __future__ import annotations

import argparse
import sys
import threading
import time
from typing import List, Optional

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    OpType, QueueFull, Topology, WorkDescriptor, make_device)
from repro.obs import PHASES, Sampler  # noqa: E402

DEFAULT_CSV = "results/obs/pcm_repro.csv"
#: fig2-style transfer-size mix (bytes): small descriptors stress submit
#: overhead, large ones stress bandwidth — both ends of the paper's Fig. 2
WORKLOAD_SIZES = [4096, 65536, 1 << 20]


class BurstWorkload(threading.Thread):
    """Background fig2-style submitter: mixed-size memcpy/crc32 round-robin
    over the fabric (alternating home-node hints on -numa so cross-node
    traffic shows up) until stopped."""

    def __init__(self, device, numa: bool):
        super().__init__(daemon=True, name="pcm-workload")
        self.device = device
        self.numa = numa
        self.stop_evt = threading.Event()
        n_nodes = device.topology.n_nodes if numa else 1
        # one buffer set per node, registered to its home so the locality
        # registry (not just the submit hint) drives src_node stamping
        self.bufs = []
        for nid in range(n_nodes):
            per_node = [jnp.zeros((max(size // 512, 1), 128), jnp.float32)
                        for size in WORKLOAD_SIZES]
            if numa:
                for b in per_node:
                    device.register(b, node=nid)
            self.bufs.append(per_node)
        self.submitted = 0

    def burst(self, n: int = 8) -> None:
        """Submit one burst of n descriptors and retire them.  Alternating
        bursts go through the fused ``submit_many`` doorbell, so the SUB/s
        and FUSED% columns show both submission paths live."""
        futs = []
        if (self.submitted // max(n, 1)) % 2 == 0:
            # fused burst: homogeneous copies through one doorbell
            descs = []
            for i in range(n):
                k = self.submitted + i
                buf = self.bufs[k % len(self.bufs)][k % len(WORKLOAD_SIZES)]
                descs.append(WorkDescriptor(op=OpType.MEMCPY, src=buf))
            try:
                futs = self.device.submit_many(descs)
            except QueueFull:
                time.sleep(0.001)  # backpressure: let the PEs catch up
        else:
            for i in range(n):
                k = self.submitted + i
                home = k % len(self.bufs)
                buf = self.bufs[home][k % len(WORKLOAD_SIZES)]
                node = None
                if self.numa:
                    # a quarter of the ops are placed on the remote node (in
                    # both directions) — the engine reads across the link,
                    # lighting up the CROSS-GB/s column
                    node = (1 - home) % self.device.topology.n_nodes \
                        if k % 8 in (1, 6) else home
                try:
                    if k % 4 == 3:
                        futs.append(self.device.crc32_async(buf, node=node))
                    else:
                        futs.append(self.device.memcpy_async(buf, node=node))
                except QueueFull:
                    time.sleep(0.001)  # backpressure: let the PEs catch up
        self.submitted += len(futs)
        if futs:
            self.device.wait_all(futs)

    def run(self) -> None:
        while not self.stop_evt.is_set():
            self.burst()

    def stop(self) -> None:
        self.stop_evt.set()
        self.join(timeout=10.0)
        self.device.drain()


def _cell(row: dict, key: str, fmt: str = "{:.2f}", default: str = "-") -> str:
    v = row.get(key)
    return default if v is None else fmt.format(v)


def render_frame(sampler: Sampler, device, numa: bool, frame: int) -> str:
    """One engine x metric table (plus the per-node table on -numa) from
    the latest tick's row — the pcm-accel refresh unit."""
    rows = sampler.rows()
    row = rows[-1] if rows else {}
    lines: List[str] = []
    lines.append(f"pcm_repro frame {frame}  t={row.get('time_s', 0.0):7.2f}s  "
                 f"interval={row.get('dt_s', 0.0):.2f}s")
    hdr = (f"{'ENGINE':<10s} {'NODE':>4s} {'GB/s':>8s} {'OPS/s':>9s} "
           f"{'SUB/s':>9s} {'FUSED%':>6s} "
           f"{'UTIL':>6s} {'WQ-OCC':>6s} {'QDELAY-us':>9s} {'RETRY':>6s} "
           f"{'ERR':>4s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    dt = max(row.get("dt_s", 1.0), 1e-9)
    for e in device.engines:
        n = e.name
        ops_s = row.get(f"engine.{n}.ops", 0.0) / dt
        fused = row.get(f"engine.{n}.fused_frac")
        lines.append(
            f"{n:<10s} {getattr(e, 'node_id', 0):>4d} "
            f"{_cell(row, f'engine.{n}.gbps'):>8s} {ops_s:>9.1f} "
            f"{_cell(row, f'engine.{n}.submits_per_s', '{:.1f}'):>9s} "
            f"{('-' if fused is None else f'{fused:.0%}'):>6s} "
            f"{_cell(row, f'engine.{n}.util'):>6s} "
            f"{_cell(row, f'engine.{n}.wq_occupancy'):>6s} "
            f"{_cell(row, f'engine.{n}.queue_delay_us', '{:.1f}'):>9s} "
            f"{_cell(row, f'engine.{n}.retries', '{:.0f}'):>6s} "
            f"{_cell(row, f'engine.{n}.errors', '{:.0f}'):>4s}"
        )
    if numa:
        lines.append("")
        nhdr = (f"{'NODE':<6s} {'LOCAL-GB/s':>10s} {'CROSS-GB/s':>10s} "
                f"{'LINK-OCC':>8s}  ENGINES")
        lines.append(nhdr)
        lines.append("-" * len(nhdr))
        for node in device.topology.nodes:
            nid = node.node_id
            engines = ",".join(e.name for e in device.engines_on(nid))
            occ = row.get(f"node.{nid}.link_occupancy")
            lines.append(
                f"{nid:<6d} {_cell(row, f'node.{nid}.local_gbps'):>10s} "
                f"{_cell(row, f'node.{nid}.cross_gbps'):>10s} "
                f"{('-' if occ is None else f'{occ:.1%}'):>8s}  {engines}"
            )
    waits = sorted({k.split(".")[1] for k in row if k.startswith("wait.")})
    for pname in waits:
        frac = row.get(f"wait.{pname}.host_free_frac")
        lines.append(
            f"wait/{pname}: host_free="
            f"{('-' if frac is None else f'{frac:.1%}')} "
            f"wakes={row.get(f'wait.{pname}.wakes', 0):.0f} "
            f"irqs={row.get(f'wait.{pname}.irqs', 0):.0f}"
        )
    lines.append(
        f"pressure: backoff_retries={row.get('device.backoff_retries', 0):.0f} "
        f"queue_full={row.get('device.queue_full', 0):.0f}"
    )
    if any(k.startswith("trace.") for k in row):
        parts = [f"sampled=+{row.get('trace.sampled', 0):.0f}"]
        for phase in PHASES:
            occ = row.get(f"trace.phase.{phase}.occupancy")
            if occ:
                parts.append(f"{phase}={occ:.1%}")
        lines.append("trace: " + " ".join(parts))
    return "\n".join(lines)


def shutdown_quietly(*stoppables) -> None:
    """Stop monitors/workloads without letting a teardown race (sampler
    thread vs device drain) turn into a traceback — the monitor must not
    fail the run it observes."""
    for s in stoppables:
        try:
            s.stop()
        except Exception as exc:  # noqa: BLE001 — deliberate: exit clean
            print(f"pcm_repro: shutdown note ({type(s).__name__}): {exc!r}",
                  file=sys.stderr)


def print_summary(sampler: Sampler) -> None:
    print("\nwindow summary (p50/p95/max per metric):")
    summary = sampler.summary()
    for name, s in summary.items():
        if not any(name.endswith(k) for k in
                   (".gbps", ".util", ".wq_occupancy", ".queue_delay_us",
                    ".host_free_frac", ".link_occupancy")):
            continue
        if s["n"] == 0 or (s["max"] == 0 and s["p95"] == 0):
            continue
        print(f"  {name:<40s} p50={s['p50']:>10.3f} p95={s['p95']:>10.3f} "
              f"max={s['max']:>10.3f}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="pcm_repro", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("-dsa", action="store_true", default=True,
                    help="monitor the DSA-analogue engines (default target)")
    ap.add_argument("-numa", action="store_true",
                    help="2-node fabric + per-node traffic table")
    ap.add_argument("-i", type=float, default=1.0, metavar="INTERVAL",
                    help="refresh interval seconds (default 1.0)")
    ap.add_argument("-n", type=int, default=0, metavar="FRAMES",
                    help="stop after N frames (0 = run for --duration)")
    ap.add_argument("-csv", nargs="?", const=DEFAULT_CSV, default=None,
                    metavar="PATH", help=f"write CSV (default {DEFAULT_CSV})")
    ap.add_argument("-silent", action="store_true",
                    help="measurement frames only, no banner")
    ap.add_argument("--once", action="store_true",
                    help="single burst + single frame, no live refresh (CI)")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="workload duration seconds (default 5.0)")
    ap.add_argument("--instances", type=int, default=2,
                    help="engine instances (per node with -numa)")
    ap.add_argument("--trace", nargs="?", const=1.0, default=None,
                    type=float, metavar="RATE",
                    help="descriptor-lifecycle tracing at RATE (default 1.0)")
    args = ap.parse_args(argv)

    topo = (Topology.symmetric(2, engines_per_node=args.instances)
            if args.numa else None)
    device = make_device(n_instances=args.instances, topology=topo,
                         policy="numa_local" if args.numa else "round_robin",
                         trace=args.trace)
    sampler = Sampler(device, interval_s=args.i)
    if not args.silent:
        names = ", ".join(e.name for e in device.engines)
        print(f"pcm_repro: monitoring {len(device.engines)} DSA-analogue "
              f"instance(s) [{names}] over {device.topology!r}", flush=True)

    workload = BurstWorkload(device, numa=args.numa)
    if args.once:
        workload.burst(16)
        device.drain()
        sampler.tick()
        print(render_frame(sampler, device, args.numa, frame=1))
        if args.csv:
            sampler.to_csv(args.csv)
            if not args.silent:
                print(f"wrote {args.csv}")
        return 0

    workload.start()
    live = sys.stdout.isatty()
    deadline = time.perf_counter() + args.duration
    frame = 0
    try:
        while (args.n and frame < args.n) or (not args.n and
                                              time.perf_counter() < deadline):
            time.sleep(args.i)
            sampler.tick()
            frame += 1
            text = render_frame(sampler, device, args.numa, frame)
            if live:
                sys.stdout.write("\x1b[H\x1b[2J")  # home + clear, in-place
            print(text, flush=True)
            if args.csv:
                sampler.to_csv(args.csv)  # rewrite: crash keeps the tail
    except KeyboardInterrupt:
        pass
    finally:
        shutdown_quietly(workload, sampler)
    if sampler.error is not None:
        # a tick raced device teardown: report it, keep the exit clean
        print(f"pcm_repro: sampler note: {sampler.error!r}", file=sys.stderr)
    if args.csv:
        try:
            sampler.to_csv(args.csv)
            if not args.silent:
                print(f"wrote {args.csv}")
        except Exception as exc:  # noqa: BLE001 — deliberate: exit clean
            print(f"pcm_repro: csv note: {exc!r}", file=sys.stderr)
    if not args.silent:
        print_summary(sampler)
    return 0


if __name__ == "__main__":
    sys.exit(main())
