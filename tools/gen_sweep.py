"""gen_sweep — generated submit-pattern sweep over the offload engine.

Generates a parameterized matrix of submission patterns — op mix x
transfer size x batch depth x WQ mode x wait policy — runs each pattern
against a fresh device, and reports the per-submit overhead in us/op:
the host-side cost the paper's batch-amortization guideline (Fig. 3 / G1)
is about.  The per-descriptor legs (b1) are the baseline; the batched legs
(b8/b32) go through ``Device.submit_many`` and share one doorbell + one
engine kick per burst.

    PYTHONPATH=src python tools/gen_sweep.py [--quick] [--iters N]
        [--json PATH] [--merge-into BENCH.json] [--check] [--list]

Row schema matches ``benchmarks/run.py --json``, so
``tools/bench_compare.py`` gates the sweep directly (CI uses
``--require '^sweep/'`` plus a loose ``--figure-tolerance sweep=...``):

    {"name": "sweep/memcpy/1KiB/b8/swq/umwait",
     "us_per_call": <submit-phase us per descriptor>,
     "derived": "n=64 submit_wall=...us e2e=...ms"}

``us_per_call`` times the SUBMIT PHASE only — first doorbell to last,
divided by descriptor count, median over ``--iters`` — after a JIT warmup
and with completion waiting off the clock, so it isolates exactly the
overhead ``submit_many`` amortizes.  The derived-only claim row
(``us_per_call=-1``) records the relative b1 -> b8 drop for 1 KiB copies;
``--check`` exits 1 when that drop is under 25%.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
import time
from pathlib import Path
from typing import List, Optional

sys.path.insert(0, "src")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import OpType, WorkDescriptor, make_device  # noqa: E402

#: the claim row's pattern legs (swq = the ENQCMD path the paper amortizes)
CLAIM_BASE = "sweep/memcpy/1KiB/b1/swq/umwait"
CLAIM_BATCH = "sweep/memcpy/1KiB/b8/swq/umwait"
CLAIM_ROW = "sweep/claim/submit_overhead_drop_1KiB"
CLAIM_MIN_DROP = 0.25

SIZE_LABELS = {1 << 10: "1KiB", 64 << 10: "64KiB", 1 << 20: "1MiB"}


@dataclasses.dataclass(frozen=True)
class Pattern:
    """One generated submit pattern (a point in the sweep matrix)."""

    op: str          # "memcpy" | "crc32" | "fill"
    size: int        # transfer bytes per descriptor
    batch: int       # descriptors per doorbell (1 = per-descriptor submit)
    wq: str          # "dwq" | "swq"
    wait: str        # completion wait policy name
    n: int = 64      # descriptors per timed iteration

    @property
    def name(self) -> str:
        return (f"sweep/{self.op}/{SIZE_LABELS[self.size]}/b{self.batch}/"
                f"{self.wq}/{self.wait}")


def generate(quick: bool = False) -> List[Pattern]:
    """The pattern matrix.  quick keeps the legs CI gates (both WQ modes,
    b1 vs b8, small + medium transfers) and drops the rest."""
    if quick:
        ops = ("memcpy", "crc32")
        sizes = (1 << 10, 64 << 10)
        batches = (1, 8)
        wqs = ("dwq", "swq")
        waits = ("umwait",)
    else:
        ops = ("memcpy", "crc32", "fill")
        sizes = (1 << 10, 64 << 10, 1 << 20)
        batches = (1, 8, 32)
        wqs = ("dwq", "swq")
        waits = ("spin", "umwait")
    return [Pattern(op, size, batch, wq, wait)
            for op in ops for size in sizes for batch in batches
            for wq in wqs for wait in waits]


def _make_descs(p: Pattern) -> List[WorkDescriptor]:
    n_words = max(p.size // 4, 1)
    if p.op == "fill":
        pat = jnp.asarray([0xDEADBEEF], jnp.uint32)
        return [WorkDescriptor(op=OpType.FILL, pattern=pat, n_words=n_words)
                for _ in range(p.n)]
    rng = np.random.default_rng(7)
    src = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
    op = OpType.MEMCPY if p.op == "memcpy" else OpType.CRC32
    # one shared source buffer: the sweep times submission, not allocation
    return [WorkDescriptor(op=op, src=src) for _ in range(p.n)]


def run_pattern(p: Pattern, iters: int = 3) -> dict:
    """Run one pattern on a fresh device; us_per_call = submit-phase wall
    per descriptor (median over iters), completions retired off the clock."""
    device = make_device(
        wq_mode="dedicated" if p.wq == "dwq" else "shared",
        wq_size=max(2 * p.n, 64),
        wait_policy=p.wait,
    )
    warm = _make_descs(dataclasses.replace(p, n=1))
    device.wait_all([device.submit(warm[0])])  # JIT warmup off the clock

    submit_us: List[float] = []
    e2e_s = 0.0
    for _ in range(iters):
        descs = _make_descs(p)
        t0 = time.perf_counter()
        if p.batch == 1:
            futs = [device.submit(d) for d in descs]  # dsalint: disable=DSA106 — the per-descriptor baseline leg
        else:
            futs = device.submit_many(descs, chunk=p.batch)
        t1 = time.perf_counter()
        device.wait_all(futs)
        e2e_s = time.perf_counter() - t0
        submit_us.append((t1 - t0) / p.n * 1e6)
    us = float(statistics.median(submit_us))
    return {
        "name": p.name,
        "us_per_call": us,
        "derived": (f"n={p.n} submit_wall={us * p.n:.1f}us "
                    f"e2e={e2e_s * 1e3:.2f}ms"),
    }


def claim_row(rows: List[dict]) -> dict:
    """Derived-only row recording the b1 -> b8 submit-overhead drop for
    1 KiB copies on the shared-WQ path (the PR's >=25% acceptance bar)."""
    us = {r["name"]: r["us_per_call"] for r in rows}
    base, batched = us.get(CLAIM_BASE), us.get(CLAIM_BATCH)
    if not base or batched is None:
        return {"name": CLAIM_ROW, "us_per_call": -1.0,
                "derived": "drop=n/a (claim legs not in this sweep)"}
    drop = (base - batched) / base
    return {"name": CLAIM_ROW, "us_per_call": -1.0,
            "derived": (f"drop={drop:.1%} (b1={base:.2f}us -> "
                        f"b8={batched:.2f}us, min {CLAIM_MIN_DROP:.0%})")}


def claim_drop(rows: List[dict]) -> Optional[float]:
    us = {r["name"]: r["us_per_call"] for r in rows}
    base, batched = us.get(CLAIM_BASE), us.get(CLAIM_BATCH)
    if not base or batched is None:
        return None
    return (base - batched) / base


def merge_into(path: str, rows: List[dict]) -> None:
    """Replace the sweep/ rows of an existing bench JSON with this run's."""
    p = Path(path)
    existing = json.loads(p.read_text()) if p.exists() else []
    kept = [r for r in existing if not r["name"].startswith("sweep/")]
    p.write_text(json.dumps(kept + rows, indent=1))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="gen_sweep", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true",
                    help="reduced matrix (the CI bench-smoke legs)")
    ap.add_argument("--iters", type=int, default=3,
                    help="timed iterations per pattern (median; default 3)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as bench_compare-compatible JSON")
    ap.add_argument("--merge-into", default=None, metavar="BENCH.json",
                    help="replace the sweep/ rows inside an existing bench "
                         "JSON with this run's rows")
    ap.add_argument("--check", action="store_true",
                    help=f"exit 1 unless the 1KiB b1->b8 submit-overhead "
                         f"drop is >= {CLAIM_MIN_DROP:.0%}")
    ap.add_argument("--list", action="store_true",
                    help="print the generated pattern names and exit")
    args = ap.parse_args(argv)

    patterns = generate(quick=args.quick)
    if args.list:
        for p in patterns:
            print(p.name)
        return 0

    rows: List[dict] = []
    print("name,us_per_call,derived")
    for p in patterns:
        row = run_pattern(p, iters=args.iters)
        rows.append(row)
        print(f"{row['name']},{row['us_per_call']:.3f},{row['derived']}",
              flush=True)
    rows.append(claim_row(rows))
    print(f"{rows[-1]['name']},{rows[-1]['us_per_call']:.0f},"
          f"{rows[-1]['derived']}")

    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1))
    if args.merge_into:
        merge_into(args.merge_into, rows)

    if args.check:
        drop = claim_drop(rows)
        if drop is None:
            print("gen_sweep: claim legs missing from the sweep",
                  file=sys.stderr)
            return 1
        if drop < CLAIM_MIN_DROP:
            print(f"gen_sweep: submit-overhead drop {drop:.1%} is under the "
                  f"{CLAIM_MIN_DROP:.0%} bar", file=sys.stderr)
            return 1
        print(f"gen_sweep: check ok — drop {drop:.1%} >= "
              f"{CLAIM_MIN_DROP:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
