"""trace_view — descriptor-lifecycle trace inspector and Perfetto exporter.

    PYTHONPATH=src python tools/trace_view.py [options]

Runs a short traced workload (or just analyzes), then prints the span
summary table, the top-K slowest descriptors, the critical-path report,
and the host-free cross-check (span-derived vs WaitStats-derived — the
paper's Fig. 11 attribution, reconciled two ways).

options:
    --workload {burst,openloop}
                    burst (default): fig2-style mixed-size copies with
                    after= dependency chains and a then() continuation per
                    chain, so the trace exercises every edge kind.
                    openloop: a short VhostStyleServer open-loop run
                    (NullDecoder) — request-scoped trace contexts.
    --rate R        sampling rate in [0, 1] (default 1.0 = every descriptor)
    --descriptors N burst size for --workload burst (default 64)
    --horizon S     virtual horizon for --workload openloop (default 0.5)
    --top K         slowest-descriptor table depth (default 5)
    --perfetto PATH also export trace_event JSON (chrome://tracing /
                    ui.perfetto.dev loadable)
    --check         validate the run: every lifecycle phase present on
                    sampled describe-traces, Perfetto output is strict
                    JSON with ts/dur >= 0, and span-derived host-free
                    agrees with WaitStats within 5%.  Exit nonzero on any
                    failure (the CI trace-smoke gate).
    --json          emit the analysis as JSON instead of tables
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.core import QueueFull, make_device  # noqa: E402
from repro.obs import (  # noqa: E402
    PHASES,
    critical_path,
    host_free_fraction,
    phase_breakdown,
    slowest,
    to_perfetto,
)

#: fig2-style transfer sizes (bytes) for the burst workload
SIZES = [4096, 65536, 1 << 20]


# --------------------------------------------------------------------- workloads
def run_burst(rate: float, n: int):
    """Mixed-size copy/CRC burst with after= chains and then() tails."""
    device = make_device(n_instances=2, trace=rate)
    bufs = [jnp.zeros((max(s // 512, 1), 128), jnp.float32) for s in SIZES]
    futs = []
    prev = None
    for i in range(n):
        buf = bufs[i % len(SIZES)]
        after = [prev] if prev is not None and i % 4 == 1 else None
        try:
            if i % 4 == 3:
                fut = device.crc32_async(buf, after=after)
            else:
                fut = device.memcpy_async(buf, after=after)
        except QueueFull:
            device.wait_all(futs)
            futs = []
            continue
        if i % 8 == 2:
            futs.append(fut.then(lambda r: r))  # host continuation span
        futs.append(fut)
        prev = fut
    if futs:
        device.wait_all(futs)
    device.drain()
    return device


def run_openloop(rate: float, horizon_s: float):
    """Short open-loop serving run with request-scoped trace contexts."""
    from repro.serving import (
        AdmissionController,
        LatencyTracker,
        NullDecoder,
        PoissonArrivals,
        TrafficGenerator,
        VhostStyleServer,
        ZipfLengths,
    )

    device = make_device(n_instances=2, trace=rate)
    server = VhostStyleServer(
        NullDecoder(64), {}, slots=4, max_cache_len=128, device=device,
        admission=AdmissionController(), tracker=LatencyTracker())
    traffic = TrafficGenerator(
        PoissonArrivals(rate_rps=200.0, seed=7),
        prompt_lengths=ZipfLengths(lo=4, hi=32),
        output_lengths=ZipfLengths(lo=1, hi=8), seed=7)
    server.run_open_loop(traffic, horizon_s, step_s=0.01)
    device.drain()
    return device


# --------------------------------------------------------------------- reports
def summary_report(tracer) -> dict:
    return {
        "phases": phase_breakdown(tracer),
        "critical_path": critical_path(tracer),
        "host_free": host_free_cross_check(tracer),
        "slowest": [
            {"desc_id": dt.desc_id, "trace_id": dt.trace_id, "op": dt.op,
             "duration_s": dt.duration_s}
            for dt in slowest(tracer)
        ],
        "n_traces": len(tracer.traces()),
        "n_edges": len(tracer.edges()),
    }


def host_free_cross_check(tracer) -> dict:
    """Host-free fraction two ways: from the tracer's wait-span counters
    (span-derived) and from the same numbers WaitPolicy billed into the
    device WaitStats buckets — identical by construction, so any drift
    flags an instrumentation bug."""
    spans_frac = host_free_fraction(tracer)
    busy = free = 0.0
    for w in tracer.wait_spans():
        busy += w.busy_s
        free += w.free_s
    total = busy + free
    waitstats_frac = (free / total) if total > 0 else None
    delta = (abs(spans_frac - waitstats_frac)
             if spans_frac is not None and waitstats_frac is not None
             else None)
    return {"spans": spans_frac, "waitstats": waitstats_frac, "delta": delta}


def print_report(report: dict, top: int) -> None:
    print("phase breakdown:")
    hdr = (f"  {'PHASE':<16s} {'COUNT':>6s} {'MEAN-us':>9s} {'P95-us':>9s} "
           f"{'TOTAL-ms':>9s} {'SHARE':>6s}")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    for phase in PHASES:
        s = report["phases"].get(phase)
        if not s or not s["count"]:
            continue
        print(f"  {phase:<16s} {int(s['count']):>6d} {s['mean_s'] * 1e6:>9.2f} "
              f"{s['p95_s'] * 1e6:>9.2f} {s['total_s'] * 1e3:>9.3f} "
              f"{s['share']:>6.1%}")

    cp = report["critical_path"]
    if cp["chain"]:
        print(f"\ncritical path: {len(cp['chain'])} descriptor(s) "
              f"[{' -> '.join(str(d) for d in cp['chain'])}], "
              f"{cp['total_s'] * 1e3:.3f} ms on-path of "
              f"{cp['elapsed_s'] * 1e3:.3f} ms elapsed")
        for phase in PHASES:
            sec = cp["phases"].get(phase, 0.0)
            if sec > 0:
                print(f"  {phase:<16s} {sec * 1e3:>9.3f} ms "
                      f"{cp['shares'].get(phase, 0.0):>6.1%}")

    hf = report["host_free"]
    if hf["spans"] is not None:
        print(f"\nhost-free fraction: spans={hf['spans']:.4f} "
              f"waitstats={hf['waitstats']:.4f} delta={hf['delta']:.2e}")
    else:
        print("\nhost-free fraction: no wait spans recorded")

    if report["slowest"]:
        print(f"\nslowest descriptors (top {top}):")
        for row in report["slowest"][:top]:
            print(f"  desc {row['desc_id']:<6d} {row['op']:<14s} "
                  f"trace={row['trace_id']:<12s} "
                  f"{row['duration_s'] * 1e3:.3f} ms")
    print(f"\n{report['n_traces']} trace(s), {report['n_edges']} edge(s)")


# --------------------------------------------------------------------- checks
def run_checks(tracer, report: dict, perfetto_text: Optional[str]) -> List[str]:
    """Return a list of failure strings (empty == pass)."""
    fails: List[str] = []
    if not tracer.traces():
        fails.append("no traces retained")
    full = [dt for dt in tracer.traces()
            if dt.attrs.get("kind") != "then" and "error" not in dt.attrs]
    for dt in full:
        missing = [p for p in PHASES if p not in dt.phase_durations()]
        if missing:
            fails.append(f"desc {dt.desc_id}: missing phases {missing}")
    hf = report["host_free"]
    if hf["delta"] is None:
        fails.append("host-free cross-check impossible (no wait spans)")
    elif hf["spans"] and hf["delta"] > 0.05 * max(hf["spans"], 1e-12):
        fails.append(f"host-free drift {hf['delta']:.3e} exceeds 5% "
                     f"of {hf['spans']:.4f}")
    if perfetto_text is not None:
        try:
            doc = json.loads(perfetto_text)
        except ValueError as exc:
            fails.append(f"perfetto output is not strict JSON: {exc}")
        else:
            events = doc.get("traceEvents", [])
            if not events:
                fails.append("perfetto output has no traceEvents")
            for ev in events:
                if ev.get("ts", 0) < 0:
                    fails.append(f"negative ts in event {ev.get('name')}")
                if ev.get("dur", 0) < 0:
                    fails.append(f"negative dur in event {ev.get('name')}")
            slice_names = {ev["name"] for ev in events if ev.get("ph") == "X"}
            missing = [p for p in PHASES if p not in slice_names]
            if full and missing:
                fails.append(f"perfetto slices missing phases {missing}")
    return fails


# --------------------------------------------------------------------- main
def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="trace_view", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workload", choices=("burst", "openloop"),
                    default="burst")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="sampling rate in [0, 1] (default 1.0)")
    ap.add_argument("--descriptors", type=int, default=64,
                    help="burst size (default 64)")
    ap.add_argument("--horizon", type=float, default=0.5,
                    help="openloop virtual horizon seconds (default 0.5)")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest-descriptor table depth (default 5)")
    ap.add_argument("--perfetto", metavar="PATH", default=None,
                    help="write trace_event JSON to PATH")
    ap.add_argument("--check", action="store_true",
                    help="validate phases/Perfetto/host-free; nonzero on fail")
    ap.add_argument("--json", action="store_true",
                    help="emit the analysis as JSON")
    args = ap.parse_args(argv)

    if args.workload == "burst":
        device = run_burst(args.rate, args.descriptors)
    else:
        device = run_openloop(args.rate, args.horizon)
    tracer = device.tracer

    report = summary_report(tracer)
    perfetto_text = None
    if args.perfetto or args.check:
        perfetto_text = to_perfetto(tracer, args.perfetto)
        if args.perfetto and not args.json:
            print(f"wrote {args.perfetto}", file=sys.stderr)

    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True, default=str))
    else:
        print_report(report, args.top)

    if args.check:
        fails = run_checks(tracer, report, perfetto_text)
        if fails:
            for f in fails:
                print(f"CHECK FAIL: {f}", file=sys.stderr)
            return 1
        print("all trace checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
