"""Logical-axis sharding rules with divisibility-aware fallback.

Models annotate tensors with *logical* dimension names ("batch", "heads",
"mlp", ...).  ``ShardingRules`` maps logical names to mesh axes and resolves a
concrete ``PartitionSpec`` for a given shape.  A dimension that is not
divisible by its mesh-axes product silently falls back to replication — this
is what guarantees every (arch x shape x mesh) dry-run cell compiles even for
odd head counts (25) and odd vocabs (50280, 32001, 256206); the roofline
report then shows what the fallback costs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


def _as_tuple(a: Axes) -> Tuple[str, ...]:
    if a is None:
        return ()
    if isinstance(a, str):
        return (a,)
    return tuple(a)


@dataclass(frozen=True)
class ShardingRules:
    """logical dim name -> mesh axes."""

    mesh_axes: Dict[str, int]  # axis name -> size (from the mesh)
    table: Dict[str, Axes] = field(default_factory=dict)

    def axis_size(self, axes: Axes) -> int:
        return math.prod(self.mesh_axes[a] for a in _as_tuple(axes)) or 1

    def resolve_dim(self, dim_size: int, logical: Optional[str]) -> Axes:
        if logical is None:
            return None
        axes = self.table.get(logical)
        if axes is None:
            return None
        n = self.axis_size(axes)
        if n <= 1 or dim_size % n != 0:
            return None  # divisibility fallback -> replicate this dim
        t = _as_tuple(axes)
        return t[0] if len(t) == 1 else t

    def spec(self, shape: Sequence[int], logical_dims: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(logical_dims), (shape, logical_dims)
        used: set = set()
        parts = []
        for dim, name in zip(shape, logical_dims):
            ax = self.resolve_dim(dim, name)
            # one mesh axis may appear at most once in a spec
            t = _as_tuple(ax)
            if any(a in used for a in t):
                ax = None
                t = ()
            used.update(t)
            parts.append(ax)
        return P(*parts)

    def with_overrides(self, **table_updates: Axes) -> "ShardingRules":
        new = dict(self.table)
        new.update(table_updates)
        return replace(self, table=new)


def rules_for_mesh(mesh: Mesh, overrides: Optional[Dict[str, Axes]] = None) -> ShardingRules:
    """Default production rules.

    batch  -> all data-like axes ("pod","data")
    model-parallel dims ("heads", "kv_heads", "mlp", "vocab", "expert",
    "dinner") -> "model".  "seq" is unsharded by default; the long-context
    decode hillclimb overrides it to "data" (sequence-parallel KV).
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    table: Dict[str, Axes] = {
        "batch": data_axes if data_axes else None,
        "seq": None,
        "embed": None,
        "heads": "model" if "model" in axes else None,
        "kv_heads": "model" if "model" in axes else None,
        "qkv_flat": "model" if "model" in axes else None,
        "mlp": "model" if "model" in axes else None,
        "expert_ff": "model" if "model" in axes else None,
        "vocab": "model" if "model" in axes else None,
        "embed_alt": "model" if "model" in axes else None,  # fallback for odd vocab
        "expert": "model" if "model" in axes else None,
        "dinner": "model" if "model" in axes else None,
        "dstate": None,
        "opt": None,  # ZeRO-1: override to data axes to shard optimizer state
    }
    if overrides:
        table.update(overrides)
    return ShardingRules(mesh_axes=axes, table=table)


def named_sharding(mesh: Mesh, rules: ShardingRules, shape, logical_dims) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(shape, logical_dims))
