"""Activation sharding annotations resolved against a context-set mesh+rules.

``ann(x, "batch", None, "heads", None)`` applies a
``with_sharding_constraint`` when a mesh context is active, and is a no-op
otherwise (so the same model code runs in single-device smoke tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import ShardingRules

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: ShardingRules):
    prev = _current()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def logical_sharding(shape, logical_dims) -> Optional[NamedSharding]:
    ctx = _current()
    if ctx is None:
        return None
    mesh, rules = ctx
    return NamedSharding(mesh, rules.spec(shape, logical_dims))


def ann(x: jax.Array, *logical_dims):
    """Constrain ``x``'s sharding by logical dim names (None = unsharded)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.spec(x.shape, logical_dims)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
