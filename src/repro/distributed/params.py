"""Path-pattern -> logical-dims mapping for parameter / cache / batch pytrees.

Names are assigned by the model code; dims are padded on the left with None
for stacked (scanned) prefixes.  The fallback chain for embeddings
(vocab-shard -> d_model-shard -> replicate) is resolved here against the
actual shapes, so odd vocabs (50280, 32001, 256206) never fail.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.distributed.sharding import ShardingRules

# name -> trailing logical dims
_BASE = {
    "wq": (None, "qkv_flat"),
    "wk": (None, "qkv_flat"),
    "wv": (None, "qkv_flat"),
    "wo": ("qkv_flat", None),
    "w1": (None, "mlp"),
    "w3": (None, "mlp"),
    "w2": ("mlp", None),
    "shared_w1": (None, "mlp"),
    "shared_w3": (None, "mlp"),
    "shared_w2": ("mlp", None),
    "router": (None, None),
    "in_proj": (None, "dinner"),
    "out_proj": ("dinner", None),
    "conv_w": (None, None),
    "conv_b": (None,),
    "dt_bias": (None,),
    "A_log": (None,),
    "D": (None,),
    "out_norm": (None,),
    "meta_tokens": (None, None),
    # caches
    "k": ("batch", "seq", "kv_heads", None),
    "v": ("batch", "seq", "kv_heads", None),
    "cross_k": ("batch", None, "kv_heads", None),
    "cross_v": ("batch", None, "kv_heads", None),
    "pos": ("batch", None),
    "ssm_state": ("batch", "dinner", None, None),
    "conv_state": ("batch", None, None),
    "lengths": ("batch",),
    # batches
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "loss_mask": ("batch", None),
    "patch_embeds": ("batch", None, "embed"),
    "positions_thw": (None, "batch", None),
    "frame_embeds": ("batch", None, "embed"),
}

_MOE_OVERRIDES = {
    # "fsdp" resolves to the data axes only when a cell's rules enable it
    # (llama4-scale experts); otherwise it is absent from the table -> None.
    # "expert_ff" defaults to the same axis as "mlp" but can be remapped
    # independently (llama4 decode: experts over model x FF over data while
    # dense-layer MLPs stay TP over model — EXPERIMENTS.md §Perf cell C).
    "w1": ("expert", "fsdp", "expert_ff"),
    "w3": ("expert", "fsdp", "expert_ff"),
    "w2": ("expert", "expert_ff", "fsdp"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for part in path:
        if isinstance(part, DictKey):
            names.append(str(part.key))
        elif isinstance(part, SequenceKey):
            names.append(f"[{part.idx}]")
    return tuple(names)


def logical_dims(path, leaf, rules: ShardingRules) -> Tuple[Optional[str], ...]:
    names = _path_names(path)
    name = names[-1] if names else ""
    ndim = len(leaf.shape)
    tp = rules.axis_size(rules.table.get("vocab"))

    if name == "embed":
        V, D = leaf.shape[-2], leaf.shape[-1]
        base = ("vocab", None) if tp > 1 and V % tp == 0 else (None, "embed_alt")
    elif name == "unembed":
        D, V = leaf.shape[-2], leaf.shape[-1]
        base = (None, "vocab") if tp > 1 and V % tp == 0 else ("embed_alt", None)
    elif name in _MOE_OVERRIDES and "moe" in names:
        base = _MOE_OVERRIDES[name]
    elif name in _BASE:
        base = _BASE[name]
    else:
        base = ()  # norms / unknowns -> replicate

    if len(base) > ndim:
        base = base[-ndim:]
    return (None,) * (ndim - len(base)) + tuple(base)


def tree_pspecs(tree: Any, rules: ShardingRules) -> Any:
    """Same-structure tree of PartitionSpec."""

    def f(path, leaf):
        dims = logical_dims(path, leaf, rules)
        return rules.spec(leaf.shape, dims)

    return jax.tree_util.tree_map_with_path(f, tree)


def tree_shardings(tree: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    specs = tree_pspecs(tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def zero1_pspec(param_spec: P, shape: Tuple[int, ...], rules: ShardingRules) -> P:
    """ZeRO-1: additionally shard one replicated dim of the optimizer moment
    over the data axes (the master copy of the param stays as-is).  Falls
    back to the param's spec when no dim is divisible."""
    data_axes = rules.table.get("batch")
    if data_axes is None:
        return param_spec
    n = rules.axis_size(data_axes)
    if n <= 1:
        return param_spec
    parts = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = {a for p in parts if p is not None for a in ((p,) if isinstance(p, str) else p)}
    from repro.distributed.sharding import _as_tuple

    da = _as_tuple(data_axes)
    if any(a in used for a in da):
        return param_spec
    # pick the largest divisible unsharded dim
    best, best_size = -1, 0
    for i, (d, p) in enumerate(zip(shape, parts)):
        if p is None and d % n == 0 and d > best_size:
            best, best_size = i, d
    if best < 0:
        return param_spec
    parts[best] = da[0] if len(da) == 1 else tuple(da)
    return P(*parts)


def opt_state_shardings(opt_state_abs, params_abs, mesh: Mesh, rules: ShardingRules, zero1: bool = True):
    """Shardings for AdamWState(step, m, v) given abstract params."""
    p_specs = tree_pspecs(params_abs, rules)

    def moment(spec_tree):
        def f(spec, p):
            s = zero1_pspec(spec, p.shape, rules) if zero1 else spec
            return NamedSharding(mesh, s)

        return jax.tree.map(f, p_specs, params_abs)

    import repro.optim.adamw as adamw

    return adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        m=moment(p_specs),
        v=moment(p_specs),
    )
