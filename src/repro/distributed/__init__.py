from repro.distributed.annotate import ann, logical_sharding, use_rules
from repro.distributed.sharding import ShardingRules, rules_for_mesh

__all__ = ["ann", "logical_sharding", "use_rules", "ShardingRules", "rules_for_mesh"]
