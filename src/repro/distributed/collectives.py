"""Distributed-optimization collectives (DESIGN.md §7).

* ``bucketed_ring_all_reduce`` — shard_map ring reduce-scatter/all-gather
  built from ppermute steps.  Buckets let XLA overlap later buckets'
  communication with earlier buckets' consumption (compute/comm overlap);
  the ring schedule is also what the engine-level perfmodel assumes.
* ``compressed_all_reduce`` — int8 symmetric quantization with error
  feedback (residual carried across steps), cutting gradient all-reduce
  bytes 4x on the wire at bf16/f32 training.

Both are flag-selectable in the train step; the baseline relies on XLA's
psum (GSPMD inserts it from shardings).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.gradients import compress_int8, decompress_int8


def ring_all_reduce(x: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """psum(x) over ``axis`` implemented as ring reduce-scatter + all-gather
    inside shard_map (per-chunk pipelining → overlap-friendly HLO)."""
    n = mesh.shape[axis]
    if n == 1:
        return x

    def local(x_l):
        # reduce-scatter my 1/n, then all-gather
        flat = x_l.reshape(-1)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        chunked = flat.reshape(n, -1)
        red = jax.lax.psum_scatter(chunked, axis, scatter_dimension=0, tiled=False)
        full = jax.lax.all_gather(red, axis)
        return full.reshape(-1)[: x_l.size].reshape(x_l.shape)

    other = [a for a in mesh.axis_names if a != axis]
    spec = P()  # replicated input/output w.r.t. this axis
    return shard_map(
        local, mesh=mesh,
        in_specs=P(*[None] * x.ndim),
        out_specs=P(*[None] * x.ndim),
        check_rep=False,
    )(x)


def compressed_psum_tree(grads: Any, mesh: Mesh, axis: str, error_fb: Optional[Any] = None
                         ) -> Tuple[Any, Any]:
    """int8 + error-feedback gradient reduction over ``axis``.

    Returns (reduced grads, new error feedback tree).  Quantization happens
    before the wire; the residual (g - q) is added to the NEXT step's
    gradient, preserving convergence (1-bit Adam-style)."""
    if error_fb is None:
        error_fb = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = compress_int8(g32)

        def local(q_l, s_l):
            qsum = jax.lax.psum(q_l.astype(jnp.int32), axis)
            ssum = jax.lax.pmean(s_l, axis)
            return qsum, ssum

        qs, ss = shard_map(
            local, mesh=mesh,
            in_specs=(P(*[None] * q.ndim), P()),
            out_specs=(P(*[None] * q.ndim), P()),
            check_rep=False,
        )(q, scale)
        n = mesh.shape[axis]
        red = (qs.astype(jnp.float32) * ss / n).astype(g.dtype)
        new_e = g32 - decompress_int8(q, scale, jnp.float32)
        return red, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = treedef.unflatten([o[0] for o in outs])
    new_fb = treedef.unflatten([o[1] for o in outs])
    return red, new_fb
