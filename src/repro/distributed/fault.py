"""Fault tolerance & straggler mitigation for the multi-pod launcher
(DESIGN.md §7).

On a real cluster each host runs one process (jax.distributed); here the
protocol is exercised with simulated ranks.  Components:

* ``Heartbeat`` — per-rank liveness file updated every step; the monitor
  declares a rank dead after ``timeout_s`` and triggers restart-from-
  checkpoint (the driver owns the restart).
* ``StragglerDetector`` — per-rank step-time EWMA + z-score over the fleet;
  persistent outliers are flagged with a pluggable policy (log / exclude).
* ``RestartPolicy`` — bounded restarts with exponential backoff, always from
  the newest CRC-valid checkpoint (CheckpointManager.restore already skips
  corrupt saves).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional


class Heartbeat:
    def __init__(self, directory: str, rank: int):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.rank = rank
        self.path = self.dir / f"rank_{rank:05d}.hb"

    def beat(self, step: int):
        self.path.write_text(json.dumps({"step": step, "t": time.time()}))


class HeartbeatMonitor:
    def __init__(self, directory: str, world_size: int, timeout_s: float = 60.0):
        self.dir = Path(directory)
        self.world_size = world_size
        self.timeout_s = timeout_s

    def dead_ranks(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        dead = []
        for r in range(self.world_size):
            p = self.dir / f"rank_{r:05d}.hb"
            if not p.exists():
                dead.append(r)
                continue
            try:
                t = json.loads(p.read_text())["t"]
            except (json.JSONDecodeError, KeyError):
                dead.append(r)
                continue
            if now - t > self.timeout_s:
                dead.append(r)
        return dead

    def all_alive(self) -> bool:
        return not self.dead_ranks()


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step time per rank + fleet z-score flagging."""

    alpha: float = 0.2
    z_threshold: float = 3.0
    min_samples: int = 8
    _ewma: Dict[int, float] = dataclasses.field(default_factory=dict)
    _count: Dict[int, int] = dataclasses.field(default_factory=dict)

    def record(self, rank: int, step_time_s: float):
        prev = self._ewma.get(rank, step_time_s)
        self._ewma[rank] = (1 - self.alpha) * prev + self.alpha * step_time_s
        self._count[rank] = self._count.get(rank, 0) + 1

    def stragglers(self) -> List[int]:
        ranks = [r for r, c in self._count.items() if c >= self.min_samples]
        if len(ranks) < 4:
            return []
        vals = [self._ewma[r] for r in ranks]
        mean = sum(vals) / len(vals)
        var = sum((v - mean) ** 2 for v in vals) / len(vals)
        std = max(var ** 0.5, 1e-9)
        return [r for r in ranks if (self._ewma[r] - mean) / std > self.z_threshold]


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 16
    backoff_base_s: float = 0.1
    backoff_max_s: float = 60.0
    _restarts: int = 0

    def should_restart(self) -> bool:
        return self._restarts < self.max_restarts

    def backoff(self) -> float:
        d = min(self.backoff_base_s * (2 ** self._restarts), self.backoff_max_s)
        self._restarts += 1
        return d


def run_with_restarts(
    train_fn: Callable[[int], int],
    checkpointed_step: Callable[[], Optional[int]],
    policy: Optional[RestartPolicy] = None,
    sleep=time.sleep,
) -> int:
    """Driver loop: run train_fn(start_step); on failure, back off and resume
    from the newest valid checkpoint.  Returns the final step reached."""
    policy = policy or RestartPolicy()
    start = checkpointed_step() or 0
    while True:
        try:
            return train_fn(start)
        except Exception as e:  # noqa: BLE001 — node failure analogue
            if not policy.should_restart():
                raise
            sleep(policy.backoff())
            start = checkpointed_step() or 0
            print(f"[fault] restarting from step {start} after {type(e).__name__}: {e}")
