"""Mamba-2 / SSD (state-space duality) mixer — chunked training form and the
O(1)-state decode recurrence.  Follows the minimal SSD reference from
arXiv:2405.21060, adapted to chunk-parallel JAX (matmul-heavy intra-chunk
"attention" form on the MXU + lax.scan inter-chunk recurrence).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.distributed.annotate import ann


def segsum(x: jax.Array) -> jax.Array:
    """x [..., T] -> [..., T, T]; out[i,j] = sum_{k=j+1..i} x[k] (i>=j) else -inf."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # [B, S, H, P] (already multiplied by dt)
    a_bar: jax.Array,  # [B, S, H]  (A * dt, negative)
    b: jax.Array,  # [B, S, G, N]
    c: jax.Array,  # [B, S, G, N]
    chunk: int,
    initial_state=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    hpg = H // G  # heads per group

    xc = x.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    ac = a_bar.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2).astype(jnp.float32)  # [B,H,nc,c]
    bc = b.reshape(B, nc, chunk, G, N).astype(jnp.float32)
    cc = c.reshape(B, nc, chunk, G, N).astype(jnp.float32)
    # expand groups to heads
    bh = jnp.repeat(bc, hpg, axis=3)  # [B,nc,c,H,N]
    ch = jnp.repeat(cc, hpg, axis=3)

    a_cum = jnp.cumsum(ac, axis=-1)  # [B,H,nc,c]
    L = jnp.exp(segsum(ac))  # [B,H,nc,c,c]

    # intra-chunk (the "attention-like" quadratic-in-chunk term)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", ch, bh, L, xc)

    # per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # [B,H,nc,c]
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", bh, decay_states, xc)  # [B,nc,H,P,N]

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])  # [B,H,nc]
    s0 = (
        jnp.zeros((B, H, P, N), dtype=jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(state, inp):
        st_c, dec_c = inp  # [B,H,P,N], [B,H]
        prev = state
        state = state * dec_c[..., None, None] + st_c
        return state, prev

    final_state, prev_states = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    state_decay_out = jnp.exp(a_cum)  # [B,H,nc,c]
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", ch, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final_state


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """x [B, S, C]; w [K, C]; causal depthwise conv along S."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k : k + x.shape[1], :].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + bias.astype(jnp.float32)).astype(x.dtype)


def mamba2_mixer(
    x: jax.Array, p: dict, cfg: SSMConfig, d_model: int
) -> jax.Array:
    """Full Mamba-2 block mixer (training / prefill, no cache)."""
    y, _, _ = mamba2_mixer_with_state(x, p, cfg, d_model)
    return y


def mamba2_mixer_with_state(x: jax.Array, p: dict, cfg: SSMConfig, d_model: int):
    """Returns (y, final_ssm_state, final_conv_state)."""
    B, S, _ = x.shape
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = x @ p["in_proj"]  # [B,S, 2*di + 2*G*N + H]
    z, xs, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)  # [B,S, di + 2GN]
    conv_out = jax.nn.silu(_causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, b, c = jnp.split(conv_out, [di, di + G * N], axis=-1)
    xs = ann(xs, "batch", None, "dinner")

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]
    xh = xs.reshape(B, S, H, P)
    bg = b.reshape(B, S, G, N)
    cg = c.reshape(B, S, G, N)

    chunk = min(cfg.chunk_size, S)
    while S % chunk != 0:
        chunk //= 2
    y, final_state = ssd_chunked(xh.astype(jnp.float32) * dt[..., None], A[None, None, :] * dt, bg, cg, chunk)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.layers import rms_norm

    y = rms_norm(y, p["out_norm"], 1e-6)
    conv_state = conv_in[:, -(cfg.d_conv - 1) :, :] if S >= cfg.d_conv - 1 else jnp.pad(
        conv_in, ((0, 0), (cfg.d_conv - 1 - S, 0), (0, 0))
    )
    return y @ p["out_proj"], final_state, conv_state


def mamba2_decode_step(
    x: jax.Array,  # [B, D]
    state: jax.Array,  # [B, H, P, N]
    conv_state: jax.Array,  # [B, d_conv-1, di+2GN]
    p: dict,
    cfg: SSMConfig,
    d_model: int,
):
    """Single-token recurrent update.  Returns (y [B,D], state, conv_state)."""
    B, _ = x.shape
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = x @ p["in_proj"]
    z, xs, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)  # [B, di+2GN]
    # causal conv over (conv_state ++ conv_in)
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # [B, K, C]
    w = p["conv_w"].astype(jnp.float32)  # [K, C]
    conv_out = jax.nn.silu(
        (window.astype(jnp.float32) * w[None]).sum(axis=1) + p["conv_b"].astype(jnp.float32)
    ).astype(x.dtype)
    xs, b, c = jnp.split(conv_out, [di, di + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    bg = jnp.repeat(b.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)  # [B,H,N]
    cg = jnp.repeat(c.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)

    decay = jnp.exp(A[None] * dt)  # [B,H]
    state = state.astype(jnp.float32) * decay[..., None, None] + (
        (dt[..., None] * xh)[..., None] * bg[:, :, None, :]
    )
    y = (state * cg[:, :, None, :]).sum(-1) + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.layers import rms_norm

    y = rms_norm(y, p["out_norm"], 1e-6)
    new_conv_state = window[:, 1:, :]
    return y @ p["out_proj"], state, new_conv_state


def init_mamba2_params(rng, cfg: SSMConfig, d_model: int, dtype) -> dict:
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    G, N = cfg.n_groups, cfg.d_state
    k = jax.random.split(rng, 4)
    proj_out = 2 * di + 2 * G * N + H
    scale = d_model ** -0.5
    return {
        "in_proj": (jax.random.normal(k[0], (d_model, proj_out)) * scale).astype(dtype),
        "conv_w": (jax.random.normal(k[1], (cfg.d_conv, di + 2 * G * N)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * G * N,), dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, H))).astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "out_norm": jnp.zeros((di,), dtype=dtype),
        "out_proj": (jax.random.normal(k[2], (di, d_model)) * di ** -0.5).astype(dtype),
    }
