"""Shared neural-net layers: norms, rotary embeddings (incl. M-RoPE), gated
MLPs, and memory-efficient attention.

Everything here is a pure function over explicit parameter pytrees — no
framework modules.  Attention comes in two forms:

* ``attention``       — training/prefill, online-softmax chunked over KV blocks
                        (flash-attention schedule in pure JAX; the quadratic
                        score matrix never materializes for long sequences).
* ``decode_attention`` — single-token decode against a (full or ring-buffer)
                        KV cache with explicit per-sequence length masks.

Block sizes are static python ints, so causal/window block skipping is
resolved at trace time (no dynamic control flow).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.annotate import ann

NEG_INF = -1e30


# --------------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dt)


# --------------------------------------------------------------------------- rope
def rope_cos_sin(
    positions: jax.Array, head_dim: int, theta: float, dtype=jnp.float32
) -> Tuple[jax.Array, jax.Array]:
    """positions [...,] -> cos/sin [..., head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, S, H, hd]; cos/sin [B, S, hd//2] (broadcast over heads)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def mrope_cos_sin(
    positions_thw: jax.Array,
    head_dim: int,
    theta: float,
    sections: Tuple[int, int, int],
) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions_thw [3, B, S] -> cos/sin [B, S, hd//2].

    The hd//2 frequency slots are partitioned into (t, h, w) sections; each
    section rotates by its own position stream.  Text tokens set t=h=w.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang_all = positions_thw.astype(jnp.float32)[..., None] * freqs  # [3, B, S, half]
    pieces = []
    start = 0
    for i, sec in enumerate(sections):
        pieces.append(ang_all[i, ..., start : start + sec])
        start += sec
    ang = jnp.concatenate(pieces, axis=-1)  # [B, S, half]
    return jnp.cos(ang), jnp.sin(ang)


# --------------------------------------------------------------------------- mlp
def gated_mlp(x: jax.Array, p: dict, act: str = "silu", tp_comm: str = "auto") -> jax.Array:
    """SwiGLU/GeGLU MLP.  p = {w1 [D,F], w3 [D,F], w2 [F,D]}.

    tp_comm="manual_bf16": run the whole TP block in shard_map with an
    explicit bf16 cast on the row-parallel partial sums — GSPMD otherwise
    all-reduces the f32 matmul ACCUMULATOR, doubling wire bytes
    (EXPERIMENTS.md §Perf cell A iter 2)."""
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    if tp_comm == "manual_bf16":
        out = _tp_block_manual(x, p, fn)
        if out is not None:
            return out
    h = fn(x @ p["w1"]) * (x @ p["w3"])
    h = ann(h, "batch", None, "mlp")
    return h @ p["w2"]


def _tp_block_manual(x, p, fn):
    """Megatron-style column+row parallel MLP with bf16 wire; returns None
    when the mesh/rules context is absent or the FF dim isn't model-sharded."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.annotate import _current

    ctx = _current()
    if ctx is None:
        return None
    mesh, rules = ctx
    w1_spec = rules.spec(p["w1"].shape, (None, "mlp"))
    if w1_spec[1] is None:
        return None
    x_spec = rules.spec(x.shape, ("batch", None, None))

    def local(x_l, w1_l, w3_l, w2_l):
        h = fn(x_l @ w1_l) * (x_l @ w3_l)
        part = (h @ w2_l).astype(x_l.dtype)  # cast BEFORE the wire
        return jax.lax.psum(part, w1_spec[1])

    return shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, w1_spec, w1_spec, P(w1_spec[1], None)),
        out_specs=x_spec, check_rep=False,
    )(x, p["w1"], p["w3"], p["w2"])


def row_parallel_out(o_flat: jax.Array, wo: jax.Array, tp_comm: str = "auto") -> jax.Array:
    """Attention output projection [B,S,H*hd] @ [H*hd,D], row-parallel with
    bf16-wire psum when tp_comm="manual_bf16" (same rationale as gated_mlp)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.annotate import _current

    ctx = _current()
    if tp_comm != "manual_bf16" or ctx is None:
        return o_flat @ wo
    mesh, rules = ctx
    wo_spec = rules.spec(wo.shape, ("qkv_flat", None))
    if wo_spec[0] is None:
        return o_flat @ wo
    o_spec = rules.spec(o_flat.shape, ("batch", None, "qkv_flat"))
    if o_spec[2] is None:
        return o_flat @ wo
    out_spec = P(o_spec[0], None, None)

    def local(o_l, w_l):
        part = (o_l @ w_l).astype(o_l.dtype)
        return jax.lax.psum(part, wo_spec[0])

    return shard_map(local, mesh=mesh, in_specs=(o_spec, wo_spec),
                     out_specs=out_spec, check_rep=False)(o_flat, wo)


# --------------------------------------------------------------------------- attention
def _pick_block(seq: int, target: int = 512) -> int:
    """Largest divisor of ``seq`` that is <= target (prefers multiples of 128)."""
    best = 1
    for b in range(1, min(seq, target) + 1):
        if seq % b == 0:
            best = b
    return best


def _mask_block(
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool,
    window: int,
    n_meta: int,
) -> jax.Array:
    """[q_blk, kv_blk] boolean mask."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        in_window = (qp - kp) < window
        if n_meta > 0:
            in_window |= kp < n_meta  # meta tokens are always attendable
        m &= in_window
    return m


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    n_meta: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    max_block: int = 512,
) -> jax.Array:
    """Chunked online-softmax attention (training / prefill).

    q [B, Sq, H, hd]; k, v [B, Skv, KV, hd] with H % KV == 0 (GQA).
    Returns [B, Sq, H, hd].  ``q_offset`` is the absolute position of q[0]
    relative to k[0] (used by enc-dec / prefix setups; 0 for self-attn).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(B, Sq, KV, G, hd)

    # Small sequences: one dense block.
    if Sq * Skv <= 1024 * 1024:
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
        s *= scale
        mask = _mask_block(
            jnp.arange(Sq) + q_offset, jnp.arange(Skv), causal=causal, window=window, n_meta=n_meta
        )
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
        return o.reshape(B, Sq, H, hd)

    q_blk = _pick_block(Sq, max_block)
    kv_blk = _pick_block(Skv, max_block)
    n_q = Sq // q_blk

    def kv_step(carry, kv_i, qb, q_pos):
        m, l, acc = carry
        k_b = jax.lax.dynamic_slice_in_dim(k, kv_i * kv_blk, kv_blk, axis=1)
        v_b = jax.lax.dynamic_slice_in_dim(v, kv_i * kv_blk, kv_blk, axis=1)
        k_pos = kv_i * kv_blk + jnp.arange(kv_blk)
        # operands stay bf16 on the wire; the MXU accumulates in f32
        # (preferred_element_type) — halves attention HBM traffic vs
        # materializing f32 copies (EXPERIMENTS.md §Perf cell A iter 1)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb, k_b, preferred_element_type=jnp.float32)
        s *= scale
        mask = _mask_block(q_pos, k_pos, causal=causal, window=window, n_meta=n_meta)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(v.dtype), v_b, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    outs = []
    for qi in range(n_q):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * q_blk, q_blk, axis=1)
        q_pos = qi * q_blk + jnp.arange(q_blk) + q_offset
        q_end = (qi + 1) * q_blk - 1 + q_offset
        q_start = qi * q_blk + q_offset
        # static block skipping: causal upper bound and window lower bound
        kv_hi = min((q_end // kv_blk) + 1, Skv // kv_blk) if causal else Skv // kv_blk
        kv_lo = 0
        if window > 0:
            kv_lo = max(0, (q_start - window + 1) // kv_blk)
        n_meta_blocks = (n_meta + kv_blk - 1) // kv_blk if n_meta > 0 else 0
        idxs = list(range(min(n_meta_blocks, kv_lo))) + list(range(kv_lo, kv_hi))
        m0 = jnp.full((B, KV, G, q_blk), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_blk), dtype=jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_blk, hd), dtype=jnp.float32)

        step = jax.checkpoint(lambda c, i: kv_step(c, i, qb, q_pos))
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.asarray(idxs, dtype=jnp.int32))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, q_blk, H, hd).astype(v.dtype))
    return jnp.concatenate(outs, axis=1)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid_mask: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a cache.

    q [B, H, hd]; k_cache/v_cache [B, S, KV, hd]; valid_mask [B, S] bool.
    """
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32))
    s *= scale
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(v_cache.dtype)


# --------------------------------------------------------------------------- flash wrapper
def _flash_call(q, k, v, causal, window, n_meta):
    """Flash kernel, shard_map'd when a mesh context is active.

    Standard TPU deployment: the kernel runs per-device on its local
    (batch x head) shard; KV stays as-sharded/replicated (GQA KV heads are
    replicated whenever KV % tp != 0, so every q-head shard has its K/V).
    Falls back to a direct call when dims don't divide.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.annotate import _current
    from repro.kernels.flash_attention import flash_attention

    ctx = _current()
    kernel = functools.partial(
        flash_attention, causal=causal, window=window, n_meta=n_meta
    )
    if ctx is None:
        return kernel(q, k, v)
    mesh, rules = ctx
    q_spec = rules.spec(q.shape, ("batch", None, "heads", None))
    kv_spec = rules.spec(k.shape, ("batch", None, "kv_heads", None))
    # local shapes must keep GQA consistent: if KV ends up sharded but heads
    # replicated (or group mismatch), fall back to the direct call
    def _size(entry):
        return rules.axis_size(entry)

    h_shard = _size(q_spec[2])
    kv_shard = _size(kv_spec[2])
    if kv_shard not in (1, h_shard):
        return kernel(q, k, v)
    return shard_map(
        kernel, mesh=mesh, in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec, check_rep=False,
    )(q, k, v)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def _flash_fwd_ref_bwd(q, k, v, causal, window, n_meta, q_offset):
    return _flash_call(q, k, v, causal, window, n_meta)


def _ffrb_fwd(q, k, v, causal, window, n_meta, q_offset):
    out = _flash_fwd_ref_bwd(q, k, v, causal, window, n_meta, q_offset)
    return out, (q, k, v)


def _ffrb_bwd(causal, window, n_meta, q_offset, res, g):
    q, k, v = res
    # reference bwd: recompute via the chunked-attention path and AD it.
    # (fwd + remat replays use the VMEM-resident kernel; only the true bwd
    # pays the chunked-path HBM traffic — see EXPERIMENTS.md §Perf.)
    _, vjp = jax.vjp(
        lambda q, k, v: attention(
            q, k, v, causal=causal, window=window, n_meta=n_meta, q_offset=q_offset
        ),
        q, k, v,
    )
    return vjp(g)


_flash_fwd_ref_bwd.defvjp(_ffrb_fwd, _ffrb_bwd)


def attention_trainable(
    q, k, v, *, causal: bool = True, window: int = 0, n_meta: int = 0,
    q_offset: int = 0, impl: str = "chunked",
):
    """Attention with a selectable implementation: "chunked" (pure JAX,
    baseline) or "flash" (Pallas kernel fwd, reference bwd)."""
    if impl == "flash":
        return _flash_fwd_ref_bwd(q, k, v, causal, window, n_meta, q_offset)
    return attention(q, k, v, causal=causal, window=window, n_meta=n_meta, q_offset=q_offset)


# --------------------------------------------------------------------------- qkv projection helpers
def project_qkv(x: jax.Array, p: dict, cfg, *, qk_norm_p: Optional[dict] = None):
    """x [B,S,D] -> q [B,S,H,hd], k,v [B,S,KV,hd] (+ optional per-head RMS qk-norm)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if qk_norm_p is not None:
        q = rms_norm(q, qk_norm_p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, qk_norm_p["k_norm"], cfg.norm_eps)
    q = ann(q, "batch", None, "heads", None)
    k = ann(k, "batch", None, "kv_heads", None)
    v = ann(v, "batch", None, "kv_heads", None)
    return q, k, v


def unembed(x: jax.Array, table: jax.Array, transpose: bool) -> jax.Array:
    """Logits head.  table is [V, D] if transpose (tied) else [D, V]."""
    w = table.T if transpose else table
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean CE over masked positions.  logits [B,S,V] f32, labels [B,S] i32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
