"""Transformer / SSM / hybrid block definitions.

Each block is a pair of pure functions:

* ``init_*_layer(rng, cfg, ...) -> params``  (single layer)
* ``apply_*(x, p, ctx, mode, cache) -> (x, aux, new_cache)``

``mode`` is one of "train" | "prefill" | "decode".  Caches are dicts of
arrays; in "prefill" the block writes a fresh cache, in "decode" it updates
one token in place.  All blocks are scan-compatible (uniform pytrees).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.annotate import ann
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through blocks (static + traced values)."""

    cfg: ModelConfig
    mesh: Any = None
    # rope tables: [B, S, hd//2] (train/prefill) or [B, 1, hd//2] (decode)
    cos_local: Any = None
    sin_local: Any = None
    cos_global: Any = None
    sin_global: Any = None
    lengths: Any = None  # [B] int32, tokens already in cache (decode)
    n_meta: int = 0
    moe_dispatch: str = "dense"
    max_cache_len: int = 0
    window: int = 0
    remat: bool = True
    causal: bool = True  # False for encoder stacks
    attn_impl: str = "chunked"  # "chunked" (baseline) | "flash" (Pallas)
    tp_comm: str = "auto"  # "auto" (GSPMD) | "manual_bf16" (shard_map TP, bf16 wire)

    def rope(self, layer_type: str):
        if layer_type == "global" and self.cos_global is not None:
            return self.cos_global, self.sin_global
        return self.cos_local, self.sin_local


# --------------------------------------------------------------------------- init helpers
def _dense(rng, shape, dtype, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def init_attn_params(rng, cfg: ModelConfig, dtype) -> dict:
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    k = jax.random.split(rng, 4)
    p = {
        "wq": _dense(k[0], (D, H * hd), dtype),
        "wk": _dense(k[1], (D, KV * hd), dtype),
        "wv": _dense(k[2], (D, KV * hd), dtype),
        "wo": _dense(k[3], (H * hd, D), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_mlp_params(rng, d_model: int, d_ff: int, dtype) -> dict:
    k = jax.random.split(rng, 3)
    return {
        "w1": _dense(k[0], (d_model, d_ff), dtype),
        "w3": _dense(k[1], (d_model, d_ff), dtype),
        "w2": _dense(k[2], (d_ff, d_model), dtype),
    }


# --------------------------------------------------------------------------- attention sub-block
def _init_attn_cache(cfg: ModelConfig, B: int, layer_type: str, ctx: Ctx, dtype) -> dict:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if layer_type == "local" and ctx.window > 0:
        Sc = ctx.n_meta + ctx.window
        return {
            "k": jnp.zeros((B, Sc, KV, hd), dtype),
            "v": jnp.zeros((B, Sc, KV, hd), dtype),
            "pos": jnp.full((B, Sc), -1, jnp.int32),
        }
    Sc = ctx.max_cache_len
    return {
        "k": jnp.zeros((B, Sc, KV, hd), dtype),
        "v": jnp.zeros((B, Sc, KV, hd), dtype),
    }


def attn_sub(
    x: jax.Array,
    p: dict,
    ctx: Ctx,
    layer_type: str,
    mode: str,
    cache: Optional[dict],
) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention sub-block (no residual/norm).  x [B,S,D] or [B,1,D]."""
    cfg = ctx.cfg
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qk_p = {"q_norm": p["q_norm"], "k_norm": p["k_norm"]} if cfg.qk_norm else None
    q, k, v = L.project_qkv(x, p, cfg, qk_norm_p=qk_p)
    cos, sin = ctx.rope(layer_type)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    window = ctx.window if layer_type == "local" else 0

    if mode in ("train", "prefill"):
        o = L.attention_trainable(
            q, k, v, causal=ctx.causal, window=window, n_meta=ctx.n_meta, impl=ctx.attn_impl
        )
        new_cache = None
        if mode == "prefill":
            new_cache = _write_prefill_cache(cfg, ctx, layer_type, k, v)
    else:  # decode: S == 1
        new_cache, k_all, v_all, valid = _decode_cache_update(
            cfg, ctx, layer_type, cache, k[:, 0], v[:, 0]
        )
        o = L.decode_attention(q[:, 0], k_all, v_all, valid)[:, None]
    o = ann(o, "batch", None, "heads", None)
    out = L.row_parallel_out(o.reshape(B, S, H * hd), p["wo"], ctx.tp_comm)
    return out, new_cache


def _write_prefill_cache(cfg: ModelConfig, ctx: Ctx, layer_type: str, k, v) -> dict:
    B, S = k.shape[0], k.shape[1]
    dtype = k.dtype
    if layer_type == "local" and ctx.window > 0:
        n_meta, W = ctx.n_meta, ctx.window
        Sc = n_meta + W
        ck = jnp.zeros((B, Sc, k.shape[2], k.shape[3]), dtype)
        cv = jnp.zeros_like(ck)
        cpos = jnp.full((B, Sc), -1, jnp.int32)
        if n_meta > 0:
            ck = ck.at[:, :n_meta].set(k[:, :n_meta])
            cv = cv.at[:, :n_meta].set(v[:, :n_meta])
            cpos = cpos.at[:, :n_meta].set(jnp.arange(n_meta)[None])
        body_len = S - n_meta
        take = min(W, body_len)
        # absolute positions of the last `take` body tokens
        pos = jnp.arange(S - take, S)
        slots = n_meta + (pos - n_meta) % W
        ck = ck.at[:, slots].set(k[:, S - take :])
        cv = cv.at[:, slots].set(v[:, S - take :])
        cpos = cpos.at[:, slots].set(pos[None])
        return {"k": ck, "v": cv, "pos": cpos}
    Sc = ctx.max_cache_len
    ck = jnp.zeros((B, Sc, k.shape[2], k.shape[3]), dtype)
    cv = jnp.zeros_like(ck)
    ck = ck.at[:, :S].set(k)
    cv = cv.at[:, :S].set(v)
    ck = ann(ck, "batch", "seq", "kv_heads", None)
    cv = ann(cv, "batch", "seq", "kv_heads", None)
    return {"k": ck, "v": cv}


def _decode_cache_update(cfg, ctx: Ctx, layer_type: str, cache: dict, k1, v1):
    """k1/v1 [B, KV, hd] for the current token at position ctx.lengths."""
    B = k1.shape[0]
    bidx = jnp.arange(B)
    pos = ctx.lengths  # [B]
    if layer_type == "local" and ctx.window > 0:
        n_meta, W = ctx.n_meta, ctx.window
        slot = jnp.where(pos < n_meta, pos, n_meta + (pos - n_meta) % W)
        ck = cache["k"].at[bidx, slot].set(k1)
        cv = cache["v"].at[bidx, slot].set(v1)
        cpos = cache["pos"].at[bidx, slot].set(pos)
        in_window = (pos[:, None] - cpos) < W
        is_meta = (cpos >= 0) & (cpos < n_meta)
        valid = (cpos >= 0) & (cpos <= pos[:, None]) & (in_window | is_meta)
        return {"k": ck, "v": cv, "pos": cpos}, ck, cv, valid
    ck = cache["k"].at[bidx, pos].set(k1)
    cv = cache["v"].at[bidx, pos].set(v1)
    ck = ann(ck, "batch", "seq", "kv_heads", None)
    cv = ann(cv, "batch", "seq", "kv_heads", None)
    valid = jnp.arange(ck.shape[1])[None] <= pos[:, None]
    return {"k": ck, "v": cv}, ck, cv, valid


# --------------------------------------------------------------------------- full blocks
def init_dense_layer(rng, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> dict:
    k = jax.random.split(rng, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attn_params(k[0], cfg, dtype),
        "mlp": init_mlp_params(k[1], cfg.d_model, d_ff or cfg.d_ff, dtype),
    }


def apply_dense(x, p, ctx: Ctx, layer_type: str, mode: str, cache=None):
    h, new_cache = attn_sub(L.rms_norm(x, p["ln1"], ctx.cfg.norm_eps), p["attn"], ctx, layer_type, mode, cache)
    x = x + h
    x = x + L.gated_mlp(L.rms_norm(x, p["ln2"], ctx.cfg.norm_eps), p["mlp"], ctx.cfg.act,
                        tp_comm=ctx.tp_comm)
    x = ann(x, "batch", None, "embed")
    return x, jnp.zeros((), jnp.float32), new_cache


def init_moe_layer(rng, cfg: ModelConfig, dtype) -> dict:
    k = jax.random.split(rng, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attn_params(k[0], cfg, dtype),
        "moe": moe_lib.init_moe_params(k[1], cfg.moe, cfg.d_model, dtype),
    }


def apply_moe(x, p, ctx: Ctx, layer_type: str, mode: str, cache=None):
    h, new_cache = attn_sub(L.rms_norm(x, p["ln1"], ctx.cfg.norm_eps), p["attn"], ctx, layer_type, mode, cache)
    x = x + h
    y, aux = moe_lib.moe_block(
        L.rms_norm(x, p["ln2"], ctx.cfg.norm_eps),
        p["moe"],
        ctx.cfg.moe,
        ctx.cfg.act,
        dispatch=ctx.moe_dispatch,
        mesh=ctx.mesh,
    )
    x = x + y
    x = ann(x, "batch", None, "embed")
    return x, aux, new_cache


def init_ssm_layer(rng, cfg: ModelConfig, dtype) -> dict:
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "mixer": ssm_lib.init_mamba2_params(rng, cfg.ssm, cfg.d_model, dtype),
    }


def _init_ssm_cache(cfg: ModelConfig, B: int, ssm_cfg, dtype) -> dict:
    H = ssm_cfg.n_heads(cfg.d_model)
    return {
        "ssm_state": jnp.zeros((B, H, ssm_cfg.head_dim, ssm_cfg.d_state), jnp.float32),
        "conv_state": jnp.zeros(
            (B, ssm_cfg.d_conv - 1, ssm_cfg.d_inner(cfg.d_model) + 2 * ssm_cfg.n_groups * ssm_cfg.d_state),
            dtype,
        ),
    }


def apply_ssm(x, p, ctx: Ctx, layer_type: str, mode: str, cache=None):
    cfg = ctx.cfg
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mode == "train":
        y = ssm_lib.mamba2_mixer(xn, p["mixer"], cfg.ssm, cfg.d_model)
        return x + y, jnp.zeros((), jnp.float32), None
    if mode == "prefill":
        y, state, conv_state = ssm_lib.mamba2_mixer_with_state(xn, p["mixer"], cfg.ssm, cfg.d_model)
        return x + y, jnp.zeros((), jnp.float32), {"ssm_state": state, "conv_state": conv_state}
    # decode
    y, state, conv_state = ssm_lib.mamba2_decode_step(
        xn[:, 0], cache["ssm_state"], cache["conv_state"], p["mixer"], cfg.ssm, cfg.d_model
    )
    return x + y[:, None], jnp.zeros((), jnp.float32), {"ssm_state": state, "conv_state": conv_state}


def init_hybrid_layer(rng, cfg: ModelConfig, dtype) -> dict:
    k = jax.random.split(rng, 3)
    di = cfg.hybrid.ssm.d_inner(cfg.d_model)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attn_params(k[0], cfg, dtype),
        "mixer": ssm_lib.init_mamba2_params(k[1], cfg.hybrid.ssm, cfg.d_model, dtype),
        "attn_out_norm": jnp.zeros((cfg.d_model,), dtype),
        "ssm_out_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp_params(k[2], cfg.d_model, cfg.d_ff, dtype),
    }


def apply_hybrid(x, p, ctx: Ctx, layer_type: str, mode: str, cache=None):
    """Hymba: attention heads and SSM heads run in PARALLEL on the same input;
    outputs are normalized and averaged (arXiv:2411.13676)."""
    cfg = ctx.cfg
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a_cache = cache.get("attn") if cache else None
    attn_out, new_a_cache = attn_sub(xn, p["attn"], ctx, layer_type, mode, a_cache)
    new_cache: Optional[dict] = None
    if mode == "train":
        ssm_out = ssm_lib.mamba2_mixer(xn, p["mixer"], cfg.hybrid.ssm, cfg.d_model)
    elif mode == "prefill":
        ssm_out, state, conv_state = ssm_lib.mamba2_mixer_with_state(
            xn, p["mixer"], cfg.hybrid.ssm, cfg.d_model
        )
        new_cache = {"attn": new_a_cache, "ssm": {"ssm_state": state, "conv_state": conv_state}}
    else:
        s_cache = cache["ssm"]
        y1, state, conv_state = ssm_lib.mamba2_decode_step(
            xn[:, 0], s_cache["ssm_state"], s_cache["conv_state"], p["mixer"], cfg.hybrid.ssm, cfg.d_model
        )
        ssm_out = y1[:, None]
        new_cache = {"attn": new_a_cache, "ssm": {"ssm_state": state, "conv_state": conv_state}}
    h = 0.5 * (
        L.rms_norm(attn_out, p["attn_out_norm"], cfg.norm_eps)
        + L.rms_norm(ssm_out, p["ssm_out_norm"], cfg.norm_eps)
    )
    x = x + h
    x = x + L.gated_mlp(L.rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg.act,
                        tp_comm=ctx.tp_comm)
    x = ann(x, "batch", None, "embed")
    return x, jnp.zeros((), jnp.float32), new_cache


def init_block_cache(cfg: ModelConfig, B: int, layer_type: str, ctx: Ctx, dtype) -> dict:
    """Cache structure for one layer (matches what prefill/decode produce)."""
    if cfg.family == "ssm":
        return _init_ssm_cache(cfg, B, cfg.ssm, dtype)
    if cfg.family == "hybrid":
        return {
            "attn": _init_attn_cache(cfg, B, layer_type, ctx, dtype),
            "ssm": _init_ssm_cache(cfg, B, cfg.hybrid.ssm, dtype),
        }
    return _init_attn_cache(cfg, B, layer_type, ctx, dtype)
