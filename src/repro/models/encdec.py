"""Encoder-decoder model (seamless-m4t): bidirectional encoder over stub
frame embeddings + causal decoder with cross-attention.

The speech frontend is a STUB per the assignment: ``frame_embeds``
[B, source_len, d_model] arrive precomputed.  The decoder is the part that
serves: decode shapes exercise its self-attention KV cache (the cross-KV is
computed once at prefill and static thereafter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.annotate import ann
from repro.models import blocks as B
from repro.models import layers as L


def _init_cross_layer(rng, cfg: ModelConfig, dtype) -> dict:
    k = jax.random.split(rng, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "lnx": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": B.init_attn_params(k[0], cfg, dtype),
        "xattn": B.init_attn_params(k[1], cfg, dtype),
        "mlp": B.init_mlp_params(k[2], cfg.d_model, cfg.d_ff, dtype),
    }
    p["xattn"].pop("q_norm", None)
    p["xattn"].pop("k_norm", None)
    return p


def _cross_attend(x, p, cfg, ck, cv):
    """q from x, against precomputed cross k/v (no rope, not causal)."""
    bsz, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(bsz, S, H, hd)
    q = ann(q, "batch", None, "heads", None)
    o = L.attention(q, ck, cv, causal=False)
    return o.reshape(bsz, S, H * hd) @ p["wo"]


def _cross_kv(enc_out, p, cfg):
    bsz, Skv, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    ck = (enc_out @ p["wk"]).reshape(bsz, Skv, KV, hd)
    cv = (enc_out @ p["wv"]).reshape(bsz, Skv, KV, hd)
    return ann(ck, "batch", None, "kv_heads", None), ann(cv, "batch", None, "kv_heads", None)


class EncDecModel:
    def __init__(self, cfg: ModelConfig, mesh=None, remat: bool = True, **_):
        assert cfg.encoder is not None
        self.cfg = cfg
        self.mesh = mesh
        self.remat = remat
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg, dtype = self.cfg, self.dtype
        k = jax.random.split(rng, 5)
        enc_layers = jax.vmap(lambda r: B.init_dense_layer(r, cfg, dtype))(
            jax.random.split(k[0], cfg.encoder.num_layers)
        )
        dec_layers = jax.vmap(lambda r: _init_cross_layer(r, cfg, dtype))(
            jax.random.split(k[1], cfg.num_layers)
        )
        return {
            "embed": (jax.random.normal(k[2], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
            "enc_layers": enc_layers,
            "enc_norm": jnp.zeros((cfg.d_model,), dtype),
            "dec_layers": dec_layers,
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "unembed": (jax.random.normal(k[3], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(dtype),
        }

    def _enc_ctx(self, src_len: int, bsz: int) -> B.Ctx:
        pos = jnp.broadcast_to(jnp.arange(src_len)[None], (bsz, src_len))
        cos, sin = L.rope_cos_sin(pos, self.cfg.head_dim, self.cfg.rope_theta)
        return B.Ctx(cfg=self.cfg, mesh=self.mesh, cos_local=cos, sin_local=sin,
                     causal=False, remat=self.remat)

    def _dec_ctx(self, positions, lengths=None, max_cache_len: int = 0) -> B.Ctx:
        cos, sin = L.rope_cos_sin(positions, self.cfg.head_dim, self.cfg.rope_theta)
        return B.Ctx(cfg=self.cfg, mesh=self.mesh, cos_local=cos, sin_local=sin,
                     lengths=lengths, max_cache_len=max_cache_len, remat=self.remat)

    # ------------------------------------------------------------------ encoder
    def encode(self, params, frame_embeds) -> jax.Array:
        cfg = self.cfg
        x = frame_embeds.astype(self.dtype)
        x = ann(x, "batch", None, "embed")
        ctx = self._enc_ctx(x.shape[1], x.shape[0])

        def body(xx, p_l):
            xx, _, _ = B.apply_dense(xx, p_l, ctx, "global", "train", None)
            return xx, None

        fn = jax.checkpoint(body) if self.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc_layers"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------ decoder stack
    def _dec_stack(self, params, x, enc_out, ctx: B.Ctx, mode: str, cache=None):
        cfg = self.cfg

        if mode == "train":

            def body(carry, p_l):
                xx = carry
                h, _ = B.attn_sub(L.rms_norm(xx, p_l["ln1"], cfg.norm_eps), p_l["attn"], ctx, "global", "train", None)
                xx = xx + h
                ck, cv = _cross_kv(enc_out, p_l["xattn"], cfg)
                xx = xx + _cross_attend(L.rms_norm(xx, p_l["lnx"], cfg.norm_eps), p_l["xattn"], cfg, ck, cv)
                xx = xx + L.gated_mlp(L.rms_norm(xx, p_l["ln2"], cfg.norm_eps), p_l["mlp"], cfg.act)
                return ann(xx, "batch", None, "embed"), None

            fn = jax.checkpoint(body) if ctx.remat else body
            x, _ = jax.lax.scan(fn, x, params["dec_layers"])
            return x, None

        if mode == "prefill":

            def body(xx, p_l):
                h, nc_self = B.attn_sub(L.rms_norm(xx, p_l["ln1"], cfg.norm_eps), p_l["attn"], ctx, "global", "prefill", None)
                xx = xx + h
                ck, cv = _cross_kv(enc_out, p_l["xattn"], cfg)
                xx = xx + _cross_attend(L.rms_norm(xx, p_l["lnx"], cfg.norm_eps), p_l["xattn"], cfg, ck, cv)
                xx = xx + L.gated_mlp(L.rms_norm(xx, p_l["ln2"], cfg.norm_eps), p_l["mlp"], cfg.act)
                return ann(xx, "batch", None, "embed"), {"self": nc_self, "cross_k": ck, "cross_v": cv}

            x, nc = jax.lax.scan(body, x, params["dec_layers"])
            return x, nc

        # decode
        def body(xx, pc):
            p_l, c_l = pc
            h, nc_self = B.attn_sub(L.rms_norm(xx, p_l["ln1"], cfg.norm_eps), p_l["attn"], ctx, "global", "decode", c_l["self"])
            xx = xx + h
            xq = L.rms_norm(xx, p_l["lnx"], cfg.norm_eps)
            bsz = xq.shape[0]
            H, hd = cfg.num_heads, cfg.head_dim
            q = (xq @ p_l["xattn"]["wq"]).reshape(bsz, H, hd)
            valid = jnp.ones(c_l["cross_k"].shape[:2], bool)
            o = L.decode_attention(q, c_l["cross_k"], c_l["cross_v"], valid)
            xx = xx + (o.reshape(bsz, 1, H * hd) @ p_l["xattn"]["wo"])
            xx = xx + L.gated_mlp(L.rms_norm(xx, p_l["ln2"], cfg.norm_eps), p_l["mlp"], cfg.act)
            nc = {"self": nc_self, "cross_k": c_l["cross_k"], "cross_v": c_l["cross_v"]}
            return ann(xx, "batch", None, "embed"), nc

        x, nc = jax.lax.scan(body, x, (params["dec_layers"], cache))
        return x, nc

    # ------------------------------------------------------------------ train
    def loss(self, params, batch) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, S = tokens.shape
        enc_out = self.encode(params, batch["frame_embeds"])
        positions = jnp.broadcast_to(jnp.arange(S)[None], (bsz, S))
        ctx = self._dec_ctx(positions)
        x = params["embed"][tokens].astype(self.dtype)
        x = ann(x, "batch", None, "embed")
        x, _ = self._dec_stack(params, x, enc_out, ctx, "train")
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(tokens, jnp.float32) if mask is None else mask.astype(jnp.float32)
        mask = mask.at[:, -1].set(0.0)
        from repro.models.decoder import _chunked_ce

        ce = _chunked_ce(x, params["unembed"], False, labels, mask)
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    # ------------------------------------------------------------------ prefill / decode
    def prefill(self, params, batch, max_cache_len: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, S = tokens.shape
        enc_out = self.encode(params, batch["frame_embeds"])
        positions = jnp.broadcast_to(jnp.arange(S)[None], (bsz, S))
        ctx = self._dec_ctx(positions, max_cache_len=max_cache_len)
        x = params["embed"][tokens].astype(self.dtype)
        x = ann(x, "batch", None, "embed")
        x, nc = self._dec_stack(params, x, enc_out, ctx, "prefill")
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x[:, -1], params["unembed"], False)
        lengths = jnp.full((bsz,), S, jnp.int32)
        return {"layers": nc, "lengths": lengths}, logits, lengths

    def init_cache(self, bsz: int, max_cache_len: int) -> dict:
        cfg = self.cfg
        ctx = B.Ctx(cfg=cfg, max_cache_len=max_cache_len)
        per_layer = {
            "self": B.init_block_cache(cfg, bsz, "global", ctx, self.dtype),
            "cross_k": jnp.zeros((bsz, cfg.encoder.source_len, cfg.num_kv_heads, cfg.head_dim), self.dtype),
            "cross_v": jnp.zeros((bsz, cfg.encoder.source_len, cfg.num_kv_heads, cfg.head_dim), self.dtype),
        }
        stacked = jax.tree.map(lambda a: jnp.stack([a] * cfg.num_layers), per_layer)
        return {"layers": stacked, "lengths": jnp.zeros((bsz,), jnp.int32)}

    def decode_step(self, params, cache, tokens, batch=None):
        cfg = self.cfg
        bsz = tokens.shape[0]
        lengths = cache["lengths"]
        ctx = self._dec_ctx(lengths[:, None], lengths=lengths)
        x = params["embed"][tokens].astype(self.dtype)
        x = ann(x, "batch", None, "embed")
        x, nc = self._dec_stack(params, x, None, ctx, "decode", cache["layers"])
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x[:, 0], params["unembed"], False)
        return logits, {"layers": nc, "lengths": lengths + 1}
