"""Public model API: ``build_model(cfg)`` + batch construction helpers.

``make_batch_specs`` produces ShapeDtypeStructs for the dry-run (no
allocation); ``make_batch`` produces concrete arrays for smoke tests and the
example drivers.  Both agree on structure per (family x shape-kind).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.decoder import DecoderModel
from repro.models.encdec import EncDecModel


class Model(Protocol):
    cfg: ModelConfig

    def init(self, rng) -> dict: ...
    def loss(self, params, batch) -> Tuple[jax.Array, dict]: ...
    def prefill(self, params, batch, max_cache_len: int): ...
    def decode_step(self, params, cache, tokens, batch=None): ...
    def init_cache(self, bsz: int, max_cache_len: int) -> dict: ...


def build_model(cfg: ModelConfig, mesh=None, moe_dispatch: str = "dense",
                remat: bool = True, attn_impl: str = "chunked",
                tp_comm: str = "auto", remat_group: int = 1) -> Model:
    if cfg.family == "audio":
        return EncDecModel(cfg, mesh=mesh, remat=remat)
    return DecoderModel(cfg, mesh=mesh, moe_dispatch=moe_dispatch, remat=remat,
                        attn_impl=attn_impl, tp_comm=tp_comm, remat_group=remat_group)


def _extras_specs(cfg: ModelConfig, bsz: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    dt = jnp.dtype(cfg.dtype)
    if cfg.vlm is not None:
        out["patch_embeds"] = jax.ShapeDtypeStruct((bsz, cfg.vlm.num_patches, cfg.d_model), dt)
        out["positions_thw"] = jax.ShapeDtypeStruct((3, bsz, seq), jnp.int32)
    if cfg.encoder is not None:
        out["frame_embeds"] = jax.ShapeDtypeStruct((bsz, cfg.encoder.source_len, cfg.d_model), dt)
    return out


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for one cell.  For decode cells the KV cache itself is
    part of the input spec (donated in real serving)."""
    bsz, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((bsz, S), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((bsz, S), jnp.float32),
        }
        specs.update(_extras_specs(cfg, bsz, S))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((bsz, S), jnp.int32)}
        specs.update(_extras_specs(cfg, bsz, S))
        return specs
    # decode: one new token against a cache of length S
    specs = {"tokens": jax.ShapeDtypeStruct((bsz, 1), jnp.int32)}
    return specs


def make_batch(cfg: ModelConfig, bsz: int, seq: int, rng, kind: str = "train") -> Dict[str, Any]:
    """Concrete small batch for smoke tests / examples."""
    k1, k2 = jax.random.split(rng)
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {
        "tokens": jax.random.randint(k1, (bsz, seq), 0, cfg.vocab_size, dtype=jnp.int32)
    }
    if kind == "train":
        batch["loss_mask"] = jnp.ones((bsz, seq), jnp.float32)
    if cfg.vlm is not None:
        npch = min(cfg.vlm.num_patches, max(seq - 2, 1))
        batch["patch_embeds"] = jax.random.normal(k2, (bsz, npch, cfg.d_model)).astype(dt) * 0.02
        pos = jnp.broadcast_to(jnp.arange(seq)[None], (bsz, seq))
        batch["positions_thw"] = jnp.stack([pos, pos, pos])
        if kind == "train":
            batch["loss_mask"] = batch["loss_mask"].at[:, 1 : 1 + npch].set(0.0)
    if cfg.encoder is not None:
        batch["frame_embeds"] = (
            jax.random.normal(k2, (bsz, cfg.encoder.source_len, cfg.d_model)).astype(dt) * 0.02
        )
    return batch
