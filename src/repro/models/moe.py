"""Mixture-of-experts block: top-k router, shared experts, and two dispatch
strategies:

* ``dense``  — one-hot einsum dispatch (GSPMD-friendly baseline; experts are
               expert-parallel over the ``model`` axis, tokens all-gather).
* ``a2a``    — shard_map all-to-all dispatch (the beyond-paper optimized path;
               see EXPERIMENTS.md §Perf).

Router follows deepseek-moe (softmax gate over routed experts, top-k with
normalized weights, aux load-balancing loss) and degenerates to switch-style
top-1 for llama4-maverick.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.annotate import ann


def router_topk(
    x: jax.Array, w_router: jax.Array, cfg: MoEConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [T, D] -> (weights [T, k], idx [T, k], aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.top_k > 1:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss.
    E = w_router.shape[-1]
    me = probs.mean(axis=0)  # mean router prob per expert
    onehot = jax.nn.one_hot(idx[:, 0], E)
    ce = onehot.mean(axis=0)  # fraction of tokens (by top-1) per expert
    aux = (me * ce).sum() * E * cfg.aux_loss_coef
    return weights, idx, aux


def _expert_ffn(h: jax.Array, w1, w3, w2, act) -> jax.Array:
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    return (fn(h @ w1) * (h @ w3)) @ w2


def moe_block(
    x: jax.Array,
    p: dict,
    cfg: MoEConfig,
    act: str = "silu",
    dispatch: str = "dense",
    mesh=None,
) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, D] -> (y [B, S, D], aux_loss).

    p = {router [D,E], w1/w3 [E,D,F], w2 [E,F,D],
         shared_w1/shared_w3 [D, F*ns], shared_w2 [F*ns, D] (if shared)}
    """
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    weights, idx, aux = router_topk(xt, p["router"], cfg)

    if dispatch == "a2a" and mesh is not None and "model" in mesh.axis_names:
        y = _moe_a2a(xt, weights, idx, p, cfg, act, mesh)
    else:
        y = _moe_dense(xt, weights, idx, p, cfg, act)

    if cfg.num_shared_experts > 0:
        fn = jax.nn.silu if act == "silu" else jax.nn.gelu
        sh = fn(xt @ p["shared_w1"]) * (xt @ p["shared_w3"])
        sh = ann(sh, "batch", "mlp")
        y = y + sh @ p["shared_w2"]
    return y.reshape(B, S, D), aux


def _moe_dense(xt, weights, idx, p, cfg: MoEConfig, act) -> jax.Array:
    """Capacity-based scatter/gather dispatch (GSPMD baseline).

    Tokens are scattered into per-expert buckets [E, C, D] (C from the
    capacity factor), expert FFNs run as one grouped einsum with the
    expert dim sharded over "model" (EP), and results gather back.
    Overflow tokens beyond capacity are dropped (standard switch behavior).
    """
    T, D = xt.shape
    E, k = cfg.num_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * k * T / E), 1)

    flat_e = idx.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot  # 1-based position within expert
    pos = pos.sum(-1) - 1  # [T*k]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    src_tok = jnp.repeat(jnp.arange(T), k)

    buckets = jnp.zeros((E, cap, D), dtype=xt.dtype)
    buckets = buckets.at[flat_e, pos_c].add(jnp.where(keep[:, None], xt[src_tok], 0))
    buckets = ann(buckets, "expert", None, None)

    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    hh = fn(jnp.einsum("ecd,edf->ecf", buckets, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", buckets, p["w3"]
    )
    hh = ann(hh, "expert", None, "mlp")
    out = jnp.einsum("ecf,efd->ecd", hh, p["w2"])  # [E, cap, D]
    out = ann(out, "expert", None, None)

    gathered = out[flat_e, pos_c]  # [T*k, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    wflat = weights.reshape(-1, 1).astype(gathered.dtype)
    y = jnp.zeros_like(xt).at[src_tok].add(gathered * wflat)
    return y


def _moe_a2a(xt, weights, idx, p, cfg: MoEConfig, act, mesh) -> jax.Array:
    """shard_map expert-parallel dispatch (the beyond-paper optimized path;
    EXPERIMENTS.md §Perf cell B).

    Tokens are sharded over the data axes and REPLICATED over "model";
    experts are sharded over "model".  Each model rank therefore already
    holds every token of its data shard: it builds buckets for its LOCAL
    expert group only, runs those experts, scatters partial outputs back to
    token positions, and a single activation-sized psum over "model"
    combines the groups.  Collective bytes scale with tokens_local x D —
    never with the full [T, D] batch (dense-dispatch baseline) and never
    with expert weights (FSDP gathers)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.distributed.annotate import _current

    E = cfg.num_experts
    tp = mesh.shape["model"]
    e_local = E // tp

    # resolve shardings from the active rules so the shard_map keeps every
    # weight dim exactly where the param sharding put it (no hidden gathers):
    # tokens follow the "batch" rule; expert FF may be TP'd over data (the
    # llama4 decode scheme — see EXPERIMENTS.md §Perf cell C).
    ctx = _current()
    if ctx is not None:
        _, rules = ctx
        tok_spec = rules.spec(xt.shape, ("batch", None))
        w1_spec = rules.spec(p["w1"].shape[-3:], ("expert", "fsdp", "expert_ff"))
        w2_spec = rules.spec(p["w2"].shape[-3:], ("expert", "expert_ff", "fsdp"))
        # the local einsums contract the full d_model: an FSDP shard on D
        # must be gathered at the shard_map boundary (that cost is why the
        # optimized llama4 serving config disables fsdp in favor of
        # expert_ff TP — EXPERIMENTS.md §Perf cell C)
        w1_spec = P(w1_spec[0], None, w1_spec[2])
        w2_spec = P(w2_spec[0], w2_spec[1], None)
    else:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tok_spec = P(data_axes if data_axes else None, None)
        w1_spec = P("model", None, None)
        w2_spec = P("model", None, None)

    def _axes(entry):
        return () if entry is None else ((entry,) if isinstance(entry, str) else tuple(entry))

    tok_axes = _axes(tok_spec[0])
    ff_axes = _axes(w1_spec[2])  # axes sharding the expert FF dim (TP-within-expert)
    if set(ff_axes) & set(tok_axes):
        # FF-TP over an axis that also shards tokens would mix different
        # tokens' partial sums.  Replicate the tokens over those axes
        # instead (cheap at decode batch sizes — this is the llama4 serving
        # scheme: activations move, weights stay; EXPERIMENTS.md §Perf C).
        tok_spec = P(None, None)
        tok_axes = ()
    n_tok_shards = 1
    for a in tok_axes:
        n_tok_shards *= mesh.shape[a]
    t_local = max(xt.shape[0] // n_tok_shards, 1)
    cap = max(int(cfg.capacity_factor * cfg.top_k * t_local / E) + 1, 1)

    def local_fn(xt_l, weights_l, idx_l, w1, w3, w2):
        # xt_l [t_local, D]; w1/w3 [e_local, D, F_local]; w2 [e_local, F_local, D]
        m = jax.lax.axis_index("model")
        tl = xt_l.shape[0]
        flat_e = idx_l.reshape(-1)  # [tl*k] global expert ids
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # slot in expert bucket
        local_e = flat_e - m * e_local
        mine = (local_e >= 0) & (local_e < e_local) & (pos < cap)
        le_c = jnp.clip(local_e, 0, e_local - 1)
        pos_c = jnp.where(mine, pos, 0)
        src_tok = jnp.repeat(jnp.arange(tl), cfg.top_k)
        buckets = jnp.zeros((e_local, cap, xt_l.shape[1]), dtype=xt_l.dtype)
        buckets = buckets.at[le_c, pos_c].add(jnp.where(mine[:, None], xt_l[src_tok], 0))
        fn = jax.nn.silu if act == "silu" else jax.nn.gelu
        hh = fn(jnp.einsum("ecd,edf->ecf", buckets, w1)) * jnp.einsum(
            "ecd,edf->ecf", buckets, w3
        )
        o = jnp.einsum("ecf,efd->ecd", hh, w2)  # [e_local, cap, D] (partial if FF TP'd)
        if ff_axes:
            o = jax.lax.psum(o, ff_axes)  # TP-within-expert partial sums
        gathered = jnp.where(mine[:, None], o[le_c, pos_c], 0)
        wflat = weights_l.reshape(-1, 1).astype(gathered.dtype)
        y_partial = jnp.zeros_like(xt_l).at[src_tok].add(gathered * wflat)
        return jax.lax.psum(y_partial, "model")

    flat_spec = P(tok_spec[0], None)  # routing weights / indices [T, k]
    y = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(tok_spec, flat_spec, flat_spec, w1_spec, w1_spec, w2_spec),
        out_specs=tok_spec,
        check_rep=False,
    )(xt, weights, idx, p["w1"], p["w3"], p["w2"])
    return y


def init_moe_params(rng, cfg: MoEConfig, d_model: int, dtype) -> dict:
    E, F = cfg.num_experts, cfg.d_ff_expert
    k = jax.random.split(rng, 6)
    s_in = d_model ** -0.5
    s_out = F ** -0.5
    p = {
        "router": (jax.random.normal(k[0], (d_model, E)) * s_in).astype(jnp.float32),
        "w1": (jax.random.normal(k[1], (E, d_model, F)) * s_in).astype(dtype),
        "w3": (jax.random.normal(k[2], (E, d_model, F)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k[3], (E, F, d_model)) * s_out).astype(dtype),
    }
    if cfg.num_shared_experts > 0:
        Fs = F * cfg.num_shared_experts
        p["shared_w1"] = (jax.random.normal(k[4], (d_model, Fs)) * s_in).astype(dtype)
        p["shared_w3"] = (jax.random.normal(k[5], (d_model, Fs)) * s_in).astype(dtype)
        p["shared_w2"] = (jax.random.normal(k[0], (Fs, d_model)) * Fs ** -0.5).astype(dtype)
    return p
