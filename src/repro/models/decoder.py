"""Decoder-stack model assembly for all decoder-only families:
dense (llama), gemma3 (periodic local:global), MoE (llama4 / deepseek-moe),
SSM (mamba2), hybrid (hymba), VLM (qwen2-vl).

The stack is a list of *segments*; each segment is either scanned
(homogeneous layers, stacked params — keeps HLO size flat in depth) or
unrolled (irregular stacks: hymba; leading dense layer of deepseek-moe;
gemma3's trailing partial period).

All entry points are pure functions of (params, batch) suitable for pjit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.annotate import ann
from repro.models import blocks as B
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class SegmentDef:
    kind: str  # "scan" | "unroll"
    unit: str  # "dense" | "moe" | "ssm" | "hybrid" | "gemma_period"
    n: int  # units (layers, or periods for gemma_period)
    layer_types: Tuple[str, ...]  # per unit; for gemma_period: per-slot inside period
    d_ff: Optional[int] = None  # override (deepseek-moe leading dense layer)


def build_segments(cfg: ModelConfig) -> List[SegmentDef]:
    lt = cfg.layer_types()
    if cfg.family == "ssm":
        return [SegmentDef("scan", "ssm", cfg.num_layers, ("global",) * cfg.num_layers)]
    if cfg.family == "hybrid":
        # split the irregular stack into runs of one layer type: globals
        # (first/middle/last) unroll; the long local runs scan.  Order is
        # preserved; per-type caches keep full-length KV only where needed.
        segs: List[SegmentDef] = []
        i = 0
        while i < len(lt):
            j = i
            while j < len(lt) and lt[j] == lt[i]:
                j += 1
            kind = "scan" if (j - i) >= 3 else "unroll"
            segs.append(SegmentDef(kind, "hybrid", j - i, lt[i:j]))
            i = j
        return segs
    if cfg.family == "moe":
        if cfg.moe.moe_every == 2:
            # llama4-style interleave: scan over (dense, moe) periods
            assert cfg.num_layers % 2 == 0 and cfg.moe.first_moe_layer == 1
            return [
                SegmentDef("scan", "moe_period", cfg.num_layers // 2, ("global", "global"))
            ]
        segs = []
        lead = cfg.moe.first_moe_layer
        if lead > 0:
            segs.append(
                SegmentDef("unroll", "dense", lead, lt[:lead], d_ff=cfg.moe.d_ff_dense or cfg.d_ff)
            )
        n_moe = cfg.num_layers - lead
        segs.append(SegmentDef("scan", "moe", n_moe, lt[lead:]))
        return segs
    if cfg.attn_pattern == "gemma3":
        period = cfg.local_per_period + 1
        n_periods = cfg.num_layers // period
        trail = cfg.num_layers - n_periods * period
        segs = []
        if n_periods > 0:
            segs.append(
                SegmentDef(
                    "scan", "gemma_period", n_periods,
                    ("local",) * cfg.local_per_period + ("global",),
                )
            )
        if trail:
            segs.append(SegmentDef("unroll", "dense", trail, lt[-trail:]))
        return segs
    # dense / vlm
    return [SegmentDef("scan", "dense", cfg.num_layers, lt)]


# --------------------------------------------------------------------------- unit init/apply
def _unit_init(seg: SegmentDef, cfg: ModelConfig, dtype):
    if seg.unit == "dense":
        return lambda r: B.init_dense_layer(r, cfg, dtype, d_ff=seg.d_ff)
    if seg.unit == "moe":
        return lambda r: B.init_moe_layer(r, cfg, dtype)
    if seg.unit == "ssm":
        return lambda r: B.init_ssm_layer(r, cfg, dtype)
    if seg.unit == "hybrid":
        return lambda r: B.init_hybrid_layer(r, cfg, dtype)
    if seg.unit == "gemma_period":

        def init_period(r):
            ks = jax.random.split(r, cfg.local_per_period + 1)
            locals_p = jax.vmap(lambda k: B.init_dense_layer(k, cfg, dtype))(
                ks[: cfg.local_per_period]
            )
            return {"locals": locals_p, "global": B.init_dense_layer(ks[-1], cfg, dtype)}

        return init_period
    if seg.unit == "moe_period":

        def init_moe_period(r):
            k1, k2 = jax.random.split(r)
            return {
                "dense": B.init_dense_layer(k1, cfg, dtype, d_ff=cfg.moe.d_ff_dense or cfg.d_ff),
                "moe": B.init_moe_layer(k2, cfg, dtype),
            }

        return init_moe_period
    raise ValueError(seg.unit)


def _unit_apply(seg: SegmentDef, x, p, ctx: B.Ctx, layer_type: str, mode: str, cache):
    if seg.unit == "dense":
        return B.apply_dense(x, p, ctx, layer_type, mode, cache)
    if seg.unit == "moe":
        return B.apply_moe(x, p, ctx, layer_type, mode, cache)
    if seg.unit == "ssm":
        return B.apply_ssm(x, p, ctx, layer_type, mode, cache)
    if seg.unit == "hybrid":
        return B.apply_hybrid(x, p, ctx, layer_type, mode, cache)
    if seg.unit == "gemma_period":
        aux_total = jnp.zeros((), jnp.float32)
        new_local_caches = []
        nl = len(seg.layer_types) - 1
        for i in range(nl):
            p_i = jax.tree.map(lambda a: a[i], p["locals"])
            c_i = None if cache is None else jax.tree.map(lambda a: a[i], cache["locals"])
            x, aux, nc = B.apply_dense(x, p_i, ctx, "local", mode, c_i)
            aux_total += aux
            new_local_caches.append(nc)
        c_g = None if cache is None else cache["global"]
        x, aux, nc_g = B.apply_dense(x, p["global"], ctx, "global", mode, c_g)
        aux_total += aux
        new_cache = None
        if nc_g is not None or any(c is not None for c in new_local_caches):
            new_cache = {
                "locals": jax.tree.map(lambda *a: jnp.stack(a), *new_local_caches),
                "global": nc_g,
            }
        return x, aux_total, new_cache
    if seg.unit == "moe_period":
        c_d = None if cache is None else cache["dense"]
        c_m = None if cache is None else cache["moe"]
        x, aux1, nc_d = B.apply_dense(x, p["dense"], ctx, "global", mode, c_d)
        x, aux2, nc_m = B.apply_moe(x, p["moe"], ctx, "global", mode, c_m)
        new_cache = None
        if nc_d is not None or nc_m is not None:
            new_cache = {"dense": nc_d, "moe": nc_m}
        return x, aux1 + aux2, new_cache
    raise ValueError(seg.unit)


def _unit_cache(seg: SegmentDef, cfg: ModelConfig, bsz: int, ctx: B.Ctx, dtype):
    if seg.unit == "gemma_period":
        nl = len(seg.layer_types) - 1
        local = B.init_block_cache(cfg, bsz, "local", ctx, dtype)
        return {
            "locals": jax.tree.map(lambda a: jnp.stack([a] * nl), local),
            "global": B.init_block_cache(cfg, bsz, "global", ctx, dtype),
        }
    if seg.unit == "moe_period":
        g = B.init_block_cache(cfg, bsz, "global", ctx, dtype)
        return {"dense": g, "moe": jax.tree.map(jnp.array, g)}
    # NOTE: for unroll segments callers index by layer; layer_type varies
    return None  # handled per-layer by callers


# --------------------------------------------------------------------------- model
class DecoderModel:
    def __init__(self, cfg: ModelConfig, mesh=None, moe_dispatch: str = "dense",
                 remat: bool = True, attn_impl: str = "chunked", tp_comm: str = "auto",
                 remat_group: int = 1):
        self.cfg = cfg
        self.mesh = mesh
        self.moe_dispatch = moe_dispatch
        self.remat = remat
        self.attn_impl = attn_impl
        self.tp_comm = tp_comm
        self.remat_group = remat_group
        self.segments = build_segments(cfg)
        self.dtype = jnp.dtype(cfg.dtype)
        self.n_meta = cfg.hybrid.num_meta_tokens if cfg.hybrid is not None else 0

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg, dtype = self.cfg, self.dtype
        k_emb, k_seg, k_out, k_meta = jax.random.split(rng, 4)
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "segments": [],
        }
        seg_keys = jax.random.split(k_seg, len(self.segments))
        for seg, sk in zip(self.segments, seg_keys):
            init_fn = _unit_init(seg, cfg, dtype)
            if seg.kind == "scan":
                params["segments"].append(jax.vmap(init_fn)(jax.random.split(sk, seg.n)))
            else:
                lks = jax.random.split(sk, seg.n)
                params["segments"].append([init_fn(lk) for lk in lks])
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(k_out, (cfg.d_model, cfg.vocab_size)) * 0.02
            ).astype(dtype)
        if self.n_meta:
            params["meta_tokens"] = (
                jax.random.normal(k_meta, (self.n_meta, cfg.d_model)) * 0.02
            ).astype(dtype)
        return params

    # ------------------------------------------------------------------ ctx
    def _make_ctx(self, mode: str, positions, max_cache_len: int = 0, lengths=None, positions_thw=None) -> B.Ctx:
        cfg = self.cfg
        ctx = B.Ctx(
            cfg=cfg,
            mesh=self.mesh,
            lengths=lengths,
            n_meta=self.n_meta,
            moe_dispatch=self.moe_dispatch,
            max_cache_len=max_cache_len,
            window=cfg.window_size,
            remat=self.remat,
            attn_impl=self.attn_impl,
            tp_comm=self.tp_comm,
        )
        if cfg.family == "ssm":
            return ctx
        if cfg.vlm is not None and positions_thw is not None:
            cos, sin = L.mrope_cos_sin(
                positions_thw, cfg.head_dim, cfg.rope_theta, cfg.vlm.mrope_sections
            )
        else:
            cos, sin = L.rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
        ctx = dataclasses.replace(ctx, cos_local=cos, sin_local=sin)
        if cfg.rope_theta_global:
            cg, sg = L.rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta_global)
            ctx = dataclasses.replace(ctx, cos_global=cg, sin_global=sg)
        return ctx

    # ------------------------------------------------------------------ embedding
    def _embed(self, params, tokens, batch) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)
        if cfg.attn_pattern == "gemma3":  # gemma scales embeddings
            x = x * jnp.asarray(math.sqrt(cfg.d_model), self.dtype)
        if cfg.vlm is not None and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(self.dtype)
            x = jax.lax.dynamic_update_slice_in_dim(x, pe, 1, axis=1)
        if self.n_meta:
            meta = jnp.broadcast_to(
                params["meta_tokens"][None], (x.shape[0], self.n_meta, cfg.d_model)
            ).astype(self.dtype)
            x = jnp.concatenate([meta, x], axis=1)
        return ann(x, "batch", None, "embed")

    def _unembed_w(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"], True
        return params["unembed"], False

    # ------------------------------------------------------------------ stack walk
    def _run_stack(self, params, x, ctx: B.Ctx, mode: str, cache=None):
        """Returns (x, aux_total, new_cache)."""
        aux_total = jnp.zeros((), jnp.float32)
        new_cache: List[Any] = []
        cache_segs = cache["segments"] if cache is not None else [None] * len(self.segments)
        for si, (seg, p_seg) in enumerate(zip(self.segments, params["segments"])):
            c_seg = cache_segs[si]
            if seg.kind == "scan":
                lt = seg.layer_types[0] if seg.unit != "gemma_period" else "period"

                if mode == "train":

                    def body(carry, p_l, seg=seg):
                        xx, aux = carry
                        xx, a, _ = _unit_apply(seg, xx, p_l, ctx, seg.layer_types[0], "train", None)
                        return (xx, aux + a), None

                    group = self.remat_group
                    if ctx.remat and group > 1 and seg.n % group == 0:
                        # nested remat: save only every `group`-th residual
                        # (sqrt-style checkpointing) — bwd recomputes a
                        # group chain instead of holding 1 residual/layer
                        # (EXPERIMENTS.md §Perf cell A iter 3)
                        grouped = jax.tree.map(
                            lambda a: a.reshape((seg.n // group, group) + a.shape[1:]), p_seg
                        )

                        def group_body(carry, p_g):
                            c, _ = jax.lax.scan(body, carry, p_g)
                            return c, None

                        (x, aux_total), _ = jax.lax.scan(
                            jax.checkpoint(group_body, policy=None), (x, aux_total), grouped
                        )
                    else:
                        body_fn = jax.checkpoint(body, policy=None) if ctx.remat else body
                        (x, aux_total), _ = jax.lax.scan(
                            lambda c, p: body_fn(c, p), (x, aux_total), p_seg
                        )
                    new_cache.append(None)
                elif mode == "prefill":

                    def body(xx, p_l, seg=seg):
                        xx, a, nc = _unit_apply(seg, xx, p_l, ctx, seg.layer_types[0], "prefill", None)
                        return xx, nc

                    x, nc = jax.lax.scan(body, x, p_seg)
                    new_cache.append(nc)
                else:  # decode
                    # cache rides in the CARRY with per-layer dynamic slice /
                    # update-slice, so XLA keeps ONE aliased buffer instead of
                    # double-buffering xs+ys stacks (halves decode HBM
                    # residency — EXPERIMENTS.md §Perf cell C iter 3)

                    def body(carry, p_l, seg=seg):
                        xx, cache_stack, li = carry
                        c_l = jax.tree.map(
                            lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
                            cache_stack,
                        )
                        xx, a, nc = _unit_apply(seg, xx, p_l, ctx, seg.layer_types[0], "decode", c_l)
                        cache_stack = jax.tree.map(
                            lambda a, n: jax.lax.dynamic_update_index_in_dim(
                                a, n.astype(a.dtype), li, 0
                            ),
                            cache_stack, nc,
                        )
                        return (xx, cache_stack, li + 1), None

                    (x, nc, _), _ = jax.lax.scan(
                        body, (x, c_seg, jnp.zeros((), jnp.int32)), p_seg
                    )
                    new_cache.append(nc)
            else:  # unroll
                seg_caches = []
                for li in range(seg.n):
                    p_l = p_seg[li]
                    c_l = None if c_seg is None else c_seg[li]
                    lt = seg.layer_types[li]
                    apply = lambda xx, pp, cc, lt=lt, seg=seg: _unit_apply(seg, xx, pp, ctx, lt, mode, cc)
                    if mode == "train" and ctx.remat:
                        xx, a, nc = jax.checkpoint(apply)(x, p_l, c_l)
                    else:
                        xx, a, nc = apply(x, p_l, c_l)
                    x = xx
                    aux_total += a
                    seg_caches.append(nc)
                new_cache.append(seg_caches if mode != "train" else None)
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return x, aux_total, ({"segments": new_cache} if mode != "train" else None)

    # ------------------------------------------------------------------ train
    def loss(self, params, batch) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S + self.n_meta)[None], (bsz, S + self.n_meta))
        ctx = self._make_ctx("train", positions, positions_thw=batch.get("positions_thw"))
        x = self._embed(params, tokens, batch)
        x, aux, _ = self._run_stack(params, x, ctx, "train")
        if self.n_meta:
            x = x[:, self.n_meta :]
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(tokens, jnp.float32) if mask is None else mask.astype(jnp.float32)
        mask = mask.at[:, -1].set(0.0)
        w, transpose = self._unembed_w(params)
        ce = _chunked_ce(x, w, transpose, labels, mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ prefill / decode
    def prefill(self, params, batch, max_cache_len: int) -> Tuple[dict, jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, S = tokens.shape
        total = S + self.n_meta
        positions = jnp.broadcast_to(jnp.arange(total)[None], (bsz, total))
        ctx = self._make_ctx(
            "prefill", positions, max_cache_len=max_cache_len + self.n_meta,
            positions_thw=batch.get("positions_thw"),
        )
        x = self._embed(params, tokens, batch)
        x, _, cache = self._run_stack(params, x, ctx, "prefill")
        w, transpose = self._unembed_w(params)
        last_logits = L.unembed(x[:, -1], w, transpose)
        lengths = jnp.full((bsz,), total, jnp.int32)
        cache["lengths"] = lengths
        return cache, last_logits, lengths

    def init_cache(self, bsz: int, max_cache_len: int) -> dict:
        ctx = B.Ctx(
            cfg=self.cfg,
            n_meta=self.n_meta,
            window=self.cfg.window_size,
            max_cache_len=max_cache_len + self.n_meta,
        )
        segs = []
        for seg in self.segments:
            if seg.kind == "scan":
                if seg.unit in ("gemma_period", "moe_period"):
                    c = _unit_cache(seg, self.cfg, bsz, ctx, self.dtype)
                else:
                    c = B.init_block_cache(self.cfg, bsz, seg.layer_types[0], ctx, self.dtype)
                segs.append(jax.tree.map(lambda a: jnp.stack([a] * seg.n), c))
            else:
                segs.append(
                    [
                        B.init_block_cache(self.cfg, bsz, seg.layer_types[i], ctx, self.dtype)
                        for i in range(seg.n)
                    ]
                )
        return {"segments": segs, "lengths": jnp.zeros((bsz,), jnp.int32)}

    def decode_step(self, params, cache, tokens, batch=None) -> Tuple[jax.Array, dict]:
        """tokens [B, 1]; cache from prefill/init_cache.  Returns (logits [B,V], cache)."""
        cfg = self.cfg
        bsz = tokens.shape[0]
        lengths = cache["lengths"]
        positions = lengths[:, None]
        positions_thw = None
        if cfg.vlm is not None:
            positions_thw = jnp.broadcast_to(positions[None], (3, bsz, 1))
        ctx = self._make_ctx(
            "decode",
            positions,
            max_cache_len=0,
            lengths=lengths,
            positions_thw=positions_thw,
        )
        x = params["embed"][tokens].astype(self.dtype)
        if cfg.attn_pattern == "gemma3":
            x = x * jnp.asarray(math.sqrt(cfg.d_model), self.dtype)
        x = ann(x, "batch", None, "embed")
        x, _, new_cache = self._run_stack(params, x, ctx, "decode", cache)
        w, transpose = self._unembed_w(params)
        logits = L.unembed(x[:, 0], w, transpose)
        new_cache["lengths"] = lengths + 1
        return logits, new_cache


# --------------------------------------------------------------------------- chunked CE
def _chunked_ce(x, w, transpose, labels, mask, target_tokens: int = 16384):
    """Cross-entropy without materializing [B, S, V] logits: scan over
    sequence chunks, recomputing logits in the backward pass."""
    bsz, S, D = x.shape
    chunk = max(1, min(S, target_tokens // max(bsz, 1)))
    while S % chunk != 0:
        chunk -= 1
    n = S // chunk
    if n <= 1:
        logits = L.unembed(x, w, transpose)
        return L.cross_entropy(logits, labels, mask)

    xs = (
        x.reshape(bsz, n, chunk, D).transpose(1, 0, 2, 3),
        labels.reshape(bsz, n, chunk).transpose(1, 0, 2),
        mask.reshape(bsz, n, chunk).transpose(1, 0, 2),
    )

    def body(carry, inp):
        xb, lb, mb = inp
        logits = L.unembed(xb, w, transpose)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return carry + ((logz - gold) * mb).sum(), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(mask.sum(), 1.0)
