"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches jax
device state.  Single pod: 16x16 = 256 chips ("data","model").  Multi-pod:
2x16x16 = 512 chips ("pod","data","model") — the "pod" axis is the
data-parallel axis that crosses the inter-pod network.
"""
from __future__ import annotations

import jax


def axis_types_kw(n_axes: int) -> dict:
    """axis_types=(Auto, ...) where the installed jax supports it (>=0.5);
    older versions default every axis to Auto already."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh for smoke tests / examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"), **axis_types_kw(2))
