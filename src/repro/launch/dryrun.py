import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with NO device allocation (ShapeDtypeStruct inputs),
and record memory/cost/collective analysis per cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Device-count note: the XLA_FLAGS line above MUST run before any other
import; it only affects this entry point (smoke tests and benches see the
real single device).
"""

import argparse
import functools
import json
import time
import traceback
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES_BY_NAME, applicable, get_config, all_cells
from repro.distributed.annotate import use_rules
from repro.distributed.params import (
    opt_state_shardings,
    tree_shardings,
)
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.api import build_model, make_batch_specs
from repro.optim.adamw import AdamW, cosine_schedule
from repro.roofline.analysis import (
    V5E,
    collective_bytes_from_hlo,
    model_flops_for_cell,
    roofline_terms,
)

# per-arch training knobs (memory realism at 256/512 chips)
MICRO_STEPS = {"deepseek-67b": 8, "llama4-maverick-400b-a17b": 8}
FSDP_ARCHS = {"llama4-maverick-400b-a17b"}


def _attach(abstract, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), abstract, shardings
    )


def make_cell_rules(mesh, cfg, shape, overrides=None):
    """Sharding rules for one cell, including the divisibility-driven
    seq-sharded-KV fallback and FSDP for very large MoE."""
    ov = dict(overrides or {})
    tp = mesh.shape.get("model", 1)
    if shape.kind in ("decode", "prefill") and cfg.num_kv_heads and cfg.num_kv_heads % tp != 0:
        # KV heads not TP-shardable -> shard the cache sequence dim instead
        ov.setdefault("seq", "model")
    if cfg.name in FSDP_ARCHS:
        # 400B params don't fit at TP16 even for serving: shard expert
        # weights over the data axes too (weights all-gather per layer)
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        ov.setdefault("fsdp", data_axes)
    return rules_for_mesh(mesh, overrides=ov)


def lower_cell(arch: str, shape_name: str, mesh, *, moe_dispatch="dense", zero1=True,
               remat=True, rules_overrides=None, micro_steps=None, attn_impl="chunked",
               no_fsdp=False, tp_comm="auto", remat_group=1, zero2=False):
    """Build + lower one cell.  Returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if no_fsdp:
        rules_overrides = dict(rules_overrides or {})
        rules_overrides.setdefault("fsdp", None)
    rules = make_cell_rules(mesh, cfg, shape, rules_overrides)
    model = build_model(cfg, mesh=mesh, moe_dispatch=moe_dispatch, remat=remat,
                        attn_impl=attn_impl, tp_comm=tp_comm, remat_group=remat_group)

    rng = jax.random.key(0)
    params_abs = jax.eval_shape(model.init, rng)
    params_sh = tree_shardings(params_abs, mesh, rules)
    params_in = _attach(params_abs, params_sh)

    batch_abs = make_batch_specs(cfg, shape)
    batch_sh = tree_shardings(batch_abs, mesh, rules)
    batch_in = _attach(batch_abs, batch_sh)

    with mesh, use_rules(mesh, rules):
        if shape.kind == "train":
            ms = micro_steps if micro_steps is not None else MICRO_STEPS.get(arch, 1)
            opt = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_sh = opt_state_shardings(opt_abs, params_abs, mesh, rules, zero1=zero1)
            step = make_train_step(model, opt, micro_steps=ms,
                                   grad_shardings=opt_sh.m if zero2 else None)
            opt_in = _attach(opt_abs, opt_sh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params_in, opt_in, batch_in)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, max_cache_len=shape.seq_len)
            lowered = jax.jit(step).lower(params_in, batch_in)
        else:  # decode
            step = make_decode_step(model)
            cache_abs = jax.eval_shape(
                functools.partial(model.init_cache, shape.global_batch, shape.seq_len)
            )
            cache_sh = tree_shardings(cache_abs, mesh, rules)
            cache_in = _attach(cache_abs, cache_sh)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params_in, cache_in, batch_in["tokens"]
            )
    return lowered, dict(cfg=cfg, shape=shape, rules=rules)


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool, save_hlo: Optional[str] = None,
                **opts) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "skip", "reason": reason}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        t0 = time.time()
        lowered, meta = lower_cell(arch, shape_name, mesh, **opts)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        if save_hlo:
            import gzip

            Path(save_hlo).mkdir(parents=True, exist_ok=True)
            with gzip.open(Path(save_hlo) / f"{mesh_name}__{arch}__{shape_name}.hlo.gz",
                           "wt") as f:
                f.write(hlo)
        # loop-aware cost walk (XLA's cost_analysis counts scan bodies once)
        from repro.roofline.hlo_cost import analyze_hlo

        cost = analyze_hlo(hlo)
        flops = float(cost.flops)
        byts = float(cost.bytes)
        coll_total, coll_ops = cost.coll_bytes, {
            k: dict(v) for k, v in cost.coll_ops.items()
        }
        terms = roofline_terms(flops, byts, coll_total)
        mf = model_flops_for_cell(cfg, shape, shape.kind)
        useful = mf / (flops * n_chips) if flops > 0 else 0.0
        rec.update(
            status="ok",
            reason="",
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_dev=flops,
            bytes_per_dev=byts,
            collective_bytes_per_dev=coll_total,
            collective_ops=coll_ops,
            model_flops_total=mf,
            useful_flops_ratio=round(useful, 4),
            memory=dict(
                argument_bytes=getattr(ma, "argument_size_in_bytes", None),
                output_bytes=getattr(ma, "output_size_in_bytes", None),
                temp_bytes=getattr(ma, "temp_size_in_bytes", None),
                alias_bytes=getattr(ma, "alias_size_in_bytes", None),
            ),
            hlo_bytes=len(hlo),
            **terms,
        )
        # memory_analysis is PER-DEVICE on an SPMD module (verified: argument
        # bytes == param-shard + ZeRO opt-shard sizes); aliased outputs reuse
        # argument space.
        args = rec["memory"]["argument_bytes"] or 0
        temps = rec["memory"]["temp_bytes"] or 0
        rec["hbm_per_dev_gb"] = round((args + temps) / 1e9, 3)
        rec["fits_hbm"] = rec["hbm_per_dev_gb"] <= V5E.hbm_bytes / 1e9
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug we record
        rec.update(status="error", reason=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--moe-dispatch", choices=["dense", "a2a"], default="dense")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    n_ok = n_err = n_skip = 0
    for arch, shape_name in cells:
        for mp in meshes:
            rec = dryrun_cell(
                arch, shape_name, mp,
                moe_dispatch=args.moe_dispatch, zero1=not args.no_zero1,
                save_hlo=args.save_hlo or None,
            )
            tag = f".{args.tag}" if args.tag else ""
            name = f"{rec['mesh']}__{arch}__{shape_name}{tag}.json"
            (out_dir / name).write_text(json.dumps(rec, indent=1, default=str))
            n_ok += rec["status"] == "ok"
            n_err += rec["status"] == "error"
            n_skip += rec["status"] == "skip"
            msg = rec.get("reason", "")
            extra = (
                f"compile={rec.get('compile_s')}s flops/dev={rec.get('flops_per_dev', 0):.3g} "
                f"coll/dev={rec.get('collective_bytes_per_dev', 0):.3g}B "
                f"hbm={rec.get('hbm_per_dev_gb', 0)}GB bottleneck={rec.get('bottleneck', '')}"
                if rec["status"] == "ok"
                else msg[:160]
            )
            print(f"[{rec['status']:5s}] {rec['mesh']:6s} {arch:28s} {shape_name:12s} {extra}",
                  flush=True)
    print(f"\nok={n_ok} error={n_err} skip={n_skip}")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
