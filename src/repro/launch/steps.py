"""pjit-able train / prefill / decode step builders.

These close over a Model + optimizer and return pure functions suitable for
``jax.jit(..., donate_argnums=...)`` under a mesh.  The dry-run lowers these
exact functions — there is no separate "dry-run model".
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW, AdamWState
from repro.optim.gradients import GradAccumulator, clip_by_global_norm


def make_train_step(
    model,
    optimizer: AdamW,
    micro_steps: int = 1,
    clip_norm: float = 1.0,
    grad_shardings: Optional[Any] = None,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_shardings (ZeRO-2): constrain the f32 gradient tree to the
    optimizer-moment shardings — XLA reduce-scatters the data-parallel grad
    sync instead of all-reducing it, and the full-model f32 grad tree never
    materializes per device (EXPERIMENTS.md §Perf cell A iter 4)."""

    def train_step(params, opt_state: AdamWState, batch):
        loss, metrics, grads = GradAccumulator.accumulate(model.loss, params, batch, micro_steps)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        if clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros(())
        params, opt_state = optimizer.update(grads, opt_state, params)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(model, max_cache_len: int) -> Callable:
    """(params, batch) -> (cache, next_token, lengths)."""

    def prefill_step(params, batch):
        cache, logits, lengths = model.prefill(params, batch, max_cache_len)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return cache, next_token, lengths

    return prefill_step


def make_decode_step(model, sample: bool = False) -> Callable:
    """(params, cache, tokens) -> (next_tokens, cache).  Greedy by default."""

    def decode_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache

    return decode_step
