"""Serving driver: continuous batching with the Vhost-style 3-stage pipeline.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 16 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_device
from repro.models.api import build_model
from repro.serving.pipeline import Request, VhostStyleServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-cache", type=int, default=128)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--policy", default="least_loaded",
                    choices=["round_robin", "least_loaded", "sticky"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(args.seed))
    server = VhostStyleServer(
        model, params, slots=args.slots, max_cache_len=args.max_cache,
        device=make_device(n_instances=args.instances, policy=args.policy),
    )

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        server.enqueue(
            Request(req_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
        )
    steps = server.run_until_drained()
    dt = time.perf_counter() - t0
    m = server.metrics
    ps = server.device.policy_stats
    placed = ", ".join(f"{k}={v}" for k, v in sorted(ps["decisions"].items()))
    print(f"served {m['completed']}/{args.requests} requests in {steps} pipeline steps, "
          f"{dt:.2f}s; decoded {m['decoded_tokens']} tokens "
          f"({m['decoded_tokens']/dt:.1f} tok/s); copy bursts {m['copy_bursts']}; "
          f"policy {ps['policy']} placements [{placed}]")
    assert m["completed"] == args.requests


if __name__ == "__main__":
    main()
