"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Integrates every substrate layer: synthetic data pipeline (async prefetch),
model zoo, AdamW + grad accumulation + clipping, ZeRO-1 sharding on the
active mesh, async incremental checkpointing (delta+CRC), heartbeat +
straggler tracking, and restart-from-checkpoint on failure.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.core import make_device
from repro.data.pipeline import Prefetcher, SyntheticLMDataset
from repro.distributed.annotate import use_rules
from repro.distributed.fault import Heartbeat, StragglerDetector, run_with_restarts
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.api import build_model
from repro.optim.adamw import AdamW, cosine_schedule


def train(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rules = rules_for_mesh(mesh)
    model = build_model(cfg, mesh=mesh, remat=not args.no_remat)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=20, total=max(args.steps, 21)))
    step_fn = jax.jit(
        make_train_step(model, opt, micro_steps=args.micro_steps),
        donate_argnums=(0, 1),
    )

    # checkpoint traffic (kernel CRCs when enabled) shares one engine pool
    device = make_device(n_instances=getattr(args, "instances", 1),
                         policy=getattr(args, "policy", "round_robin"))
    ckpt = CheckpointManager(
        CheckpointConfig(directory=args.ckpt_dir, full_every=args.full_every,
                         replicas=args.replicas, async_save=True,
                         crc_impl=getattr(args, "crc_impl", "zlib")),
        device=device,
    )
    dataset = SyntheticLMDataset(cfg, args.batch, args.seq, seed=args.seed)
    hb = Heartbeat(str(Path(args.ckpt_dir) / "hb"), rank=0)
    straggler = StragglerDetector()

    def run(start_step: int) -> int:
        rng = jax.random.key(args.seed)
        params = model.init(rng)
        opt_state = opt.init(params)
        if start_step > 0:
            s, tree = ckpt.restore(treedef_like={"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            start_step = s
            print(f"[train] resumed from step {s}")
        prefetch = Prefetcher(dataset, start_step=start_step)
        losses = []
        try:
            with mesh, use_rules(mesh, rules):
                for i in range(start_step, args.steps):
                    t0 = time.perf_counter()
                    step_i, batch = next(prefetch)
                    params, opt_state, metrics = step_fn(params, opt_state, batch)
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    dt = time.perf_counter() - t0
                    straggler.record(0, dt)
                    hb.beat(i)
                    if (i + 1) % args.ckpt_every == 0:
                        ckpt.save(i + 1, {"params": params, "opt": opt_state})
                    if (i + 1) % args.log_every == 0:
                        print(
                            f"step {i+1:5d} loss {loss:.4f} gnorm "
                            f"{float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                            flush=True,
                        )
        finally:
            prefetch.stop()
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
        print(f"[train] done; first loss {losses[0]:.4f} last loss {losses[-1]:.4f}; "
              f"ckpt stats {ckpt.stats}")
        return args.steps

    return run_with_restarts(run, ckpt.latest_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--micro-steps", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--full-every", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--instances", type=int, default=1)
    ap.add_argument("--policy", default="round_robin",
                    choices=["round_robin", "least_loaded", "sticky"])
    ap.add_argument("--crc-impl", default="zlib", choices=["zlib", "kernel"])
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
