"""Data pipeline: deterministic synthetic LM stream + async double-buffered
prefetch.

The prefetcher is the paper's G2 discipline applied to input data: host ->
device batch movement is an asynchronous streaming copy overlapped with the
current step's compute, with a bounded in-flight depth (WQ-depth analogue,
paper Fig. 4).  Determinism: batch(step) is a pure function of (seed, step),
which is what makes checkpoint/restart exactly resumable (DESIGN.md §7).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLMDataset:
    """Zipf-ish token stream with structure (so loss can actually fall):
    tok[t+1] depends on tok[t] through a fixed random bigram table."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        v = min(cfg.vocab_size, 4096)
        rng = np.random.default_rng(seed)
        self._vocab_used = v
        self._bigram = rng.integers(0, v, size=(v, 4)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        v = self._vocab_used
        toks = np.zeros((self.batch, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, v, self.batch)
        choice = rng.integers(0, 4, size=(self.batch, self.seq_len))
        noise = rng.random((self.batch, self.seq_len)) < 0.1
        rand_tok = rng.integers(0, v, size=(self.batch, self.seq_len))
        for t in range(1, self.seq_len):
            nxt = self._bigram[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        batch = {"tokens": toks, "loss_mask": np.ones_like(toks, np.float32)}
        if self.cfg.vlm is not None:
            npch = min(self.cfg.vlm.num_patches, max(self.seq_len - 2, 1))
            batch["patch_embeds"] = rng.normal(size=(self.batch, npch, self.cfg.d_model)).astype(
                np.float32
            ) * 0.02
            pos = np.broadcast_to(np.arange(self.seq_len)[None], (self.batch, self.seq_len))
            batch["positions_thw"] = np.stack([pos, pos, pos]).astype(np.int32)
            batch["loss_mask"][:, 1 : 1 + npch] = 0.0
        if self.cfg.encoder is not None:
            batch["frame_embeds"] = rng.normal(
                size=(self.batch, self.cfg.encoder.source_len, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch


class Prefetcher:
    """Depth-bounded async host->device prefetch (double buffering)."""

    def __init__(self, dataset: SyntheticLMDataset, start_step: int = 0, depth: int = 2,
                 shardings: Optional[Any] = None, dtype=jnp.bfloat16):
        self.dataset = dataset
        self.depth = depth
        self.shardings = shardings
        self.dtype = dtype
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _put_device(self, batch):
        out = {}
        for k, v in batch.items():
            arr = jnp.asarray(v, self.dtype if v.dtype == np.float32 and k != "loss_mask" else None)
            if self.shardings is not None and k in self.shardings:
                arr = jax.device_put(arr, self.shardings[k])
            out[k] = arr
        return out

    def _producer(self):
        while not self._stop.is_set():
            batch = self.dataset.batch_at(self._step)
            try:
                self._q.put((self._step, self._put_device(batch)), timeout=0.5)
                self._step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)
