from repro.data.pipeline import Prefetcher, SyntheticLMDataset

__all__ = ["Prefetcher", "SyntheticLMDataset"]
