"""Asynchronous incremental checkpointing — the paper's Delta Record + CRC +
Dualcast ops as a fault-tolerance subsystem (DESIGN.md §7).

Layout (one directory per save):

  <dir>/step_00000010/            full snapshot
      manifest.json               {step, kind, leaves: {key: {mode, shape,
                                   dtype, crc, nbytes, base_step}}}
      <key>.bin                   raw little-endian bytes
  <dir>/step_00000012/            delta save (vs. the last full snapshot)
      manifest.json
      <key>.delta.npz             offsets[int32] + data[uint32] word granules

Semantics mirror DSA:
  * Create Delta Record with a capacity cap — when a leaf's delta overflows
    (> delta_cap_frac of its words), the completion status is OVERFLOW and
    the manager falls back to a full copy of that leaf (exactly how software
    must handle DSA's delta overflow status).
  * CRC32 per shard file, verified on restore; torn/corrupt saves are
    detected and the manager falls back to the previous valid manifest.
  * replicas=2 fans each shard out twice (Dualcast) for rack-failure
    tolerance.
  * Saves run on a background thread, overlapped with the next train step
    (G2: async always); ``wait()`` joins the in-flight save.

Elastic restore: checkpoints store *logical* arrays (no device layout), so
restore onto any mesh re-shards via ``jax.device_put`` with the target
shardings (tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)


def _tree_flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        out.append((name or "leaf", leaf))
    return out


def _np(leaf) -> np.ndarray:
    return np.asarray(jax.device_get(leaf))


def _u32_view(a: np.ndarray) -> np.ndarray:
    b = a.tobytes()
    pad = (-len(b)) % 4
    if pad:
        b = b + b"\0" * pad
    return np.frombuffer(b, dtype="<u4").copy()


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    full_every: int = 4  # every k-th save is a full snapshot
    delta_cap_frac: float = 0.25  # overflow threshold (fraction of words)
    replicas: int = 1  # 2 => dualcast to <dir>-replica
    verify_crc: bool = True
    async_save: bool = True
    keep: int = 8  # retained saves
    crc_impl: str = "zlib"  # "zlib" (host) | "kernel" (on-device Pallas CRC)


class CheckpointManager:
    def __init__(self, config: CheckpointConfig, device=None):
        self.cfg = config
        self.dir = Path(config.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.replica_dir = Path(str(self.dir) + "-replica") if config.replicas > 1 else None
        if self.replica_dir:
            self.replica_dir.mkdir(parents=True, exist_ok=True)
        self.device = device
        self._thread: Optional[threading.Thread] = None
        self._save_count = 0
        self._base: Optional[Dict[str, np.ndarray]] = None  # last full snapshot (u32 views)
        self._base_step: Optional[int] = None
        self.stats = {"full_leaves": 0, "delta_leaves": 0, "delta_overflows": 0,
                      "bytes_written": 0, "bytes_saved_by_delta": 0}

    # ------------------------------------------------------------------ crc
    def _crc_submit(self, data: bytes):
        """CRC of ``data``: an int for host zlib, or a Future when the CRC
        runs as an engine descriptor (crc_impl="kernel" with a device) —
        the save path submits one per leaf and gathers them with ONE
        ``device.wait_all`` instead of blocking leaf by leaf."""
        if self.cfg.crc_impl == "kernel":
            pad = (-len(data)) % 4
            words = jax.numpy.asarray(np.frombuffer(data + b"\0" * pad, dtype="<u4"))
            if self.device is not None:
                # fused copy+CRC descriptor: the save path reads each leaf
                # out anyway, so one copy_crc launch replaces the separate
                # copy and CRC passes; shows up in telemetry and shares the
                # instance pool with other checkpoint traffic
                fut = self.device.copy_crc_async(words, producer="checkpoint")
                return fut.then(lambda r: int(r[1]))
            from repro.kernels import ops as kops

            return int(kops.crc32(words))
        return zlib.crc32(data) & 0xFFFFFFFF

    def _crc(self, data: bytes) -> int:
        c = self._crc_submit(data)
        return int(c.result()) if hasattr(c, "result") else c

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, force_full: bool = False):
        self.wait()  # one in-flight save at a time
        leaves = [(k, _np(v)) for k, v in _tree_flatten_with_names(tree)]
        is_full = force_full or self._base is None or (self._save_count % self.cfg.full_every == 0)
        self._save_count += 1

        def work():
            self._write(step, leaves, is_full)

        if self.cfg.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves, is_full: bool):
        tmp = self.dir / f".tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: Dict[str, Any] = {
            "step": step,
            "kind": "full" if is_full else "delta",
            "base_step": None if is_full else self._base_step,
            "leaves": {},
        }
        new_base: Dict[str, np.ndarray] = {}
        # kernel CRCs are engine descriptors: submit per leaf, gather ONCE
        # through the completion subsystem (device.wait_all) at the end —
        # all leaf CRCs stream concurrently instead of blocking per leaf
        pending: List[Tuple[Dict[str, Any], str, Any]] = []

        def put_crc(entry: Dict[str, Any], field: str, data: bytes):
            c = self._crc_submit(data)
            if hasattr(c, "result"):
                pending.append((entry, field, c))
            else:
                entry[field] = c

        for key, arr in leaves:
            fn = key.replace("/", "__")
            words = _u32_view(arr)
            entry: Dict[str, Any] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "nbytes": int(arr.nbytes),
            }
            if is_full or key not in (self._base or {}):
                data = arr.tobytes()
                (tmp / f"{fn}.bin").write_bytes(data)
                entry["mode"] = "full"
                put_crc(entry, "crc", data)
                self.stats["full_leaves"] += 1
                self.stats["bytes_written"] += len(data)
                new_base[key] = words
            else:
                base = self._base[key]
                cap = max(int(len(words) * self.cfg.delta_cap_frac), 16)
                diff = np.nonzero(words != base)[0]
                if len(diff) == 0:
                    entry["mode"] = "same"
                    put_crc(entry, "crc", arr.tobytes())
                    self.stats["bytes_saved_by_delta"] += arr.nbytes
                elif len(diff) > cap:
                    # DSA delta-overflow status -> fall back to full copy
                    data = arr.tobytes()
                    (tmp / f"{fn}.bin").write_bytes(data)
                    entry["mode"] = "full"
                    put_crc(entry, "crc", data)
                    self.stats["delta_overflows"] += 1
                    self.stats["bytes_written"] += len(data)
                else:
                    offs = diff.astype(np.int32)
                    vals = words[diff]
                    payload = offs.tobytes() + vals.tobytes()
                    np.savez(tmp / f"{fn}.delta.npz", offsets=offs, data=vals)
                    entry["mode"] = "delta"
                    entry["count"] = int(len(diff))
                    put_crc(entry, "crc", arr.tobytes())  # crc of FINAL contents
                    put_crc(entry, "payload_crc", payload)
                    self.stats["delta_leaves"] += 1
                    self.stats["bytes_written"] += len(payload)
                    self.stats["bytes_saved_by_delta"] += arr.nbytes - len(payload)
            manifest["leaves"][key] = entry
        if pending:
            self.device.wait_all([f for _, _, f in pending])
            for entry, field, fut in pending:
                entry[field] = int(fut.result())
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        if self.replica_dir is not None:  # dualcast fan-out
            rep = self.replica_dir / final.name
            if rep.exists():
                shutil.rmtree(rep)
            shutil.copytree(final, rep)
        if is_full:
            self._base = new_base
            self._base_step = step
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        # never drop the full snapshots that live deltas depend on
        needed = set()
        for s in steps[-self.cfg.keep:]:
            m = self._manifest(s)
            if m and m.get("base_step") is not None:
                needed.add(m["base_step"])
        for s in steps[: -self.cfg.keep]:
            if s not in needed:
                shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------ restore
    def all_steps(self) -> List[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _manifest(self, step: int, directory: Optional[Path] = None) -> Optional[dict]:
        p = (directory or self.dir) / f"step_{step:08d}" / "manifest.json"
        if not p.exists():
            return None
        try:
            return json.loads(p.read_text())
        except json.JSONDecodeError:
            return None

    def _load_leaf_full(self, step: int, key: str, entry: dict, directory: Path) -> np.ndarray:
        fn = key.replace("/", "__")
        data = (directory / f"step_{step:08d}" / f"{fn}.bin").read_bytes()
        if self.cfg.verify_crc and self._crc(data) != entry["crc"]:
            raise IOError(f"CRC mismatch for {key} at step {step}")
        return np.frombuffer(data, dtype=entry["dtype"]).reshape(entry["shape"]).copy()

    def restore(self, step: Optional[int] = None, *, shardings=None, treedef_like=None):
        """Returns (step, tree-of-numpy | tree-of-jax.Array if shardings given).

        Falls back step-by-step past CRC-corrupt saves (replica dir tried
        first when configured)."""
        self.wait()
        candidates = self.all_steps()
        if step is not None:
            candidates = [s for s in candidates if s <= step]
        for s in reversed(candidates):
            try:
                tree = self._restore_step(s)
                if shardings is not None:
                    named = dict(_tree_flatten_with_names(shardings))
                    tree = {
                        k: jax.device_put(v, named[k]) if k in named else v
                        for k, v in tree.items()
                    }
                if treedef_like is not None:
                    tree = self._unflatten_like(treedef_like, tree)
                return s, tree
            except (IOError, FileNotFoundError, KeyError) as e:
                print(f"[checkpoint] step {s} unusable ({e}); falling back")
        raise FileNotFoundError(f"no restorable checkpoint in {self.dir}")

    def _restore_step(self, step: int) -> Dict[str, np.ndarray]:
        for directory in filter(None, [self.dir, self.replica_dir]):
            m = self._manifest(step, directory)
            if m is None:
                continue
            try:
                return self._materialize(m, step, directory)
            except IOError:
                continue  # try replica
        raise IOError(f"step {step}: no valid manifest/replica")

    def _materialize(self, manifest: dict, step: int, directory: Path) -> Dict[str, np.ndarray]:
        base_step = manifest.get("base_step")
        base_manifest = self._manifest(base_step, directory) if base_step is not None else None
        out: Dict[str, np.ndarray] = {}
        for key, entry in manifest["leaves"].items():
            mode = entry["mode"]
            if mode == "full":
                out[key] = self._load_leaf_full(step, key, entry, directory)
            elif mode in ("same", "delta"):
                if base_manifest is None:
                    raise IOError(f"delta save {step} missing base {base_step}")
                arr = self._load_leaf_full(base_step, key, base_manifest["leaves"][key], directory)
                if mode == "delta":
                    fn = key.replace("/", "__")
                    z = np.load(directory / f"step_{step:08d}" / f"{fn}.delta.npz")
                    words = _u32_view(arr)
                    words[z["offsets"]] = z["data"]  # Apply Delta Record
                    arr = (
                        np.frombuffer(words.tobytes()[: entry["nbytes"]], dtype=entry["dtype"])
                        .reshape(entry["shape"]).copy()
                    )
                if self.cfg.verify_crc and self._crc(arr.tobytes()) != entry["crc"]:
                    raise IOError(f"CRC mismatch after delta-apply for {key} at {step}")
                out[key] = arr
            else:
                raise IOError(f"unknown mode {mode}")
        return out

    @staticmethod
    def _unflatten_like(like, named: Dict[str, np.ndarray]):
        names = [k for k, _ in _tree_flatten_with_names(like)]
        leaves = [named[k] for k in names]
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)
