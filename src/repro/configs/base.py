"""Config dataclasses for the model zoo.

Every assigned architecture is described by a frozen ``ModelConfig``.  Configs are
plain data — they never touch jax device state, so importing them is always safe.

``reduced()`` returns a small same-family config for CPU smoke tests; the full
config is only ever exercised abstractly (ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (per-layer)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # Index of the first MoE layer; layers before it use a dense MLP
    # (deepseek-moe keeps layer 0 dense).
    first_moe_layer: int = 0
    # MoE every k-th layer from first_moe_layer (llama4-maverick interleaves
    # dense/MoE with step 2); 1 = every layer.
    moe_every: int = 1
    # Dense d_ff used by the non-MoE leading layers (if any).
    d_ff_dense: int = 0
    # Router options.
    router_jitter: float = 0.0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (audio) models.  The modality frontend is a
    STUB: inputs are precomputed frame embeddings of shape
    (batch, source_len, frontend_dim)."""

    num_layers: int
    source_len: int = 160
    frontend_dim: int = 0  # 0 -> same as d_model


@dataclass(frozen=True)
class VLMConfig:
    """VLM stub frontend: precomputed patch embeddings + M-RoPE sections."""

    num_patches: int = 256
    patch_dim: int = 0  # 0 -> same as d_model
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w halves of head_dim/2


@dataclass(frozen=True)
class HybridConfig:
    """Hymba-style parallel attention + SSM heads inside one layer."""

    ssm: SSMConfig = field(default_factory=SSMConfig)
    num_meta_tokens: int = 128
    # Layer indices that use global (full) attention; the rest use the sliding
    # window.  Hymba uses first / middle / last.
    global_layers: Tuple[int, ...] = ()


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # -- attention structure --------------------------------------------------
    # "global" for full causal attention everywhere; "gemma3" for the repeating
    # (5 local : 1 global) period; "hybrid" per HybridConfig.global_layers.
    attn_pattern: str = "global"
    window_size: int = 0  # sliding window for local layers
    local_per_period: int = 5  # gemma3: locals per period (period = locals + 1)
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3 uses a different theta for globals
    logit_softcap: float = 0.0
    qk_norm: bool = False

    # -- optional blocks -------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vlm: Optional[VLMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # -- misc ------------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"  # silu | gelu
    dtype: str = "bfloat16"
    # layers are scanned unless the stack is irregular (hymba)
    scan_layers: bool = True
    # whether long_500k applies (sub-quadratic state); pure full-attention
    # archs skip it (recorded as SKIP in the dry-run table).
    supports_long_context: bool = False
    # arbitrary provenance note
    source: str = ""

    # ---------------------------------------------------------------------
    def layer_types(self) -> Tuple[str, ...]:
        """Per-layer attention type ('global' | 'local')."""
        if self.attn_pattern == "global":
            return ("global",) * self.num_layers
        if self.attn_pattern == "gemma3":
            period = self.local_per_period + 1
            out = []
            for i in range(self.num_layers):
                out.append("global" if (i % period) == self.local_per_period else "local")
            return tuple(out)
        if self.attn_pattern == "hybrid":
            assert self.hybrid is not None
            g = set(self.hybrid.global_layers)
            return tuple("global" if i in g else "local" for i in range(self.num_layers))
        raise ValueError(f"unknown attn_pattern {self.attn_pattern}")

    def num_params(self) -> int:
        """Approximate parameter count (embedding + layers), for 6ND math."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        h = self.num_heads * self.head_dim
        kvh = self.num_kv_heads * self.head_dim
        attn = d * h + 2 * d * kvh + h * d
        mlp = 3 * d * f
        per_layer = attn + mlp
        if self.moe is not None:
            e = self.moe
            moe_layers = len(self.moe_layer_indices())
            dense_layers = L - moe_layers
            moe_mlp = 3 * d * e.d_ff_expert * (e.num_experts + e.num_shared_experts)
            dense_mlp = 3 * d * (e.d_ff_dense or f)
            per = attn
            total = emb + moe_layers * (per + moe_mlp) + dense_layers * (per + dense_mlp)
            return total
        if self.family == "ssm":
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            per_layer = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state) + di * d
            return emb + L * per_layer
        if self.hybrid is not None:
            di = self.hybrid.ssm.d_inner(d)
            ssm_per = d * di + di * d
            per_layer = attn + mlp + ssm_per
        total = emb + L * per_layer
        if self.encoder is not None:
            # encoder layers: self-attn + mlp; decoder additionally cross-attn
            enc = self.encoder.num_layers * (attn + mlp)
            total += enc + L * attn  # cross-attention blocks in decoder
        return total

    def moe_layer_indices(self) -> Tuple[int, ...]:
        if self.moe is None:
            return ()
        e = self.moe
        return tuple(
            i for i in range(e.first_moe_layer, self.num_layers)
            if (i - e.first_moe_layer) % e.moe_every == 0
        )

    def active_params(self) -> int:
        """Active (per-token) parameter count — differs for MoE."""
        if self.moe is None:
            return self.num_params()
        e = self.moe
        d, L = self.d_model, self.num_layers
        h = self.num_heads * self.head_dim
        kvh = self.num_kv_heads * self.head_dim
        attn = d * h + 2 * d * kvh + h * d
        act_mlp = 3 * d * e.d_ff_expert * (e.top_k + e.num_shared_experts)
        dense_mlp = 3 * d * (e.d_ff_dense or self.d_ff)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        n_moe = len(self.moe_layer_indices())
        return emb + (L - n_moe) * (attn + dense_mlp) + n_moe * (attn + act_mlp)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            window_size=min(self.window_size, 16) if self.window_size else 0,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                d_ff_dense=128 if self.moe.d_ff_dense else 0,
                first_moe_layer=min(self.moe.first_moe_layer, 1),
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32
            )
        if self.encoder is not None:
            changes["encoder"] = dataclasses.replace(
                self.encoder, num_layers=2, source_len=24
            )
        if self.vlm is not None:
            changes["vlm"] = dataclasses.replace(
                self.vlm, num_patches=8, mrope_sections=(4, 6, 6)
            )
        if self.hybrid is not None:
            changes["hybrid"] = dataclasses.replace(
                self.hybrid,
                ssm=dataclasses.replace(self.hybrid.ssm, d_state=8, head_dim=16, chunk_size=16),
                num_meta_tokens=4,
                global_layers=(0, 2),
            )
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
