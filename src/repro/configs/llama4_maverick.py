"""llama4-maverick-400b-a17b — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (expert) vocab=202048,
MoE 128 routed experts top-1 + 1 shared expert.  Maverick INTERLEAVES
dense/MoE layers (interleave_moe_layer_step=2): with every layer MoE the
param count would be ~780B, contradicting the 400B-A17B name; with 24 MoE
layers it lands at ~400B total / ~17B active (see DESIGN.md).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        first_moe_layer=1,
        moe_every=2,
        d_ff_dense=8192,
    ),
    rope_theta=500_000.0,
    act="silu",
    supports_long_context=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
