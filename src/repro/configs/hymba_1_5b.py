"""hymba-1.5b — hybrid parallel attention+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) head_dim=64 d_ff=5504 vocab=32001, ssm_state=16.
Sliding window (1024) on all but 3 global layers (first/middle/last); 128 meta
tokens prepended.  25 heads and vocab 32001 are not divisible by 16 =>
attention head-sharding and vocab-sharding fall back per DESIGN.md §6.
Hybrid constant-state SSM path => long_500k runs.
The layer stack is irregular (3 global layers) => unrolled, not scanned.
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_pattern="hybrid",
    window_size=1024,
    hybrid=HybridConfig(
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=256),
        num_meta_tokens=128,
        global_layers=(0, 15, 31),
    ),
    rope_theta=10_000.0,
    act="silu",
    scan_layers=False,
    supports_long_context=True,
    source="arXiv:2411.13676; hf",
)
