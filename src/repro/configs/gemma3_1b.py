"""gemma3-1b — dense, 5:1 local:global attention, 128k ctx
[hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256 (decoupled).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    attn_pattern="gemma3",
    window_size=1024,
    local_per_period=5,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    act="gelu",
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
