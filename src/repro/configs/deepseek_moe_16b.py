"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066; hf].

28L d_model=2048 16H (kv=16, i.e. MHA) d_ff_expert=1408 vocab=102400,
2 shared + 64 routed experts top-6; layer 0 is dense with d_ff 10944.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        first_moe_layer=1,
        d_ff_dense=10944,
    ),
    rope_theta=10_000.0,
    act="silu",
    supports_long_context=False,
    source="arXiv:2401.06066; hf",
)
