"""seamless-m4t-medium — enc-dec, multimodal audio [arXiv:2308.11596; hf].

12L (encoder) + 12L (decoder) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (batch, source_len, d_model).  vocab 256206 not divisible by 16 =>
embedding shards on d_model.  Decode shapes exercise the decoder (self-attn KV
cache + static cross-attention KV over the encoded source).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    encoder=EncoderConfig(num_layers=12, source_len=160),
    rope_theta=10_000.0,
    act="gelu",
    supports_long_context=False,
    source="arXiv:2308.11596; hf",
)
