"""Architecture registry: ``get_config(arch_id)`` and the canonical cell list.

Cell = (architecture x input shape).  ``applicable(cfg, shape)`` encodes the
assignment rules: long_500k only for sub-quadratic-state archs; decode shapes
skipped for encoder-only stacks (none of the assigned archs are encoder-only —
seamless is enc-dec, its decoder decodes).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig

_MODULES: Dict[str, str] = {
    "deepseek-67b": "repro.configs.deepseek_67b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for one cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode state is unbounded (DESIGN.md)"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    """Every assigned (arch, shape) pair, including skip cells."""
    return [(a, s.name) for a in ARCH_IDS for s in SHAPES]


def runnable_cells() -> List[Tuple[str, str]]:
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, _ = applicable(cfg, s)
            if ok:
                out.append((a, s.name))
    return out


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "SHAPES_BY_NAME",
    "get_config",
    "applicable",
    "all_cells",
    "runnable_cells",
]
