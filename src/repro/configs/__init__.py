from repro.configs.base import (
    EncoderConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    SSMConfig,
    VLMConfig,
)
from repro.configs.registry import (
    ARCH_IDS,
    all_cells,
    applicable,
    get_config,
    runnable_cells,
)

__all__ = [
    "ARCH_IDS",
    "EncoderConfig",
    "HybridConfig",
    "ModelConfig",
    "MoEConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "ShapeConfig",
    "SSMConfig",
    "VLMConfig",
    "all_cells",
    "applicable",
    "get_config",
    "runnable_cells",
]
