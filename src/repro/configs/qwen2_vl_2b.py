"""qwen2-vl-2b — VLM backbone with M-RoPE, dynamic resolution
[arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, head_dim=128,
mrope sections (16, 24, 24).  The vision frontend is a STUB: input_specs()
provides precomputed patch embeddings + 3D (t,h,w) positions.
12 heads not divisible by 16 => attention head-sharding falls back.
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    vlm=VLMConfig(num_patches=256, mrope_sections=(16, 24, 24)),
    rope_theta=1_000_000.0,
    act="silu",
    supports_long_context=False,
    source="arXiv:2409.12191; hf",
)
