"""deepseek-67b — dense llama-arch [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
Pure full attention => long_500k is skipped (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10_000.0,
    act="silu",
    supports_long_context=False,
    source="arXiv:2401.02954; hf",
)
