"""gemma3-4b — dense, 5:1 local:global attention, 128k ctx
[hf:google/gemma-3-4b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256 (decoupled),
sliding window 1024, global layers every 6th, tied embeddings, qk-norm.
Sliding window on 5/6 layers bounds per-token state => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    attn_pattern="gemma3",
    window_size=1024,
    local_per_period=5,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    act="gelu",
    supports_long_context=True,
    source="hf:google/gemma-3-4b-pt; unverified",
)
