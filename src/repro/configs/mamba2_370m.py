"""mamba2-370m — SSM, SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=1024 (attention-free) vocab=50280, ssm_state=128, expand=2,
head_dim=64 => d_inner=2048, 32 SSD heads.
Attention-free => constant per-token state; long_500k runs.
vocab 50280 is not divisible by 16 => embedding shards on d_model (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=256),
    tie_embeddings=True,
    supports_long_context=True,
    source="arXiv:2405.21060; unverified",
)
