from repro.serving.kv_pool import PagedKVPool
from repro.serving.pipeline import Request, VhostStyleServer

__all__ = ["PagedKVPool", "Request", "VhostStyleServer"]
