from repro.serving.kv_pool import PagedKVPool
from repro.serving.nullmodel import NullDecoder
from repro.serving.pipeline import ReorderArray, Request, VhostStyleServer
from repro.serving.slo import (
    DEFAULT_SLO_CLASSES,
    AdmissionController,
    LatencyTracker,
    SLOClass,
)
from repro.serving.traffic import (
    ArrivalProcess,
    BurstyArrivals,
    DiurnalArrivals,
    OpenRequest,
    PoissonArrivals,
    TrafficGenerator,
    ZipfLengths,
)

__all__ = [
    "AdmissionController",
    "ArrivalProcess",
    "BurstyArrivals",
    "DEFAULT_SLO_CLASSES",
    "DiurnalArrivals",
    "LatencyTracker",
    "NullDecoder",
    "OpenRequest",
    "PagedKVPool",
    "PoissonArrivals",
    "ReorderArray",
    "Request",
    "SLOClass",
    "TrafficGenerator",
    "VhostStyleServer",
    "ZipfLengths",
]
