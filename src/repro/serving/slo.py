"""SLO classes, latency accounting, and graceful-overload admission.

The QoS machinery built across PRs 2/3/5 — priority WQs, ``wait_any``,
per-node admission — only earns its keep when traffic exceeds capacity.
This module is the policy layer that exercises it:

  SLOClass             a named service class: a p99 latency target, the WQ
                       its admission copies ride (mapped onto the PR 2
                       priority WQs), an admission priority (higher-priority
                       classes jump the waiting queue), and whether overload
                       sheds it first.
  LatencyTracker       per-class virtual-clock latency accounting (TTFT and
                       end-to-end), with exact percentile queries — what
                       the fig17 benchmark and the overload soak assert on.
  AdmissionController  SLO-aware admission with graceful shedding.  Three
                       signals gate an arrival, in order of cost:
                         (1) per-class waiting-queue watermarks (shed-first
                             classes get half the depth budget),
                         (2) the device WQ occupancy probe
                             (``Device.occupancy``, PR 7's queues.py hook),
                         (3) per-node engine occupancy from a live
                             ``obs.Sampler`` when one is attached.
                       ``QueueFull`` backpressure from the engine is the
                       fourth, reactive signal: the serving pipeline calls
                       ``on_backpressure`` when a submit exhausts backoff.
                       Every decision is counted, and the accounting
                       identity  generated == admitted + shed  is checked
                       by ``closes()`` — the soak test's conservation law.

Hyperion (PAPERS.md, arXiv 2205.08882) argues queue-level backpressure must
be the producer/datapath contract rather than host-side pacing; this module
implements exactly that contract for the Vhost-style server.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple


# --------------------------------------------------------------------------- classes
@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service class.

    target_p99_s   end-to-end p99 latency target on the VIRTUAL clock;
                   requests finishing within it count toward goodput.
    wq             name of the WQ its admission copies target (``None``:
                   the device default) — the PR 2 priority-WQ mapping.
    priority       admission ordering: among queued requests, the highest
                   priority class admits first (FIFO within a class).
    shed_first     overload sheds this class before protected ones (its
                   queue watermark is halved, and reactive shedding prefers
                   it when draining backlog).
    """

    name: str
    target_p99_s: float
    wq: Optional[str] = None
    priority: int = 1
    shed_first: bool = False

    def __post_init__(self):
        if self.target_p99_s <= 0:
            raise ValueError(
                f"target_p99_s must be > 0, got {self.target_p99_s}")
        if self.priority < 1:
            raise ValueError(f"priority must be >= 1, got {self.priority}")


#: the serving default: an interactive class riding the high-priority
#: dedicated WQ, and a throughput class riding the shared bulk WQ that
#: overload sheds first (paper Fig. 9 QoS mapped to SLOs).
DEFAULT_SLO_CLASSES = (
    SLOClass("latency", target_p99_s=0.25, wq="latency", priority=12),
    SLOClass("bulk", target_p99_s=2.0, wq="bulk", priority=2,
             shed_first=True),
)


def classes_by_name(
        classes: Iterable[SLOClass] = DEFAULT_SLO_CLASSES
) -> Dict[str, SLOClass]:
    out: Dict[str, SLOClass] = {}
    for c in classes:
        if c.name in out:
            raise ValueError(f"duplicate SLO class {c.name!r}")
        out[c.name] = c
    return out


# --------------------------------------------------------------------------- latency accounting
def percentile(values: Sequence[float], p: float) -> float:
    """Exact nearest-rank percentile (p in [0, 100]); NaN when empty so a
    missing class can't silently pass a threshold assertion."""
    if not 0 <= p <= 100:
        raise ValueError(f"p must be in [0, 100], got {p}")
    if not values:
        return float("nan")
    xs = sorted(values)
    rank = max(int(math.ceil(p / 100.0 * len(xs))) - 1, 0)
    return float(xs[rank])


class LatencyTracker:
    """Per-class virtual-time latency samples: TTFT (arrival -> first
    token) and e2e (arrival -> done)."""

    def __init__(self, classes: Iterable[SLOClass] = DEFAULT_SLO_CLASSES):
        self.classes = classes_by_name(classes)
        self._ttft: Dict[str, List[float]] = {c: [] for c in self.classes}
        self._e2e: Dict[str, List[float]] = {c: [] for c in self.classes}

    def record(self, slo: str, arrival_s: float,
               first_token_s: Optional[float], done_s: float) -> None:
        if slo not in self.classes:
            raise KeyError(f"unknown SLO class {slo!r}; "
                           f"have {sorted(self.classes)}")
        if first_token_s is not None:
            self._ttft[slo].append(first_token_s - arrival_s)
        self._e2e[slo].append(done_s - arrival_s)

    def count(self, slo: str) -> int:
        return len(self._e2e[slo])

    def p(self, slo: str, q: float, kind: str = "e2e") -> float:
        samples = {"e2e": self._e2e, "ttft": self._ttft}[kind][slo]
        return percentile(samples, q)

    def within_slo(self, slo: str) -> int:
        """How many completions met their class's p99 target (the goodput
        numerator)."""
        target = self.classes[slo].target_p99_s
        return sum(1 for v in self._e2e[slo] if v <= target)

    def summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for name in sorted(self.classes):
            e2e = self._e2e[name]
            out[name] = {
                "n": len(e2e),
                "p50_s": percentile(e2e, 50),
                "p99_s": percentile(e2e, 99),
                "ttft_p50_s": percentile(self._ttft[name], 50),
                "ttft_p99_s": percentile(self._ttft[name], 99),
                "within_slo": self.within_slo(name),
            }
        return out


# --------------------------------------------------------------------------- admission
class AdmissionController:
    """Graceful-overload gate between the traffic source and the server.

    A ``None`` device/sampler simply disables that signal, so the
    controller degrades to pure queue-watermark shedding — the configuration
    the deterministic soak test uses."""

    def __init__(self, classes: Iterable[SLOClass] = DEFAULT_SLO_CLASSES, *,
                 queue_watermark: int = 64,
                 wq_occupancy_high: float = 0.95,
                 node_occupancy_high: float = 0.98,
                 device: Any = None, sampler: Any = None):
        if queue_watermark < 1:
            raise ValueError(
                f"queue_watermark must be >= 1, got {queue_watermark}")
        self.classes = classes_by_name(classes)
        self.queue_watermark = queue_watermark
        self.wq_occupancy_high = wq_occupancy_high
        self.node_occupancy_high = node_occupancy_high
        self.device = device
        self.sampler = sampler
        zero = {"generated": 0, "admitted": 0, "shed": 0,
                "shed_watermark": 0, "shed_wq_occupancy": 0,
                "shed_node_occupancy": 0, "shed_backpressure": 0}
        self.counters: Dict[str, Dict[str, int]] = {
            c: dict(zero) for c in self.classes}

    # -- signal reads --------------------------------------------------------
    def _watermark(self, cls: SLOClass) -> int:
        # shed-first classes get half the backlog budget: under overload
        # their arrivals are turned away while protected classes still queue
        return max(self.queue_watermark // (2 if cls.shed_first else 1), 1)

    def _wq_saturated(self, cls: SLOClass) -> bool:
        if self.device is None or cls.wq is None:
            return False
        occ = self.device.occupancy(wq=cls.wq)
        return occ is not None and occ >= self.wq_occupancy_high

    def _node_saturated(self, node: Optional[int]) -> bool:
        if self.sampler is None:
            return False
        occ = _sampler_node_occupancy(self.sampler, node)
        return occ is not None and occ >= self.node_occupancy_high

    # -- decisions -----------------------------------------------------------
    def admit(self, slo: str, queue_depth: int,
              node: Optional[int] = None) -> bool:
        """Admission decision for one arrival; counts both outcomes.
        ``queue_depth`` is the class's current waiting-queue depth."""
        cls = self.classes[slo]
        c = self.counters[slo]
        c["generated"] += 1
        if queue_depth >= self._watermark(cls):
            c["shed"] += 1
            c["shed_watermark"] += 1
            return False
        if self._wq_saturated(cls):
            c["shed"] += 1
            c["shed_wq_occupancy"] += 1
            return False
        if self._node_saturated(node):
            c["shed"] += 1
            c["shed_node_occupancy"] += 1
            return False
        c["admitted"] += 1
        return True

    def on_backpressure(self, slo: str) -> bool:
        """The engine said no (``QueueFull`` survived bounded backoff) for
        an ALREADY-ADMITTED request.  Shed-first classes are dropped (their
        admission converts to a shed); protected classes are kept queued —
        backpressure pushes back on bulk before it touches latency traffic.
        Returns True when the request should be shed."""
        cls = self.classes[slo]
        c = self.counters[slo]
        if cls.shed_first:
            c["admitted"] -= 1
            c["shed"] += 1
            c["shed_backpressure"] += 1
            return True
        c["shed_backpressure"] += 0  # keep key hot for exports
        return False

    # -- accounting ----------------------------------------------------------
    def totals(self) -> Dict[str, int]:
        out = {"generated": 0, "admitted": 0, "shed": 0}
        for c in self.counters.values():
            for k in out:
                out[k] += c[k]
        return out

    def closes(self) -> bool:
        """The conservation law: every generated request was either
        admitted or shed, per class and in total."""
        return all(c["generated"] == c["admitted"] + c["shed"]
                   for c in self.counters.values())


def _sampler_node_occupancy(sampler: Any, node: Optional[int]) -> Optional[float]:
    """Most recent per-engine WQ-occupancy gauge from an obs Sampler,
    restricted to ``node``'s engines when given (engine names carry the
    node: ``n{node}dsa{i}``), else the max across the fabric."""
    series = getattr(sampler, "series", None)
    if not series:
        return None
    want = None if node is None else f"engine.n{node}dsa"
    best: Optional[float] = None
    for name, s in series.items():
        if not (name.startswith("engine.") and name.endswith(".wq_occupancy")):
            continue
        if want is not None and not name.startswith(want):
            continue
        if len(s) == 0:
            continue
        v = s.last()
        best = v if best is None else max(best, v)
    return best
