"""Null decoder: a constant-work model for open-loop datapath experiments.

DPDK benchmarks its datapath against the *null PMD* — a driver that accepts
every packet and does no per-packet work — so queueing, admission, and copy
behaviour are measured without the workload's own compute noise.
``NullDecoder`` is that for the Vhost-style server: it satisfies the full
serving model interface (``init`` / ``init_cache`` / ``prefill`` /
``decode_step``), is jit- and donation-compatible, and emits the
deterministic token stream ``tok -> (tok + 1) % vocab``, while costing
near-zero compute.  The overload soak tests and ``benchmarks/
fig17_openloop.py`` drive thousands of virtual-clock steps through the REAL
pipeline (WQs, batch descriptors, reorder array, KV pool) with this model
in the decode slot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class NullDecoder:
    """Minimal model honouring the serving interface.

    The cache is one stacked segment ``[1, B, 1]`` (so ``_splice_cache``
    exercises the same stacked-leaf path a real scanned decoder hits) plus
    the ``lengths`` vector every cache carries."""

    def __init__(self, vocab_size: int = 256):
        if vocab_size < 2:
            raise ValueError(f"vocab_size must be >= 2, got {vocab_size}")
        self.vocab_size = vocab_size

    def init(self, key) -> dict:
        return {}

    def init_cache(self, batch: int, max_cache_len: int) -> dict:
        return {
            "segments": [{"state": jnp.zeros((1, batch, 1), jnp.float32)}],
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, params, batch: dict, max_cache_len: int):
        tokens = batch["tokens"]  # [B, S]
        b, s = tokens.shape
        cache = {
            "segments": [{"state": jnp.zeros((1, b, 1), jnp.float32)}],
            "lengths": jnp.full((b,), s, jnp.int32),
        }
        logits = jax.nn.one_hot((tokens[:, -1] + 1) % self.vocab_size,
                                self.vocab_size)
        return cache, logits, cache["lengths"]

    def decode_step(self, params, cache: dict, tokens):
        # tokens [B, 1] -> logits [B, V]; the cache only tracks lengths
        logits = jax.nn.one_hot((tokens[:, 0] + 1) % self.vocab_size,
                                self.vocab_size)
        cache = {
            "segments": cache["segments"],
            "lengths": cache["lengths"] + 1,
        }
        return logits, cache
