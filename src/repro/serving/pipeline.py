"""Continuous-batching serving engine with the paper's DPDK-Vhost offload
pattern (§6.4) mapped onto LLM decode:

  virtqueue            -> request queue + fixed decode slots
  packet copy          -> KV page / prompt movement through the stream engine
  3-stage pipeline     -> (1) one ``device.wait_any`` pass over the in-flight
                          copy futures (timeout=0: a single UMWAIT-style
                          poll, no busy loop) and commit IN ORDER via the
                          reorder array;
                          (2) assemble + submit this iteration's batched
                          copy descriptors (one BatchDescriptor per burst,
                          G1: burst size ~32);
                          (3) run the decode step on the model while the
                          engine moves pages (G2: async always).
  reorder array        -> per-queue ring marking which in-flight copies
                          completed; commits stop at the first incomplete
                          entry so requests always admit in arrival order.
  DWQ-per-core binding -> one DWQ per server worker (G6).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Device, OpType, Status, WorkDescriptor, WQConfig
from repro.core.descriptor import BatchDescriptor

#: default WQ provisioning for a serving device (paper Fig. 9 + G6): a small
#: high-priority dedicated WQ for latency-critical admission copies (steered
#: to cache so the prefill that consumes them reads warm lines, Fig. 12) and
#: a large low-priority shared WQ for bulk background traffic.
SERVING_WQ_CONFIGS = (
    WQConfig("latency", mode="dedicated", size=16, priority=12,
             traffic_class="to_cache"),
    WQConfig("bulk", mode="shared", size=48, priority=2,
             traffic_class="to_memory"),
)


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    # NUMA home (paper §4): the node whose engines move this request's pages
    # and whose KV shard should hold them.  None = assigned at enqueue
    # (round-robin across the fabric) or left unset on a single-node device.
    home_node: Optional[int] = None
    arrived_at: float = dataclasses.field(default_factory=time.perf_counter)
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    output: List[int] = dataclasses.field(default_factory=list)


class ReorderArray:
    """In-order commit over out-of-order completions (paper Fig. 16a).
    Entries are Futures (anything with ``is_done()``)."""

    def __init__(self, size: int = 128):
        self.size = size
        self._entries: deque = deque()  # (tag, future, payload)

    def push(self, tag: int, future, payload: Any):
        self._entries.append((tag, future, payload))

    def pop_completed(self) -> List[Tuple[int, Any]]:
        """Commit the longest completed PREFIX (in-order semantics)."""
        out = []
        while self._entries and self._entries[0][1].is_done():
            tag, fut, payload = self._entries.popleft()
            out.append((tag, payload))
        return out

    def pending_futures(self) -> List[Any]:
        """The in-flight entries' futures, head first — the wait set for
        ``device.wait_any``/``as_completed``."""
        return [fut for _, fut, _ in self._entries]

    def __len__(self):
        return len(self._entries)


class VhostStyleServer:
    """Greedy-decode continuous batching over a DecoderModel."""

    def __init__(self, model, params, *, slots: int = 4, max_cache_len: int = 256,
                 device: Optional[Device] = None, burst: int = 32,
                 topology=None, observer=None):
        from repro.launch.steps import make_decode_step, make_prefill_step

        self.model = model
        self.params = params
        self.slots = slots
        self.max_cache_len = max_cache_len
        if device is None:
            # one engine group per node: the topology's per-node engine
            # counts provision the fabric, and numa_local keeps each
            # request's copies on its home node (paper §4 guideline)
            device = Device(
                wq_configs=list(SERVING_WQ_CONFIGS), topology=topology,
                policy="numa_local" if topology is not None
                and topology.n_nodes > 1 else "round_robin",
            )
        elif topology is not None:
            raise ValueError("pass a pre-built device= OR a topology= to "
                             "provision one from, not both (the device "
                             "already fixes its fabric)")
        self.device = device
        self.topology = self.device.topology
        self._node_rr = 0  # round-robin home-node assignment at enqueue
        self.burst = burst
        # admission copies gate time-to-first-token: steer them to the
        # high-priority WQ when the device has one, else the default WQ
        self._copy_wq = "latency" if self.device.has_wq("latency") else None
        self.reorder = ReorderArray()
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self.lengths_target: Dict[int, int] = {}
        self.cache = model.init_cache(slots, max_cache_len)
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(1,))
        self._free_slots = list(range(slots))[::-1]
        self._tokens = jnp.zeros((slots, 1), jnp.int32)
        self._tag = 0
        self.metrics = {"decoded_tokens": 0, "admitted": 0, "completed": 0,
                        "copy_bursts": 0, "steps": 0,
                        "admitted_by_node": {}}
        # anything with .gauge(name, value) — normally an obs.Sampler; each
        # step() emits per-stage wall times and occupancy gauges so the
        # serving loop shows up in the same time series as the engines
        self.observer = observer

    # ------------------------------------------------------------------ API
    def enqueue(self, req: Request):
        """Admit to the waiting queue; on a multi-node fabric, unassigned
        requests get a home node round-robin so their copy bursts (and KV
        pages) stay NUMA-local to one node's engine group."""
        if req.home_node is None and self.topology.n_nodes > 1:
            req.home_node = self._node_rr % self.topology.n_nodes
            self._node_rr += 1
        self.queue.append(req)

    # ------------------------------------------------------------------ stage 1: poll + in-order commit
    def _stage_poll_commit(self, block: bool = False):
        """One completion-subsystem pass over the in-flight copy futures.

        ``timeout=0`` makes ``wait_any`` a single wait-policy poll (no busy
        loop) so decode still overlaps the copies; ``block=True`` — used
        when draining with nothing else to run — parks the host on the HEAD
        future (in-order commit can't advance past it) under the device's
        wait policy, freeing the cycles the paper's Fig. 11 measures."""
        futs = self.reorder.pending_futures()
        if futs:
            self.device.wait_any(futs[:1] if block else futs,
                                 timeout=None if block else 0)
        for _, payload in self.reorder.pop_completed():
            slot, req = payload
            self._admit_now(slot, req)

    def _admit_now(self, slot: int, req: Request):
        """Prompt pages have landed: prefill this slot's cache region."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        cache1, logits, _ = self.model.prefill(self.params, {"tokens": prompt}, self.max_cache_len)
        # splice the single-sequence cache into the batch cache at `slot`
        self.cache = _splice_cache(self.cache, cache1, slot)
        tok = int(jnp.argmax(logits[0]))
        req.output.append(tok)
        req.first_token_at = time.perf_counter()
        self._tokens = self._tokens.at[slot, 0].set(tok)
        self.active[slot] = req
        self.metrics["admitted"] += 1
        if req.home_node is not None:
            by_node = self.metrics["admitted_by_node"]
            by_node[req.home_node] = by_node.get(req.home_node, 0) + 1

    # ------------------------------------------------------------------ stage 2: submit batched copies
    def _stage_submit_copies(self):
        while self._free_slots and self.queue:
            slot = self._free_slots.pop()
            req = self.queue.popleft()
            # burst the prompt over as a batch descriptor (packet copy analogue)
            chunks = np.array_split(req.prompt, max(1, len(req.prompt) // 64))
            descs = [
                WorkDescriptor(op=OpType.MEMCPY, src=jnp.asarray(np.ascontiguousarray(c)))
                for c in chunks[: self.burst]
            ]
            fut = self.device.batch_async(descs, producer=f"slot{slot}",
                                          wq=self._copy_wq,
                                          node=req.home_node)
            self.reorder.push(self._tag, fut, (slot, req))
            self._tag += 1
            self.metrics["copy_bursts"] += 1

    # ------------------------------------------------------------------ stage 3: decode step
    def _stage_decode(self):
        if not self.active:
            return
        next_tokens, self.cache = self._decode(self.params, self.cache, self._tokens)
        self._tokens = next_tokens
        self.metrics["decoded_tokens"] += len(self.active)
        done_slots = []
        for slot, req in self.active.items():
            tok = int(next_tokens[slot, 0])
            req.output.append(tok)
            if len(req.output) >= req.max_new_tokens:
                req.done_at = time.perf_counter()
                done_slots.append(slot)
        for slot in done_slots:
            self.metrics["completed"] += 1
            del self.active[slot]
            self._free_slots.append(slot)

    # ------------------------------------------------------------------ loop
    def step(self):
        # (1) completions -> in-order admit.  With decode work in flight OR
        # queued requests that stage 2 can still submit (a free slot
        # exists), the pass is non-blocking (timeout=0) so compute and new
        # copy bursts overlap the in-flight ones (G2); when neither stage
        # can make progress, park on the head copy under the device's wait
        # policy instead of spinning the loop.
        can_submit = bool(self.queue) and bool(self._free_slots)
        t0 = time.perf_counter()
        self._stage_poll_commit(block=not self.active and not can_submit
                                and len(self.reorder) > 0)
        t1 = time.perf_counter()
        self._stage_submit_copies() # (2) batch descriptors for new requests
        t2 = time.perf_counter()
        self._stage_decode()        # (3) compute overlapped with copies
        t3 = time.perf_counter()
        self.metrics["steps"] += 1
        if self.observer is not None:
            obs = self.observer
            obs.gauge("serving.queue_depth", len(self.queue))
            obs.gauge("serving.active_slots", len(self.active))
            obs.gauge("serving.slot_occupancy", len(self.active) / self.slots)
            obs.gauge("serving.inflight_copies", len(self.reorder))
            obs.gauge("serving.stage.poll_us", (t1 - t0) * 1e6)
            obs.gauge("serving.stage.submit_us", (t2 - t1) * 1e6)
            obs.gauge("serving.stage.decode_us", (t3 - t2) * 1e6)

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self.active or len(self.reorder)) and steps < max_steps:
            self.step()
            steps += 1
        self.device.drain()
        return steps


def _splice_cache(batch_cache, one_cache, slot: int):
    """Write a batch-1 cache into row `slot` of the batch cache.

    lengths is [B]; other leaves have batch as the SECOND dim under layer
    stacking for scanned segments ([L, B, ...]) or the first dim for
    unrolled per-layer caches."""

    def splice(dst, src):
        if dst is None:
            return None
        if dst.ndim >= 2 and src.ndim == dst.ndim and src.shape[0] == dst.shape[0]:
            # stacked [L, B, ...]
            return dst.at[:, slot].set(src[:, 0])
        if src.ndim == dst.ndim:
            return dst.at[slot].set(src[0])
        return dst

    import jax

    dst_segs = batch_cache["segments"]
    src_segs = one_cache["segments"]
    new_segs = []
    for d, s in zip(dst_segs, src_segs):
        new_segs.append(jax.tree.map(splice, d, s))
    lengths = batch_cache["lengths"].at[slot].set(one_cache["lengths"][0])
    return {"segments": new_segs, "lengths": lengths}
