"""Continuous-batching serving engine with the paper's DPDK-Vhost offload
pattern (§6.4) mapped onto LLM decode:

  virtqueue            -> request queue + fixed decode slots
  packet copy          -> KV page / prompt movement through the stream engine
  3-stage pipeline     -> (1) one ``device.wait_any`` pass over the in-flight
                          copy futures (timeout=0: a single UMWAIT-style
                          poll, no busy loop) and commit IN ORDER via the
                          reorder array;
                          (2) assemble + submit this iteration's batched
                          copy descriptors (one BatchDescriptor per burst,
                          G1: burst size ~32);
                          (3) run the decode step on the model while the
                          engine moves pages (G2: async always).
  reorder array        -> per-queue ring marking which in-flight copies
                          completed; commits stop at the first incomplete
                          entry so requests always admit in arrival order.
  DWQ-per-core binding -> one DWQ per server worker (G6).
  open-loop traffic    -> ``run_open_loop`` drives the server from a
                          ``TrafficGenerator`` on a virtual clock: arrivals
                          land whether or not the server keeps up, SLO
                          classes map onto the priority WQs, and overload is
                          shed at admission (watermarks/occupancy) or on
                          ``QueueFull`` backpressure — the paper's sustained
                          packet-arrival regime instead of a replayed list.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
import time
from collections import Counter, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import lockcheck as _lockcheck
from repro.core import Device, OpType, QueueFull, Status, WorkDescriptor, WQConfig
from repro.core.descriptor import BatchDescriptor
from repro.serving.slo import DEFAULT_SLO_CLASSES, classes_by_name

#: default WQ provisioning for a serving device (paper Fig. 9 + G6): a small
#: high-priority dedicated WQ for latency-critical admission copies (steered
#: to cache so the prefill that consumes them reads warm lines, Fig. 12) and
#: a large low-priority shared WQ for bulk background traffic.
SERVING_WQ_CONFIGS = (
    WQConfig("latency", mode="dedicated", size=16, priority=12,
             traffic_class="to_cache"),
    WQConfig("bulk", mode="shared", size=48, priority=2,
             traffic_class="to_memory"),
)


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    # NUMA home (paper §4): the node whose engines move this request's pages
    # and whose KV shard should hold them.  None = assigned at enqueue
    # (round-robin across the fabric) or left unset on a single-node device.
    home_node: Optional[int] = None
    # SLO class (serving/slo.py): picks the admission-copy WQ and the
    # admission priority.  The default keeps the pre-SLO behaviour — every
    # admission copy rides the high-priority latency WQ.
    slo: str = "latency"
    arrived_at: float = dataclasses.field(default_factory=time.perf_counter)
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    # virtual-clock stamps (open-loop runs): arrival_s comes from the
    # traffic trace; the server stamps the other two from its ``now_s``
    arrival_s: Optional[float] = None
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    # device KV pages reserved at admission (0 = none / kv_pool disabled)
    kv_pages: int = 0
    output: List[int] = dataclasses.field(default_factory=list)


class ReorderArray:
    """In-order commit over out-of-order completions (paper Fig. 16a).
    Entries are Futures (anything with ``is_done()``).

    ``pop_completed`` is atomic AND reentrancy-guarded.  Under continuous
    admission a completion can be observed mid-drain — a future's
    ``is_done()`` pumps the engine, whose completion callback may re-enter
    the commit path while the outer drain is between its done-check and its
    pop.  The unguarded check-then-pop then commits the wrong entry: the
    inner call pops the head the outer call just checked, and the outer pop
    takes the NEXT (possibly incomplete) entry — a double/premature commit
    that re-admits a slot.  tests/test_serving.py pins the crafted
    completion order that reproduced this."""

    def __init__(self, size: int = 128):
        self.size = size
        self._entries: deque = deque()  # (tag, future, payload)
        self._lock = _lockcheck.checked_rlock("serving.reorder")
        self._draining = False

    def push(self, tag: int, future, payload: Any):
        with self._lock:
            self._entries.append((tag, future, payload))

    def pop_completed(self) -> List[Tuple[int, Any]]:
        """Commit the longest completed PREFIX (in-order semantics).  A
        reentrant call (completion callback firing inside ``is_done()``)
        returns [] — the outer drain owns the commit."""
        with self._lock:
            if self._draining:
                return []
            self._draining = True
            try:
                out: List[Tuple[int, Any]] = []
                while self._entries:
                    tag, fut, payload = self._entries[0]
                    if not fut.is_done():
                        break
                    self._entries.popleft()
                    out.append((tag, payload))
                return out
            finally:
                self._draining = False

    def pending_futures(self) -> List[Any]:
        """The in-flight entries' futures, head first — the wait set for
        ``device.wait_any``/``as_completed``."""
        with self._lock:
            return [fut for _, fut, _ in self._entries]

    def __len__(self):
        return len(self._entries)


class VhostStyleServer:
    """Greedy-decode continuous batching over a DecoderModel."""

    def __init__(self, model, params, *, slots: int = 4, max_cache_len: int = 256,
                 device: Optional[Device] = None, burst: int = 32,
                 topology=None, observer=None, kv_pool=None,
                 slo_classes=None, admission=None, tracker=None):
        from repro.launch.steps import make_decode_step, make_prefill_step

        self.model = model
        self.params = params
        self.slots = slots
        self.max_cache_len = max_cache_len
        if device is None:
            # one engine group per node: the topology's per-node engine
            # counts provision the fabric, and numa_local keeps each
            # request's copies on its home node (paper §4 guideline)
            device = Device(
                wq_configs=list(SERVING_WQ_CONFIGS), topology=topology,
                policy="numa_local" if topology is not None
                and topology.n_nodes > 1 else "round_robin",
            )
        elif topology is not None:
            raise ValueError("pass a pre-built device= OR a topology= to "
                             "provision one from, not both (the device "
                             "already fixes its fabric)")
        self.device = device
        self.topology = self.device.topology
        self._node_rr = 0  # round-robin home-node assignment at enqueue
        self.burst = burst
        # admission copies gate time-to-first-token: steer them to the
        # high-priority WQ when the device has one, else the default WQ
        self._copy_wq = "latency" if self.device.has_wq("latency") else None
        # SLO classes (serving/slo.py): per-request WQ mapping + admission
        # priority; registered with the device so submits carry slo= hints
        self._slo_classes = classes_by_name(slo_classes or DEFAULT_SLO_CLASSES)
        self.device.register_slo_classes(self._slo_classes.values())
        # optional PagedKVPool: admission reserves the prompt's device pages
        # before the copy burst, completion/shed releases them — the KV
        # occupancy is then a real admission signal and the no-leak contract
        # extends to the open-loop path
        self.kv_pool = kv_pool
        # optional slo.AdmissionController / slo.LatencyTracker, wired by
        # run_open_loop or the caller
        self.admission = admission
        self.tracker = tracker
        # virtual clock (seconds) for open-loop runs; the driver advances it
        self.now_s: float = 0.0
        self.reorder = ReorderArray()
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self.lengths_target: Dict[int, int] = {}
        self.cache = model.init_cache(slots, max_cache_len)
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(1,))
        self._free_slots = list(range(slots))[::-1]
        self._tokens = jnp.zeros((slots, 1), jnp.int32)
        self._tag = 0
        self.metrics = {"decoded_tokens": 0, "admitted": 0, "completed": 0,
                        "copy_bursts": 0, "steps": 0, "shed": 0,
                        "shed_backpressure": 0,
                        "backpressure_events": 0, "kv_alloc_failures": 0,
                        "admitted_by_node": {}, "by_class": {}}
        # anything with .gauge(name, value) — normally an obs.Sampler; each
        # step() emits per-stage wall times and occupancy gauges so the
        # serving loop shows up in the same time series as the engines
        self.observer = observer

    # ------------------------------------------------------------------ API
    def enqueue(self, req: Request):
        """Admit to the waiting queue; on a multi-node fabric, unassigned
        requests get a home node round-robin so their copy bursts (and KV
        pages) stay NUMA-local to one node's engine group."""
        if req.home_node is None and self.topology.n_nodes > 1:
            req.home_node = self._node_rr % self.topology.n_nodes
            self._node_rr += 1
        self.queue.append(req)

    # ------------------------------------------------------------------ stage 1: poll + in-order commit
    def _stage_poll_commit(self, block: bool = False):
        """One completion-subsystem pass over the in-flight copy futures.

        ``timeout=0`` makes ``wait_any`` a single wait-policy poll (no busy
        loop) so decode still overlaps the copies; ``block=True`` — used
        when draining with nothing else to run — parks the host on the HEAD
        future (in-order commit can't advance past it) under the device's
        wait policy, freeing the cycles the paper's Fig. 11 measures."""
        futs = self.reorder.pending_futures()
        if futs:
            self.device.wait_any(futs[:1] if block else futs,
                                 timeout=None if block else 0)
        for _, payload in self.reorder.pop_completed():
            slot, req = payload
            self._admit_now(slot, req)

    def _admit_now(self, slot: int, req: Request):
        """Prompt pages have landed: prefill this slot's cache region.
        Runs under the request's trace context (reorder commit is part of
        the request lifecycle: any descriptor the prefill path submits
        shares the request's trace id)."""
        with self._trace_request(req):
            self._admit_now_inner(slot, req)

    def _admit_now_inner(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        cache1, logits, _ = self.model.prefill(self.params, {"tokens": prompt}, self.max_cache_len)
        # splice the single-sequence cache into the batch cache at `slot`
        self.cache = _splice_cache(self.cache, cache1, slot)
        tok = int(jnp.argmax(logits[0]))
        req.output.append(tok)
        req.first_token_at = time.perf_counter()
        req.first_token_s = self.now_s
        self._tokens = self._tokens.at[slot, 0].set(tok)
        self.active[slot] = req
        self.metrics["admitted"] += 1
        self._class_metrics(req.slo)["admitted"] += 1
        if req.home_node is not None:
            by_node = self.metrics["admitted_by_node"]
            by_node[req.home_node] = by_node.get(req.home_node, 0) + 1

    # ------------------------------------------------------------------ bookkeeping helpers
    def _class_metrics(self, slo: str) -> Dict[str, int]:
        m = self.metrics["by_class"].get(slo)
        if m is None:
            m = self.metrics["by_class"][slo] = {
                "admitted": 0, "completed": 0, "shed": 0}
        return m

    def _wq_for(self, req: Request):
        """The admission-copy WQ for a request's SLO class — the PR 2
        priority-WQ mapping; falls back to the legacy latency/default WQ
        when the class (or its WQ) is not provisioned on this device."""
        cls = self._slo_classes.get(req.slo)
        if cls is not None and cls.wq is not None and self.device.has_wq(cls.wq):
            return cls.wq
        return self._copy_wq

    def _pop_next_request(self) -> Request:
        """Admission order: highest SLO-class priority first, FIFO within a
        class — latency traffic jumps the bulk backlog, never the reverse."""
        if len(self.queue) == 1 or not self._slo_classes:
            return self.queue.popleft()
        best_i, best_p = 0, -1
        for i, req in enumerate(self.queue):
            cls = self._slo_classes.get(req.slo)
            p = cls.priority if cls is not None else 0
            if p > best_p:
                best_i, best_p = i, p
        req = self.queue[best_i]
        del self.queue[best_i]
        return req

    def _trace_request(self, req: Request):
        """Request-scoped trace context: every descriptor submitted inside
        (admission copies, KV paging, continuations) shares one trace id —
        ``req<id>`` — so the trace tooling can group a request's lifecycle
        across SLO admission, KV paging, and reorder commit.  A no-op
        context when the device has no tracer."""
        tracer = getattr(self.device, "tracer", None)
        if tracer is None:
            return contextlib.nullcontext()
        return tracer.request(f"req{req.req_id}")

    def _release_kv(self, req: Request):
        if self.kv_pool is not None and req.kv_pages:
            with self._trace_request(req):
                self.kv_pool.free(req.req_id)
            req.kv_pages = 0

    def _shed_now(self, req: Request):
        """Drop an already-dequeued request (backpressure shed): release
        its KV reservation and account the drop per class."""
        self._release_kv(req)
        self.metrics["shed"] += 1
        self.metrics["shed_backpressure"] += 1
        self._class_metrics(req.slo)["shed"] += 1

    def _reserve_kv(self, req: Request) -> bool:
        """Reserve the prompt's device pages before moving its bytes (the
        admission copy lands in KV); False = no capacity right now."""
        if self.kv_pool is None or req.kv_pages:
            return True
        n_pages = max(1, math.ceil(len(req.prompt) / self.kv_pool.page_tokens))
        node = (req.home_node if self.topology.n_nodes > 1 else None)
        with self._trace_request(req):
            ok = self.kv_pool.alloc(req.req_id, n_pages, node=node)
        if not ok:
            self.metrics["kv_alloc_failures"] += 1
            return False
        req.kv_pages = n_pages
        return True

    # ------------------------------------------------------------------ stage 2: submit batched copies
    def _stage_submit_copies(self):
        while self._free_slots and self.queue:
            req = self._pop_next_request()
            if not self._reserve_kv(req):
                # KV pressure is backpressure too: shed-first classes drop,
                # protected classes wait at the head for pages to free
                if (self.admission is not None
                        and req.slo in self.admission.classes
                        and self.admission.on_backpressure(req.slo)):
                    self._shed_now(req)
                    continue
                self.queue.appendleft(req)
                break
            slot = self._free_slots.pop()
            # burst the prompt over as a batch descriptor (packet copy analogue)
            chunks = np.array_split(req.prompt, max(1, len(req.prompt) // 64))
            descs = [
                WorkDescriptor(op=OpType.MEMCPY, src=jnp.asarray(np.ascontiguousarray(c)))
                for c in chunks[: self.burst]
            ]
            try:
                with self._trace_request(req):
                    fut = self.device.batch_async(descs, producer=f"slot{slot}",
                                                  wq=self._wq_for(req),
                                                  node=req.home_node)
            except QueueFull:
                # engine-side backpressure survived bounded backoff: give
                # the slot back, then either shed (shed-first classes) or
                # hold the request for the next step — never busy-loop
                self._free_slots.append(slot)
                self.metrics["backpressure_events"] += 1
                if (self.admission is not None
                        and req.slo in self.admission.classes
                        and self.admission.on_backpressure(req.slo)):
                    self._shed_now(req)
                    continue
                self.queue.appendleft(req)
                break
            self.reorder.push(self._tag, fut, (slot, req))
            self._tag += 1
            self.metrics["copy_bursts"] += 1

    # ------------------------------------------------------------------ stage 3: decode step
    def _stage_decode(self):
        if not self.active:
            return
        next_tokens, self.cache = self._decode(self.params, self.cache, self._tokens)
        self._tokens = next_tokens
        self.metrics["decoded_tokens"] += len(self.active)
        done_slots = []
        for slot, req in self.active.items():
            tok = int(next_tokens[slot, 0])
            req.output.append(tok)
            if len(req.output) >= req.max_new_tokens:
                req.done_at = time.perf_counter()
                req.done_s = self.now_s
                done_slots.append(slot)
        for slot in done_slots:
            req = self.active.pop(slot)
            self.metrics["completed"] += 1
            self._class_metrics(req.slo)["completed"] += 1
            self._release_kv(req)
            if self.tracker is not None and req.arrival_s is not None:
                self.tracker.record(req.slo, req.arrival_s,
                                    req.first_token_s, req.done_s)
            self._free_slots.append(slot)

    # ------------------------------------------------------------------ loop
    def step(self):
        # (1) completions -> in-order admit.  With decode work in flight OR
        # queued requests that stage 2 can still submit (a free slot
        # exists), the pass is non-blocking (timeout=0) so compute and new
        # copy bursts overlap the in-flight ones (G2); when neither stage
        # can make progress, park on the head copy under the device's wait
        # policy instead of spinning the loop.
        can_submit = bool(self.queue) and bool(self._free_slots)
        t0 = time.perf_counter()
        self._stage_poll_commit(block=not self.active and not can_submit
                                and len(self.reorder) > 0)
        t1 = time.perf_counter()
        self._stage_submit_copies() # (2) batch descriptors for new requests
        t2 = time.perf_counter()
        self._stage_decode()        # (3) compute overlapped with copies
        t3 = time.perf_counter()
        self.metrics["steps"] += 1
        if self.observer is not None:
            obs = self.observer
            obs.gauge("serving.queue_depth", len(self.queue))
            obs.gauge("serving.active_slots", len(self.active))
            obs.gauge("serving.slot_occupancy", len(self.active) / self.slots)
            obs.gauge("serving.inflight_copies", len(self.reorder))
            obs.gauge("serving.stage.poll_us", (t1 - t0) * 1e6)
            obs.gauge("serving.stage.submit_us", (t2 - t1) * 1e6)
            obs.gauge("serving.stage.decode_us", (t3 - t2) * 1e6)
            # per-SLO-class gauges: queue depth now, admitted/shed to date —
            # the overload experiments read these next to the engine series
            queued = Counter(r.slo for r in self.queue)
            for name in self._slo_classes:
                cm = self._class_metrics(name)
                obs.gauge(f"serving.class.{name}.queue_depth",
                          queued.get(name, 0))
                obs.gauge(f"serving.class.{name}.admitted", cm["admitted"])
                obs.gauge(f"serving.class.{name}.shed", cm["shed"])

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self.active or len(self.reorder)) and steps < max_steps:
            self.step()
            steps += 1
        self.device.drain()
        return steps

    # ------------------------------------------------------------------ open loop
    def run_open_loop(self, traffic, horizon_s: float, *,
                      step_s: float = 0.01, vocab_size: int = 256,
                      drain: bool = True, max_steps: int = 1_000_000) -> dict:
        """Drive the server open-loop from a ``TrafficGenerator`` for
        ``horizon_s`` VIRTUAL seconds: arrivals land at their trace times
        whether or not the server keeps up (the paper's §6 sustained-load
        regime).  Each ``step()`` advances the virtual clock by ``step_s``.

        Admission runs through ``self.admission`` when set (watermarks,
        occupancy probes, backpressure sheds); latencies land in
        ``self.tracker`` when set.  ``drain=True`` keeps stepping past the
        horizon until all admitted work completes, so the accounting
        identity  generated == admitted + shed + in-flight  closes with
        in-flight == 0 — the overload soak test's conservation law.

        Returns a report: offered/sustained RPS, per-class latency summary,
        shed/admission counters, and the in-flight remainder."""
        if step_s <= 0:
            raise ValueError(f"step_s must be > 0, got {step_s}")
        events = traffic.trace(horizon_s)
        i = 0
        t = 0.0
        steps = 0
        generated = admitted = shed = 0
        # cumulative-counter baselines, so a server reused across runs
        # reports THIS run's deltas
        completed_0 = self.metrics["completed"]
        shed_0 = self.metrics["shed"]
        bp_shed_0 = self.metrics["shed_backpressure"]
        queued_by_class: Counter = Counter()
        while True:
            self.now_s = t
            while i < len(events) and events[i].arrival_s <= t:
                ev = events[i]
                i += 1
                generated += 1
                if self.admission is not None and ev.slo in self.admission.classes:
                    ok = self.admission.admit(ev.slo, queued_by_class[ev.slo])
                else:
                    ok = True
                if ok:
                    req = ev.materialize(vocab_size)
                    self.enqueue(req)
                    queued_by_class[ev.slo] += 1
                    admitted += 1
                else:
                    self.metrics["shed"] += 1
                    self._class_metrics(ev.slo)["shed"] += 1
                    shed += 1
            had_queued = len(self.queue)
            work = bool(self.queue or self.active or len(self.reorder))
            if i >= len(events) and not work:
                break
            if not drain and t >= horizon_s:
                break
            if steps >= max_steps:
                break
            self.step()
            steps += 1
            # dequeues (admits + backpressure sheds) shrink the per-class
            # waiting counts the admission watermark reads
            if len(self.queue) != had_queued:
                queued_by_class = Counter(r.slo for r in self.queue)
            t += step_s
        in_flight = len(self.queue) + len(self.reorder) + len(self.active)
        completed = self.metrics["completed"] - completed_0
        bp_shed = self.metrics["shed_backpressure"] - bp_shed_0
        report = {
            "horizon_s": horizon_s,
            "virtual_s": t,
            "steps": steps,
            "generated": generated,
            # enqueued minus later backpressure sheds: what the server
            # actually took responsibility for (== completed + in_flight)
            "admitted": admitted - bp_shed,
            "shed": self.metrics["shed"] - shed_0,
            "shed_backpressure": bp_shed,
            "completed": completed,
            "in_flight": in_flight,
            "offered_rps": traffic.offered_rps(),
            "sustained_rps": completed / max(t, step_s),
            "by_class": {k: dict(v) for k, v in self.metrics["by_class"].items()},
        }
        if self.tracker is not None:
            report["latency"] = self.tracker.summary()
            goodput = sum(self.tracker.within_slo(c)
                          for c in self.tracker.classes)
            report["goodput_rps"] = goodput / max(t, step_s)
        return report


def _splice_cache(batch_cache, one_cache, slot: int):
    """Write a batch-1 cache into row `slot` of the batch cache.

    lengths is [B]; other leaves have batch as the SECOND dim under layer
    stacking for scanned segments ([L, B, ...]) or the first dim for
    unrolled per-layer caches."""

    def splice(dst, src):
        if dst is None:
            return None
        if dst.ndim >= 2 and src.ndim == dst.ndim and src.shape[0] == dst.shape[0]:
            # stacked [L, B, ...]
            return dst.at[:, slot].set(src[:, 0])
        if src.ndim == dst.ndim:
            return dst.at[slot].set(src[0])
        return dst

    import jax

    dst_segs = batch_cache["segments"]
    src_segs = one_cache["segments"]
    new_segs = []
    for d, s in zip(dst_segs, src_segs):
        new_segs.append(jax.tree.map(splice, d, s))
    lengths = batch_cache["lengths"].at[slot].set(one_cache["lengths"][0])
    return {"segments": new_segs, "lengths": lengths}
