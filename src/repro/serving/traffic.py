"""Open-loop traffic engine: arrival processes and workload synthesis.

The paper's DPDK Vhost case study (§6) measures the serving datapath under
*sustained packet arrival* — an open loop where requests keep coming whether
or not the server is keeping up — not a pre-built request list replayed in
closed loop.  This module is that traffic source for the Vhost-style
serving pipeline:

  ArrivalProcess   a seeded, deterministic generator of absolute arrival
                   times on a VIRTUAL clock.  Re-iterating a process (or
                   re-seeding an identical one) draws the identical trace,
                   which is what makes the statistical test harness and the
                   overload soak tests reproducible.

    PoissonArrivals   memoryless arrivals at a constant rate (CV^2 = 1),
                      the baseline every queueing result assumes.
    BurstyArrivals    MMPP-style on-off modulation: dwell times are
                      exponential, arrivals within a state are Poisson at
                      that state's rate.  CV^2 > 1 — the bursty traffic
                      that actually breaks naive admission.
    DiurnalArrivals   sinusoidal rate ramp (trough -> peak -> trough per
                      period) via Lewis-Shedler thinning, the
                      millions-of-users daily cycle compressed onto the
                      virtual clock.

  ZipfLengths      bounded Zipf-distributed request lengths (rank-based:
                   short requests common, long-tail heavy), used for both
                   context and output lengths.

  TrafficGenerator arrival process x length distributions x SLO-class mix
                   -> a deterministic trace of OpenRequest records, each
                   carrying its arrival time, SLO class, and lengths.

Statistical helpers (``interarrival_stats``, ``zipf_tail_slope``) are the
assertion vocabulary of tests/test_traffic.py; benchmarks reuse them so the
generator's properties are checked in the same terms they were specified.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


# --------------------------------------------------------------------------- arrival processes
class ArrivalProcess:
    """Seeded generator of absolute arrival times (virtual seconds).

    ``times(horizon_s)`` yields strictly increasing floats in
    ``[0, horizon_s)``.  Every call re-seeds an identical stream: same
    process + same seed => identical trace, independent of how many other
    processes drew randomness in between (each process owns its rng)."""

    name = "base"

    def __init__(self, seed: int = 0):
        self.seed = int(seed)

    def _rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)

    def times(self, horizon_s: float) -> Iterator[float]:
        raise NotImplementedError

    def rate_at(self, t: float) -> Optional[float]:
        """Instantaneous offered rate (requests/s) at virtual time ``t``,
        when the process defines one (diurnal does; stationary processes
        return their mean rate)."""
        return None

    def mean_rate(self) -> float:
        """Long-run offered rate in requests/s."""
        raise NotImplementedError


class PoissonArrivals(ArrivalProcess):
    """Constant-rate Poisson process: i.i.d. exponential inter-arrivals."""

    name = "poisson"

    def __init__(self, rate_rps: float, seed: int = 0):
        super().__init__(seed)
        if rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
        self.rate_rps = float(rate_rps)

    def times(self, horizon_s: float) -> Iterator[float]:
        rng = self._rng()
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.rate_rps)
            if t >= horizon_s:
                return
            yield t

    def rate_at(self, t: float) -> float:
        return self.rate_rps

    def mean_rate(self) -> float:
        return self.rate_rps


class BurstyArrivals(ArrivalProcess):
    """Two-state MMPP (on-off modulated Poisson): exponential dwell times,
    Poisson arrivals at ``on_rps`` inside a burst and ``off_rps`` between
    bursts.  Memorylessness makes the event-driven simulation exact: a gap
    drawn at the current state's rate that would cross the state boundary
    is discarded and redrawn from the boundary.

    The squared coefficient of variation of inter-arrivals exceeds 1
    whenever ``on_rps != off_rps`` — the burstiness the property tests pin.
    """

    name = "bursty"

    def __init__(self, on_rps: float, off_rps: float = 0.0,
                 mean_on_s: float = 1.0, mean_off_s: float = 1.0,
                 seed: int = 0):
        super().__init__(seed)
        if on_rps <= 0:
            raise ValueError(f"on_rps must be > 0, got {on_rps}")
        if off_rps < 0:
            raise ValueError(f"off_rps must be >= 0, got {off_rps}")
        if mean_on_s <= 0 or mean_off_s <= 0:
            raise ValueError("mean_on_s and mean_off_s must be > 0")
        self.on_rps = float(on_rps)
        self.off_rps = float(off_rps)
        self.mean_on_s = float(mean_on_s)
        self.mean_off_s = float(mean_off_s)

    def times(self, horizon_s: float) -> Iterator[float]:
        rng = self._rng()
        t = 0.0
        on = True  # start inside a burst so short horizons still see traffic
        state_end = rng.exponential(self.mean_on_s)
        while t < horizon_s:
            rate = self.on_rps if on else self.off_rps
            if rate <= 0:
                # silent state: jump straight to the next burst
                t = state_end
                on = not on
                state_end = t + rng.exponential(
                    self.mean_on_s if on else self.mean_off_s)
                continue
            gap = rng.exponential(1.0 / rate)
            if t + gap >= state_end:
                # arrival would land past the state switch: restart the
                # (memoryless) draw from the boundary in the next state
                t = state_end
                on = not on
                state_end = t + rng.exponential(
                    self.mean_on_s if on else self.mean_off_s)
                continue
            t += gap
            if t >= horizon_s:
                return
            yield t

    def mean_rate(self) -> float:
        w_on = self.mean_on_s / (self.mean_on_s + self.mean_off_s)
        return self.on_rps * w_on + self.off_rps * (1.0 - w_on)

    def rate_at(self, t: float) -> float:
        return self.mean_rate()  # stationary mean; per-state rate is random


class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal rate ramp between ``trough_rps`` and ``peak_rps`` with the
    given period, sampled by Lewis-Shedler thinning of a ``peak_rps``
    homogeneous process.  ``rate_at(t)`` is the exact intensity, so tests
    can check that windowed arrival counts track the ramp."""

    name = "diurnal"

    def __init__(self, peak_rps: float, trough_rps: float,
                 period_s: float, seed: int = 0, phase: float = 0.0):
        super().__init__(seed)
        if peak_rps <= 0 or not 0 <= trough_rps <= peak_rps:
            raise ValueError(
                f"need 0 <= trough_rps <= peak_rps and peak_rps > 0; "
                f"got trough={trough_rps} peak={peak_rps}")
        if period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {period_s}")
        self.peak_rps = float(peak_rps)
        self.trough_rps = float(trough_rps)
        self.period_s = float(period_s)
        self.phase = float(phase)

    def rate_at(self, t: float) -> float:
        # trough at t=0 (+phase), peak at half period
        x = 2.0 * math.pi * (t / self.period_s) + self.phase
        return self.trough_rps + (self.peak_rps - self.trough_rps) * 0.5 * (
            1.0 - math.cos(x))

    def times(self, horizon_s: float) -> Iterator[float]:
        rng = self._rng()
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.peak_rps)
            if t >= horizon_s:
                return
            if rng.uniform() * self.peak_rps < self.rate_at(t):
                yield t

    def mean_rate(self) -> float:
        return 0.5 * (self.peak_rps + self.trough_rps)


# --------------------------------------------------------------------------- length distribution
class ZipfLengths:
    """Bounded Zipf over the integer lengths ``[lo, hi]``: rank 1 (= ``lo``)
    is the most likely, and P(rank k) ~ k**-s.  Real request logs are
    heavy-tailed in exactly this way (short prompts dominate, the tail
    carries the bytes), and the bound keeps the KV budget finite.

    The pmf is materialized once, so sampling is one ``rng.choice`` and the
    tail slope is available in closed form for the property tests."""

    def __init__(self, s: float = 1.1, lo: int = 1, hi: int = 1024):
        if not lo >= 1:
            raise ValueError(f"lo must be >= 1, got {lo}")
        if not hi >= lo:
            raise ValueError(f"need hi >= lo, got [{lo}, {hi}]")
        if s <= 0:
            raise ValueError(f"s must be > 0, got {s}")
        self.s = float(s)
        self.lo = int(lo)
        self.hi = int(hi)
        ranks = np.arange(1, self.hi - self.lo + 2, dtype=np.float64)
        w = ranks ** -self.s
        self._pmf = w / w.sum()
        self._values = np.arange(self.lo, self.hi + 1, dtype=np.int64)

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return rng.choice(self._values, size=n, p=self._pmf)

    def pmf(self) -> np.ndarray:
        return self._pmf.copy()

    def mean(self) -> float:
        return float((self._values * self._pmf).sum())


# --------------------------------------------------------------------------- generated trace
@dataclasses.dataclass(frozen=True)
class OpenRequest:
    """One generated arrival, before materialization into a serving Request:
    when it lands, what SLO class it belongs to, and how big it is."""

    req_id: int
    arrival_s: float
    slo: str
    prompt_len: int
    max_new_tokens: int

    def materialize(self, vocab_size: int = 256):
        """Build the serving-pipeline Request for this arrival.  The prompt
        is keyed by req_id, so the same trace always materializes the same
        token streams."""
        from repro.serving.pipeline import Request

        rng = np.random.default_rng(0xC0FFEE ^ self.req_id)
        return Request(
            req_id=self.req_id,
            prompt=rng.integers(0, vocab_size, self.prompt_len).astype(np.int32),
            max_new_tokens=self.max_new_tokens,
            slo=self.slo,
            arrival_s=self.arrival_s,
        )


class TrafficGenerator:
    """Arrival process x Zipf lengths x SLO-class mix -> deterministic trace.

    Independent child seeds (``np.random.SeedSequence.spawn``) drive the
    class and length draws, so the arrival process, the class mix, and the
    length marginals each see their own stream: changing one knob never
    perturbs the others' draws — the property the same-seed tests pin.
    """

    def __init__(self, arrivals: ArrivalProcess, *,
                 prompt_lengths: Optional[ZipfLengths] = None,
                 output_lengths: Optional[ZipfLengths] = None,
                 class_mix: Optional[Dict[str, float]] = None,
                 seed: int = 0):
        self.arrivals = arrivals
        self.prompt_lengths = prompt_lengths or ZipfLengths(s=1.1, lo=8, hi=256)
        self.output_lengths = output_lengths or ZipfLengths(s=1.2, lo=2, hi=64)
        mix = class_mix or {"latency": 0.25, "bulk": 0.75}
        total = sum(mix.values())
        if total <= 0 or any(v < 0 for v in mix.values()):
            raise ValueError(f"class_mix must be non-negative with a positive "
                             f"sum, got {mix}")
        self.class_names = sorted(mix)
        self.class_probs = np.asarray(
            [mix[c] / total for c in self.class_names])
        self.seed = int(seed)

    def trace(self, horizon_s: float) -> List[OpenRequest]:
        """The full deterministic arrival trace over ``[0, horizon_s)``."""
        times = list(self.arrivals.times(horizon_s))
        n = len(times)
        cls_seed, plen_seed, olen_seed = np.random.SeedSequence(
            self.seed).spawn(3)
        classes = np.random.default_rng(cls_seed).choice(
            len(self.class_names), size=n, p=self.class_probs)
        plens = self.prompt_lengths.sample(n, np.random.default_rng(plen_seed))
        olens = self.output_lengths.sample(n, np.random.default_rng(olen_seed))
        return [
            OpenRequest(req_id=i, arrival_s=float(times[i]),
                        slo=self.class_names[int(classes[i])],
                        prompt_len=int(plens[i]),
                        max_new_tokens=int(olens[i]))
            for i in range(n)
        ]

    def offered_rps(self) -> float:
        return self.arrivals.mean_rate()


# --------------------------------------------------------------------------- statistics
def interarrival_stats(times: Sequence[float]) -> Tuple[float, float]:
    """(mean gap, CV^2 of gaps) for an arrival-time sequence.  CV^2 = 1 for
    Poisson, > 1 for bursty, < 1 for regular traffic."""
    gaps = np.diff(np.asarray(times, dtype=np.float64))
    if len(gaps) < 2:
        raise ValueError(f"need >= 3 arrivals for gap stats, got {len(times)}")
    mean = float(gaps.mean())
    var = float(gaps.var())
    return mean, var / (mean * mean) if mean > 0 else float("inf")


def windowed_rates(times: Sequence[float], horizon_s: float,
                   window_s: float) -> Tuple[np.ndarray, np.ndarray]:
    """(window centers, empirical rate per window) — the diurnal-tracking
    assertion's view of a trace."""
    edges = np.arange(0.0, horizon_s + window_s, window_s)
    counts, _ = np.histogram(np.asarray(times), bins=edges)
    centers = 0.5 * (edges[:-1] + edges[1:])
    return centers, counts / window_s


def zipf_tail_slope(samples: Sequence[int], lo: int = 1) -> float:
    """Least-squares slope of log(frequency) vs log(rank) over the sampled
    lengths (ranked by value: ``lo`` is rank 1).  For a Zipf(s) source the
    slope converges to ``-s``; the property test asserts the fitted slope
    is within tolerance of the configured exponent.  Only ranks observed
    at least 5 times enter the fit — the extreme tail is shot noise."""
    vals, counts = np.unique(np.asarray(samples, dtype=np.int64),
                             return_counts=True)
    ranks = vals - lo + 1
    keep = (counts >= 5) & (ranks >= 1)
    if keep.sum() < 3:
        raise ValueError("too few well-populated ranks for a slope fit")
    x = np.log(ranks[keep].astype(np.float64))
    y = np.log(counts[keep].astype(np.float64))
    slope, _ = np.polyfit(x, y, 1)
    return float(slope)
