"""Paged KV storage: a NUMA-sharded device page pool over a host spill tier.

The DSA mapping (DESIGN.md §2): pages are the transfer granule; swapping a
sequence's pages between tiers is a BATCH DESCRIPTOR of page copies executed
as one ``batch_copy`` kernel launch (paper F2), and tier choice follows G4
(the faster-write tier holds the hot working set).  The topology layer
(core/topology.py) adds the paper's §4 axis: the device pool is SHARDED
across NUMA nodes — every page-table entry carries its home node, each pool
slab is registered with the device's buffer-locality registry (so swap
descriptors derive src/dst nodes and the ``numa_local`` policy can keep the
engine next to the data), and a multi-node swap batches per node: one batch
descriptor per (node, direction) pair, never one descriptor mixing nodes.

Pages are [page_tokens, kv_dim] slabs; a sequence owns an ordered page list
in the page table.  This is the functional state layer under the
Vhost-style serving pipeline.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.topology import Topology
from repro.kernels import ops as kops


@dataclasses.dataclass
class PoolStats:
    device_pages_used: int = 0
    host_pages_used: int = 0
    swaps_in: int = 0
    swaps_out: int = 0
    batch_copies: int = 0
    pages_moved: int = 0
    cross_node_swaps: int = 0  # swaps whose src and dst homes differ
    copy_fallbacks: int = 0  # engine path failed -> sync kops.batch_copy


class PagedKVPool:
    """NUMA-sharded two-tier page pool.  The per-node 'device' pools and the
    'host' pool are all jax arrays here (CPU backend); on TPU the host pool
    lives in pinned host memory and batch_copy rides the DMA engine.

    ``n_device_pages`` is the FABRIC total, split as evenly as possible
    across the topology's nodes (earlier nodes take the remainder).  The
    default single-node topology reproduces the old flat pool exactly.
    """

    def __init__(self, n_device_pages: int, n_host_pages: int, page_tokens: int,
                 kv_dim: int, dtype=jnp.bfloat16, device=None,
                 topology: Optional[Topology] = None, host_node: int = 0):
        self.page_tokens = page_tokens
        self.kv_dim = kv_dim
        self.device = device  # optional Device: swaps become engine descriptors
        self.topology = (topology
                         or (device.topology if device is not None else None)
                         or Topology.single_node())
        n_nodes = self.topology.n_nodes
        if not 0 <= host_node < n_nodes:
            raise ValueError(f"host_node {host_node} out of range for "
                             f"{n_nodes}-node topology")
        self.host_node = host_node
        base, extra = divmod(n_device_pages, n_nodes)
        self._node_pages = [base + (1 if n < extra else 0) for n in range(n_nodes)]
        self.device_pools: List[jax.Array] = [
            jnp.zeros((p, page_tokens, kv_dim), dtype) for p in self._node_pages
        ]
        self.host_pool = jnp.zeros((n_host_pages, page_tokens, kv_dim), dtype)
        self._free_device: List[List[int]] = [
            list(range(p))[::-1] for p in self._node_pages
        ]
        self._free_host = list(range(n_host_pages))[::-1]
        # seq_id -> list of (tier, node, page_idx) in order
        self.page_table: Dict[int, List[Tuple[str, int, int]]] = {}
        self.stats = PoolStats()
        if self.device is not None:
            for n, pool in enumerate(self.device_pools):
                self.device.register(pool, n)
            self.device.register(self.host_pool, self.host_node)

    # ------------------------------------------------------------------ pool state
    @property
    def device_pool(self) -> jax.Array:
        """Single-node compatibility view (the old flat-pool attribute)."""
        if self.topology.n_nodes != 1:
            raise AttributeError(
                "device_pool is ambiguous on a multi-node pool; "
                "use device_pools[node]"
            )
        return self.device_pools[0]

    def _set_device_pool(self, node: int, pool: jax.Array):
        """Replace one node's slab, keeping the locality registry current
        (functional updates mint new arrays every time)."""
        self.device_pools[node] = pool
        if self.device is not None:
            self.device.register(pool, node)

    def _set_host_pool(self, pool: jax.Array):
        self.host_pool = pool
        if self.device is not None:
            self.device.register(pool, self.host_node)

    def free_device_pages(self, node: Optional[int] = None) -> int:
        if node is not None:
            return len(self._free_device[self._check_node(node)])
        return sum(len(f) for f in self._free_device)

    def _check_node(self, node: int) -> int:
        """Range-check a caller-supplied node id BEFORE any free-list pops:
        a bad pin must fail cleanly, not alias via negative indexing or
        blow up mid-commit after state has already moved."""
        if not 0 <= node < self.topology.n_nodes:
            raise ValueError(f"node {node} out of range for "
                             f"{self.topology.n_nodes}-node pool")
        return node

    # ------------------------------------------------------------------ alloc
    def alloc(self, seq_id: int, n_pages: int, tier: str = "device",
              node: Optional[int] = None) -> bool:
        """Reserve pages.  Device pages come from ``node`` when pinned, else
        greedily from the freest nodes (locality beats striping: a sequence
        lands on as few nodes as possible)."""
        if tier == "host":
            if len(self._free_host) < n_pages:
                return False
            pages = [self._free_host.pop() for _ in range(n_pages)]
            self.page_table.setdefault(seq_id, []).extend(
                ("host", self.host_node, p) for p in pages)
            self._count()
            return True
        candidates = ([self._check_node(node)] if node is not None
                      else sorted(range(self.topology.n_nodes),
                                  key=lambda n: -len(self._free_device[n])))
        if sum(len(self._free_device[n]) for n in candidates) < n_pages:
            return False
        entries: List[Tuple[str, int, int]] = []
        remaining = n_pages
        for n in candidates:
            take = min(remaining, len(self._free_device[n]))
            entries.extend(("device", n, self._free_device[n].pop())
                           for _ in range(take))
            remaining -= take
            if not remaining:
                break
        self.page_table.setdefault(seq_id, []).extend(entries)
        self._count()
        return True

    def free(self, seq_id: int):
        for tier, node, p in self.page_table.pop(seq_id, []):
            if tier == "device":
                self._free_device[node].append(p)
            else:
                self._free_host.append(p)
        self._count()

    def _count(self):
        self.stats.device_pages_used = (
            sum(self._node_pages) - sum(len(f) for f in self._free_device)
        )
        self.stats.host_pages_used = self.host_pool.shape[0] - len(self._free_host)

    # ------------------------------------------------------------------ page IO
    def write_page(self, seq_id: int, page_no: int, data: jax.Array):
        tier, node, idx = self.page_table[seq_id][page_no]
        if tier == "device":
            pool = self.device_pools[node]
            self._set_device_pool(node, pool.at[idx].set(data.astype(pool.dtype)))
        else:
            self._set_host_pool(
                self.host_pool.at[idx].set(data.astype(self.host_pool.dtype)))

    def read_pages(self, seq_id: int) -> jax.Array:
        out = []
        for tier, node, idx in self.page_table[seq_id]:
            pool = self.device_pools[node] if tier == "device" else self.host_pool
            out.append(pool[idx])
        return jnp.concatenate(out, axis=0)

    # ------------------------------------------------------------------ tier moves (batch descriptors)
    def _batch_copy(self, src_pool, dst_pool, src_idx, dst_idx, dst_node=None):
        """One per-node batch descriptor through the engine, falling back to
        the synchronous kernel when the offload path fails (QueueFull after
        backoff, engine error): a saturated fabric degrades to a slow swap,
        never a lost one.  Registered pools let the descriptor derive its
        src/dst nodes; ``dst_node`` homes the INTERMEDIATE pools a chained
        multi-node swap mints (functional updates return fresh, unregistered
        arrays), so every per-node batch keeps its cross-node link charge."""
        if self.device is not None:
            try:
                return self.device.batch_copy_async(
                    src_pool, dst_pool, src_idx, dst_idx, producer="kv-pool",
                    node=dst_node,
                ).result()
            except Exception:  # noqa: BLE001  # dsalint: disable=DSA104 — counted fallback to the sync copy path
                self.stats.copy_fallbacks += 1
        return kops.batch_copy(src_pool, dst_pool, src_idx, dst_idx)

    def swap_out(self, seq_id: int) -> bool:
        """Device -> host: one batch descriptor PER SOURCE NODE.  Free-list
        pops are restored if any copy fails, so a raising batch copy leaks
        no pages (the pools and page table only commit after every copy
        succeeded)."""
        entries = self.page_table.get(seq_id, [])
        by_node: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
        for slot, (tier, node, p) in enumerate(entries):
            if tier == "device":
                by_node[node].append((slot, p))
        total = sum(len(g) for g in by_node.values())
        if not total:
            return True
        if len(self._free_host) < total:
            return False
        host_pages = [self._free_host.pop() for _ in range(total)]
        plan: List[Tuple[int, List[Tuple[int, int]], List[int]]] = []
        cursor = 0
        for node in sorted(by_node):
            group = by_node[node]
            plan.append((node, group, host_pages[cursor:cursor + len(group)]))
            cursor += len(group)
        try:
            new_host = self.host_pool
            for node, group, dst in plan:
                src_idx = jnp.asarray([p for _, p in group], jnp.int32)
                dst_idx = jnp.asarray(dst, jnp.int32)
                new_host = self._batch_copy(self.device_pools[node], new_host,
                                            src_idx, dst_idx,
                                            dst_node=self.host_node)
        except Exception:
            # restore the pops in reverse so the free list is byte-identical
            self._free_host.extend(reversed(host_pages))
            raise
        self._set_host_pool(new_host)
        for node, group, dst in plan:
            for (slot, p), hp in zip(group, dst):
                entries[slot] = ("host", self.host_node, hp)
                self._free_device[node].append(p)
        self.stats.swaps_out += 1
        self.stats.batch_copies += len(plan)
        self.stats.cross_node_swaps += sum(
            1 for n, _, _ in plan if n != self.host_node)
        self.stats.pages_moved += total
        self._count()
        return True

    def swap_in(self, seq_id: int, node: Optional[int] = None) -> bool:
        """Host -> device: one batch descriptor PER DESTINATION NODE, for
        scheduling a sequence.  ``node`` pins the landing node; otherwise
        pages land greedily on the freest nodes.  Same no-leak contract as
        ``swap_out``: pops restore on failure, state commits on success."""
        entries = self.page_table.get(seq_id, [])
        host = [(slot, p) for slot, (t, _n, p) in enumerate(entries) if t == "host"]
        if not host:
            return True
        candidates = ([self._check_node(node)] if node is not None
                      else sorted(range(self.topology.n_nodes),
                                  key=lambda n: -len(self._free_device[n])))
        if sum(len(self._free_device[n]) for n in candidates) < len(host):
            return False
        popped: Dict[int, List[int]] = defaultdict(list)
        plan: List[Tuple[int, List[Tuple[int, int]], List[int]]] = []
        cursor = 0
        for n in candidates:
            take = min(len(host) - cursor, len(self._free_device[n]))
            if not take:
                continue
            dst = [self._free_device[n].pop() for _ in range(take)]
            popped[n] = dst
            plan.append((n, host[cursor:cursor + take], dst))
            cursor += take
            if cursor == len(host):
                break
        try:
            new_pools: Dict[int, jax.Array] = {}
            for n, group, dst in plan:
                src_idx = jnp.asarray([p for _, p in group], jnp.int32)
                dst_idx = jnp.asarray(dst, jnp.int32)
                new_pools[n] = self._batch_copy(
                    self.host_pool, new_pools.get(n, self.device_pools[n]),
                    src_idx, dst_idx, dst_node=n)
        except Exception:
            for n, dst in popped.items():
                self._free_device[n].extend(reversed(dst))
            raise
        for n, pool in new_pools.items():
            self._set_device_pool(n, pool)
        for n, group, dst in plan:
            for (slot, p), dp in zip(group, dst):
                entries[slot] = ("device", n, dp)
                self._free_host.append(p)
        self.stats.swaps_in += 1
        self.stats.batch_copies += len(plan)
        self.stats.cross_node_swaps += sum(
            1 for n, _, _ in plan if n != self.host_node)
        self.stats.pages_moved += len(host)
        self._count()
        return True
