"""Paged KV storage with a two-tier (device HBM / host DRAM) page pool.

The DSA mapping (DESIGN.md §2): pages are the transfer granule; swapping a
sequence's pages between tiers is a BATCH DESCRIPTOR of page copies executed
as one ``batch_copy`` kernel launch (paper F2), and tier choice follows G4
(the faster-write tier holds the hot working set).

Pages are [page_tokens, kv_dim] slabs; a sequence owns an ordered page list
in the page table.  This is the functional state layer under the
Vhost-style serving pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


@dataclasses.dataclass
class PoolStats:
    device_pages_used: int = 0
    host_pages_used: int = 0
    swaps_in: int = 0
    swaps_out: int = 0
    batch_copies: int = 0
    pages_moved: int = 0


class PagedKVPool:
    """Two-tier page pool.  The 'device' and 'host' pools are both jax arrays
    here (CPU backend); on TPU the host pool lives in pinned host memory and
    batch_copy rides the DMA engine."""

    def __init__(self, n_device_pages: int, n_host_pages: int, page_tokens: int,
                 kv_dim: int, dtype=jnp.bfloat16, device=None):
        self.page_tokens = page_tokens
        self.kv_dim = kv_dim
        self.device_pool = jnp.zeros((n_device_pages, page_tokens, kv_dim), dtype)
        self.host_pool = jnp.zeros((n_host_pages, page_tokens, kv_dim), dtype)
        self._free_device = list(range(n_device_pages))[::-1]
        self._free_host = list(range(n_host_pages))[::-1]
        # seq_id -> list of (tier, page_idx) in order
        self.page_table: Dict[int, List[Tuple[str, int]]] = {}
        self.device = device  # optional Device: swaps become engine descriptors
        self.stats = PoolStats()

    # ------------------------------------------------------------------ alloc
    def alloc(self, seq_id: int, n_pages: int, tier: str = "device") -> bool:
        free = self._free_device if tier == "device" else self._free_host
        if len(free) < n_pages:
            return False
        pages = [free.pop() for _ in range(n_pages)]
        self.page_table.setdefault(seq_id, []).extend((tier, p) for p in pages)
        self._count()
        return True

    def free(self, seq_id: int):
        for tier, p in self.page_table.pop(seq_id, []):
            (self._free_device if tier == "device" else self._free_host).append(p)
        self._count()

    def _count(self):
        self.stats.device_pages_used = self.device_pool.shape[0] - len(self._free_device)
        self.stats.host_pages_used = self.host_pool.shape[0] - len(self._free_host)

    # ------------------------------------------------------------------ page IO
    def write_page(self, seq_id: int, page_no: int, data: jax.Array):
        tier, idx = self.page_table[seq_id][page_no]
        pool = self.device_pool if tier == "device" else self.host_pool
        pool = pool.at[idx].set(data.astype(pool.dtype))
        if tier == "device":
            self.device_pool = pool
        else:
            self.host_pool = pool

    def read_pages(self, seq_id: int) -> jax.Array:
        out = []
        for tier, idx in self.page_table[seq_id]:
            pool = self.device_pool if tier == "device" else self.host_pool
            out.append(pool[idx])
        return jnp.concatenate(out, axis=0)

    # ------------------------------------------------------------------ tier moves (batch descriptors)
    def _batch_copy(self, src_pool, dst_pool, src_idx, dst_idx):
        if self.device is not None:
            return self.device.batch_copy_async(
                src_pool, dst_pool, src_idx, dst_idx, producer="kv-pool"
            ).result()
        return kops.batch_copy(src_pool, dst_pool, src_idx, dst_idx)

    def swap_out(self, seq_id: int) -> bool:
        """Device -> host, all pages of a sequence in ONE batch descriptor."""
        entries = self.page_table.get(seq_id, [])
        dev = [(i, p) for i, (t, p) in enumerate(entries) if t == "device"]
        if not dev:
            return True
        if len(self._free_host) < len(dev):
            return False
        host_pages = [self._free_host.pop() for _ in dev]
        src_idx = jnp.asarray([p for _, p in dev], jnp.int32)
        dst_idx = jnp.asarray(host_pages, jnp.int32)
        self.host_pool = self._batch_copy(self.device_pool, self.host_pool, src_idx, dst_idx)
        for (slot, p), hp in zip(dev, host_pages):
            entries[slot] = ("host", hp)
            self._free_device.append(p)
        self.stats.swaps_out += 1
        self.stats.batch_copies += 1
        self.stats.pages_moved += len(dev)
        self._count()
        return True

    def swap_in(self, seq_id: int) -> bool:
        """Host -> device (one batch descriptor), for scheduling a sequence."""
        entries = self.page_table.get(seq_id, [])
        host = [(i, p) for i, (t, p) in enumerate(entries) if t == "host"]
        if not host:
            return True
        if len(self._free_device) < len(host):
            return False
        dev_pages = [self._free_device.pop() for _ in host]
        src_idx = jnp.asarray([p for _, p in host], jnp.int32)
        dst_idx = jnp.asarray(dev_pages, jnp.int32)
        self.device_pool = self._batch_copy(self.host_pool, self.device_pool, src_idx, dst_idx)
        for (slot, p), dp in zip(host, dev_pages):
            entries[slot] = ("device", dp)
            self._free_host.append(p)
        self.stats.swaps_in += 1
        self.stats.batch_copies += 1
        self.stats.pages_moved += len(host)
        self._count()
        return True
