"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits a ``while`` body ONCE — for a
scanned 95-layer model it reports ~1/95th of the real FLOPs.  This module
re-walks the optimized HLO with trip-count multiplication:

* parses every computation into (op, shape, operands, metadata) records,
* computes MXU FLOPs for ``dot``/``convolution`` ops (2 * numel(out) *
  contracted size),
* models HBM traffic at fusion boundaries (operands + result bytes of every
  top-level op; ops inside a fusion are free),
* accumulates ring-model collective bytes (same formulas as analysis.py),
* multiplies all three through ``while`` loops using the trip count
  recovered from the loop-condition comparison constant (lax.scan emits
  ``compare(induction_var, constant N)``),
* fusions/calls/conditionals multiply by 1 (conditional branches summed —
  a conservative upper bound).

Validated against unrolled references in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_NAME_EQ_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_SIMPLE_TYPE_RE = re.compile(r"^[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_op_line(line: str):
    """Returns (name, type_str, opcode, operand_str) or None."""
    m = _NAME_EQ_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rest[: i + 1], rest[i + 1 :]
    else:
        tm = _SIMPLE_TYPE_RE.match(rest)
        if not tm:
            return None
        type_str, rest = tm.group(0), rest[tm.end():]
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    rest = rest[om.end():]
    depth = 1
    i = 0
    while i < len(rest) and depth > 0:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    operand_str = rest[: i - 1] if depth == 0 else rest
    return name, type_str, opcode, operand_str
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|branch_computations=\{)[^,}]*")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}|true_computation=%?([\w\.\-]+)|false_computation=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # remat barriers / aliasing plumbing move no data
    "optimization-barrier", "custom-call", "domain",
}
_CONTROL_OPS = {"while", "conditional", "call", "fusion", "async-start", "async-done"}


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(type_str):
        total += _DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]  # name -> type string
    ops: List[Op]
    symbols: Dict[str, str]  # name -> type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_ops: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
    )

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_ops.items():
            self.coll_ops[k]["count"] += v["count"] * mult
            self.coll_ops[k]["bytes"] += v["bytes"] * mult


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                params: Dict[str, str] = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]))", m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), params, [], dict(params))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, opcode, operand_str = parsed
            operands = _OPERAND_RE.findall(operand_str)
            cur.ops.append(Op(name, type_str, opcode, line, operands))
            cur.symbols[name] = type_str
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    out_shapes = _parse_shapes(op.type_str)
    if not out_shapes:
        return 0.0
    out_numel = math.prod(out_shapes[0][1]) if out_shapes[0][1] else 1
    lhs = comp.symbols.get(op.operands[0]) if op.operands else None
    contracted = 1
    if lhs:
        lhs_shapes = _parse_shapes(lhs)
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            m = _CONTRACT_RE.search(op.line)
            if m and m.group(1):
                for d in m.group(1).split(","):
                    di = int(d)
                    if di < len(dims):
                        contracted *= dims[di]
    return 2.0 * out_numel * contracted


def _conv_flops(op: Op, comp: Computation) -> float:
    # flops ~= 2 * numel(out) * prod(kernel spatial+input feature)
    out_shapes = _parse_shapes(op.type_str)
    rhs = comp.symbols.get(op.operands[1]) if len(op.operands) > 1 else None
    if not out_shapes or not rhs:
        return 0.0
    out_numel = math.prod(out_shapes[0][1]) if out_shapes[0][1] else 1
    rhs_shapes = _parse_shapes(rhs)
    k = math.prod(rhs_shapes[0][1][:-1]) if rhs_shapes and rhs_shapes[0][1] else 1
    return 2.0 * out_numel * k


def _collective(op: Op) -> Tuple[str, float]:
    rb = _type_bytes(op.type_str)
    g = 2
    m = _GROUPS_LIST_RE.search(op.line)
    if m:
        g = len(m.group(1).split(","))
    else:
        m = _GROUPS_IOTA_RE.search(op.line)
        if m:
            g = int(m.group(2))
    if g <= 1:
        return op.opcode, 0.0
    base = op.opcode.replace("-start", "")
    if base == "all-gather":
        return base, rb * (g - 1) / g
    if base == "all-reduce":
        return base, 2.0 * rb * (g - 1) / g
    if base == "reduce-scatter":
        return base, rb * (g - 1)
    if base == "all-to-all":
        return base, rb * (g - 1) / g
    if base == "collective-permute":
        return base, float(rb)
    return base, 0.0


_TRIP_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _trip_count(cond: Computation) -> float:
    """lax.scan-style loops compare the induction var to a constant."""
    consts = [int(m.group(1)) for op in cond.ops for m in _TRIP_CONST_RE.finditer(op.line)]
    root_line = cond.ops[-1].line if cond.ops else ""
    if "compare" in root_line and consts:
        return float(max(consts))
    return float(max(consts)) if consts else 1.0


_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
    "all-reduce-start", "all-gather-start", "reduce-scatter-start",
    "all-to-all-start", "collective-permute-start",
}


def _comp_cost(comp: Computation, comps: Dict[str, Computation], memo: Dict[str, Cost],
               inside_fusion: bool) -> Cost:
    key = comp.name + ("#f" if inside_fusion else "")
    if key in memo:
        return memo[key]
    memo[key] = Cost()  # break cycles defensively
    c = Cost()
    for op in comp.ops:
        oc = op.opcode
        if oc == "dot":
            c.flops += _dot_flops(op, comp)
        elif oc == "convolution":
            c.flops += _conv_flops(op, comp)
        if oc in _COLLECTIVE_OPS and not oc.endswith("-done"):
            base, moved = _collective(op)
            c.coll_bytes += moved
            c.coll_ops[base]["count"] += 1
            c.coll_ops[base]["bytes"] += moved

        if oc == "fusion":
            m = _CALLS_RE.search(op.line)
            sub_comp = comps.get(m.group(1)) if m else None
            if sub_comp is not None:
                sub = _comp_cost(sub_comp, comps, memo, inside_fusion=True)
                c.add(sub, 1.0)
            if not inside_fusion:
                c.bytes += _fusion_bytes(op, comp, sub_comp)
        elif oc == "while":
            m = _WHILE_RE.search(op.line)
            if m:
                cond_name, body_name = m.group(1), m.group(2)
                trips = _trip_count(comps[cond_name]) if cond_name in comps else 1.0
                if body_name in comps:
                    sub = _comp_cost(comps[body_name], comps, memo, inside_fusion=False)
                    c.add(sub, trips)
                if cond_name in comps:
                    sub = _comp_cost(comps[cond_name], comps, memo, inside_fusion=False)
                    c.add(sub, trips)
        elif oc == "conditional":
            for m in _BRANCHES_RE.finditer(op.line):
                names = (m.group(1).split(",") if m.group(1) else []) + [m.group(2), m.group(3)]
                for nm in names:
                    if nm and nm.strip().lstrip("%") in comps:
                        c.add(_comp_cost(comps[nm.strip().lstrip("%")], comps, memo, False), 1.0)
        elif oc in ("call", "async-start"):
            m = _CALLS_RE.search(op.line)
            if m and m.group(1) in comps:
                c.add(_comp_cost(comps[m.group(1)], comps, memo, inside_fusion), 1.0)
            if not inside_fusion and oc != "async-start":
                c.bytes += _op_bytes(op, comp)
        elif oc in _FREE_OPS or inside_fusion or oc.endswith("-done"):
            pass
        else:
            c.bytes += _op_bytes(op, comp)
    memo[key] = c
    return c


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _op_bytes(op: Op, comp: Computation) -> float:
    if op.opcode in _SLICE_OPS:
        return 2.0 * _type_bytes(op.type_str)  # read slice + write result
    if op.opcode in ("dynamic-update-slice", "scatter"):
        upd = comp.symbols.get(op.operands[1]) if len(op.operands) > 1 else None
        if upd:
            return 2.0 * _type_bytes(upd)  # read update + write region (in-place)
    total = _type_bytes(op.type_str)
    for o in op.operands:
        t = comp.symbols.get(o)
        if t:
            total += _type_bytes(t)
    return float(total)


def _fusion_bytes(op: Op, comp: Computation, sub: Optional[Computation]) -> float:
    """HBM traffic of one fusion.

    Operand rules (per fused parameter):
      * used only by slice/gather ops            -> sum of slice result bytes
      * used only by DUS-as-operand-0 (in-place) -> 0 (update counted at root)
      * mix of the two (read-modify-write of a
        stacked accumulator in a scan body)      -> slice result bytes only
      * anything else                            -> full operand bytes

    Root rules:
      * dynamic-update-slice root  -> 2x update bytes (write region + read)
      * TUPLE root (multi-output fusion, e.g. one scan-body fusion updating
        several stacked grad accumulators) -> per element: DUS -> 2x its
        update bytes, else the element's full bytes
      * else -> full result bytes
    """
    if sub is None:
        return _op_bytes(op, comp)
    # fusions made ONLY of dtype-converts/bitcasts/copies are layout plumbing
    # the TPU backend folds into neighboring fusions: free
    if sub.ops and all(
        o.opcode in ("convert", "bitcast", "copy", "reshape", "broadcast",
                     "parameter", "tuple", "constant")
        for o in sub.ops
    ):
        return 0.0
    params = list(sub.params)  # insertion order == operand order
    by_name = {o.name: o for o in sub.ops}

    # dtype converts / bitcasts / copies are free inside a fusion: trace
    # THROUGH them both when collecting a param's effective uses and when
    # peeling the root (XLA keeps the DUS in place; the convert wrapper is a
    # CPU-backend fusion artifact).
    _TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "broadcast"}

    def effective_uses(name: str, depth: int = 0) -> List[Op]:
        if depth > 8:
            return []
        out: List[Op] = []
        for o in sub.ops:
            if name in o.operands:
                if o.opcode in _TRANSPARENT:
                    out.extend(effective_uses(o.name, depth + 1))
                else:
                    out.append(o)
        return out

    def peel(name: str, depth: int = 0) -> Optional[Op]:
        o = by_name.get(name)
        while o is not None and o.opcode in _TRANSPARENT and o.operands and depth < 8:
            o = by_name.get(o.operands[0])
            depth += 1
        return o

    total = 0.0
    for i, operand in enumerate(op.operands):
        full = _type_bytes(comp.symbols.get(operand, ""))
        if i < len(params):
            pname = params[i]
            uses = effective_uses(pname)
            slice_uses = [u for u in uses if u.opcode in _SLICE_OPS]
            dus_pass = [
                u for u in uses
                if u.opcode == "dynamic-update-slice"
                and u.operands
                and peel(u.operands[0]) is not None
                and peel(u.operands[0]).opcode == "parameter"
            ]
            if uses and len(slice_uses) + len(dus_pass) == len(uses):
                total += sum(_type_bytes(u.type_str) for u in slice_uses)
                continue
        total += full

    def _dus_bytes(dus_op: Op) -> float:
        if len(dus_op.operands) > 1:
            upd = peel(dus_op.operands[1])
            t = sub.symbols.get(upd.name if upd is not None else dus_op.operands[1], "")
            t = t or sub.symbols.get(dus_op.operands[1], "")
            return 2.0 * _type_bytes(t)
        return _type_bytes(dus_op.type_str)

    root = peel(sub.ops[-1].name) if sub.ops else None
    if root is not None and root.opcode == "dynamic-update-slice":
        total += _dus_bytes(root)
    elif root is not None and root.opcode == "tuple":
        for el in root.operands:
            el_op = peel(el)
            if el_op is not None and el_op.opcode == "dynamic-update-slice":
                total += _dus_bytes(el_op)
            elif el_op is not None and el_op.opcode == "parameter":
                pass  # passed-through operand, no new traffic
            else:
                total += _type_bytes(sub.symbols.get(el, ""))
    else:
        total += _type_bytes(op.type_str)
    return total


_META_RE = re.compile(r'op_name="([^"]*)"')


def top_sites(text: str, kind: str = "collective", k: int = 15):
    """Largest cost sites with loop multipliers, for perf investigation.

    kind: "collective" (bytes moved) | "dot" (flops) | "fusion" (HBM bytes).
    Returns [(total, mult, per_iter, opcode, jax_op_name), ...].
    """
    comps = parse_hlo(text)
    entry_name = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in comps:
        entry_name = max(comps, key=lambda c: len(comps[c].ops))
    sites = []

    def walk(comp: Computation, mult: float, inside: bool):
        for op in comp.ops:
            oc = op.opcode
            meta = (_META_RE.search(op.line) or [None, ""])[1] if _META_RE.search(op.line) else ""
            if oc == "fusion":
                m = _CALLS_RE.search(op.line)
                sub = comps.get(m.group(1)) if m else None
                if sub is not None:
                    walk(sub, mult, True)
                if kind == "fusion" and not inside:
                    b = _fusion_bytes(op, comp, sub)
                    sites.append((b * mult, mult, b, op.name, meta))
            elif oc == "while":
                m = _WHILE_RE.search(op.line)
                if m:
                    trips = _trip_count(comps[m.group(1)]) if m.group(1) in comps else 1.0
                    if m.group(2) in comps:
                        walk(comps[m.group(2)], mult * trips, False)
            elif oc in ("call", "async-start"):
                m = _CALLS_RE.search(op.line)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult, inside)
            elif kind == "collective" and oc in _COLLECTIVE_OPS and not oc.endswith("-done"):
                base, moved = _collective(op)
                sites.append((moved * mult, mult, moved, base + ":" + op.name, meta))
            elif kind == "dot" and oc == "dot":
                f = _dot_flops(op, comp)
                sites.append((f * mult, mult, f, op.name, meta))

    walk(comps[entry_name], 1.0, False)
    sites.sort(reverse=True)
    return sites[:k]


def analyze_hlo(text: str) -> Cost:
    comps = parse_hlo(text)
    # entry = computation referenced by ENTRY, else the last one
    entry_name = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in comps:
        # fall back: computation with the most ops
        entry_name = max(comps, key=lambda k: len(comps[k].ops))
    memo: Dict[str, Cost] = {}
    # exclude called computations from double-count: costs flow through calls
    return _comp_cost(comps[entry_name], comps, memo, inside_fusion=False)
