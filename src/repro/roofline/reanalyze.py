"""Recompute roofline terms from saved HLO dumps without recompiling.

    PYTHONPATH=src python -m repro.roofline.reanalyze \
        --hlo results/hlo --dryrun results/dryrun

Updates the per-cell JSONs in place with the current hlo_cost model; used
when the cost model improves after an expensive sweep, and by the perf loop
to diff before/after HLO.
"""
from __future__ import annotations

import argparse
import gzip
import json
from pathlib import Path

from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_cost import analyze_hlo


def reanalyze(hlo_dir: Path, dryrun_dir: Path) -> int:
    n = 0
    for gz in sorted(hlo_dir.glob("*.hlo.gz")):
        cell = gz.name.replace(".hlo.gz", "")
        jpath = dryrun_dir / f"{cell}.json"
        if not jpath.exists():
            print(f"[skip] no json for {cell}")
            continue
        rec = json.loads(jpath.read_text())
        with gzip.open(gz, "rt") as f:
            hlo = f.read()
        cost = analyze_hlo(hlo)
        rec["flops_per_dev"] = float(cost.flops)
        rec["bytes_per_dev"] = float(cost.bytes)
        rec["collective_bytes_per_dev"] = float(cost.coll_bytes)
        rec["collective_ops"] = {k: dict(v) for k, v in cost.coll_ops.items()}
        rec.update(roofline_terms(cost.flops, cost.bytes, cost.coll_bytes))
        mf = rec.get("model_flops_total", 0.0)
        n_chips = rec.get("n_chips", 1)
        rec["useful_flops_ratio"] = round(mf / (cost.flops * n_chips), 4) if cost.flops else 0.0
        jpath.write_text(json.dumps(rec, indent=1, default=str))
        n += 1
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="results/hlo")
    ap.add_argument("--dryrun", default="results/dryrun")
    args = ap.parse_args()
    n = reanalyze(Path(args.hlo), Path(args.dryrun))
    print(f"reanalyzed {n} cells")


if __name__ == "__main__":
    main()
