"""Roofline report generator: reads results/dryrun/*.json and emits the
EXPERIMENTS.md §Dry-run and §Roofline tables.

    PYTHONPATH=src python -m repro.roofline.report --dryrun results/dryrun
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from pathlib import Path
from typing import Dict, List


def _advice(rec: dict) -> str:
    b = rec.get("bottleneck", "")
    kind = rec["shape"].split("_")[0]
    if b == "compute":
        if rec.get("useful_flops_ratio", 1) < 0.5:
            return "compute-bound with low useful-flops: cut remat recompute / replicated attention math"
        return "compute-bound near roofline: only larger per-chip batch or quantization moves it"
    if b == "memory":
        if kind in ("decode", "long"):
            return "HBM-bound on KV reads: shrink cache dtype (int8 KV) or shard cache seq further"
        return "HBM-bound: raise arithmetic intensity (fuse, larger microbatch) or cut remat traffic"
    if b == "collective":
        return "ICI-bound: reshard to cut all-gathers (seq-parallel attention / a2a MoE dispatch), overlap with compute"
    return ""


def load(dryrun_dir: Path, tag: str = "") -> List[dict]:
    recs = []
    for p in sorted(dryrun_dir.glob("*.json")):
        name = p.stem
        if tag and not name.endswith(tag):
            continue
        if not tag and "." in name.replace("__", ""):
            pass
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_seconds(s) -> str:
    if s is None:
        return "-"
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"


def table(recs: List[dict], mesh: str) -> str:
    rows = [r for r in recs if r.get("mesh") == mesh]
    out = [
        f"### Mesh: {mesh} ({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips)",
        "",
        "| arch | shape | status | compute | memory | collective | bottleneck | useful-FLOPs | HBM/dev | fits 16GB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['status'].upper()} "
                f"| - | - | - | - | - | - | - | {r.get('reason','')[:80]} |"
            )
            continue
        out.append(
            "| {arch} | {shape} | ok | {c} | {m} | {k} | **{b}** | {u:.2f} | {h:.1f}GB | {f} | {adv} |".format(
                arch=r["arch"], shape=r["shape"],
                c=fmt_seconds(r.get("compute_s")), m=fmt_seconds(r.get("memory_s")),
                k=fmt_seconds(r.get("collective_s")), b=r.get("bottleneck", "?"),
                u=r.get("useful_flops_ratio", 0), h=r.get("hbm_per_dev_gb", 0),
                f="yes" if r.get("fits_hbm") else "NO",
                adv=_advice(r),
            )
        )
    return "\n".join(out)


def summary(recs: List[dict]) -> str:
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skip" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    by_bottleneck = defaultdict(int)
    for r in recs:
        if r["status"] == "ok":
            by_bottleneck[r["bottleneck"]] += 1
    worst = sorted(
        (r for r in recs if r["status"] == "ok" and r["shape"] == "train_4k"),
        key=lambda r: r.get("useful_flops_ratio", 0),
    )[:3]
    lines = [
        f"cells: {n_ok} ok / {n_skip} skip / {n_err} error",
        "bottleneck histogram: " + ", ".join(f"{k}={v}" for k, v in sorted(by_bottleneck.items())),
        "lowest useful-FLOPs train cells: "
        + ", ".join(f"{r['arch']}({r['useful_flops_ratio']:.2f})" for r in worst),
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(Path(args.dryrun), args.tag)
    print(summary(recs))
    print()
    for mesh in ("single", "multi"):
        print(table(recs, mesh))
        print()


if __name__ == "__main__":
    main()
