"""Roofline-term extraction from compiled XLA artifacts.

Terms per (arch x shape x mesh), all in seconds-per-step on the target
TPU v5e constants:

  compute    = HLO_FLOPs_per_device / peak_flops
  memory     = HLO_bytes_per_device / hbm_bw
  collective = ring-model collective bytes per device / ici_bw

``cost_analysis()`` on an SPMD-partitioned module reports *per-device*
figures (verified empirically by roofline/calibrate.py: a 4-way-sharded
matmul reports 1/4 of the total FLOPs).  Collective bytes are parsed from
the compiled HLO: per op we apply standard ring-algorithm byte counts using
the op's replica-group size g:

  all-gather          (g-1)/g * result_bytes
  all-reduce          2 * (g-1)/g * result_bytes
  reduce-scatter      (g-1)   * result_bytes       (input = g * result)
  all-to-all          (g-1)/g * result_bytes
  collective-permute  result_bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e (assignment constants)."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link
    hbm_bytes: float = 16e9  # HBM capacity per chip
    vmem_bytes: float = 128 * 2 ** 20
    # kernel-launch + dispatch overhead for one pallas_call (used by the
    # DSA-adapted offload-crossover model, core/perfmodel.py)
    launch_overhead_s: float = 4e-6


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\(?[^)=]*?\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(result: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(result):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [n_groups, group_size]<=[total]
        return int(m.group(2))
    return 2  # conservative default


def collective_bytes_from_hlo(hlo: str) -> Tuple[float, Dict[str, Dict[str, float]]]:
    """Returns (total per-device collective bytes, per-op breakdown)."""
    per_op: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    total = 0.0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("result"))
        g = _group_size(line)
        if g <= 1:
            continue
        if op == "all-gather":
            moved = rb * (g - 1) / g
        elif op == "all-reduce":
            moved = 2.0 * rb * (g - 1) / g
        elif op == "reduce-scatter":
            moved = rb * (g - 1)
        elif op == "all-to-all":
            moved = rb * (g - 1) / g
        else:  # collective-permute
            moved = float(rb)
        per_op[op]["count"] += 1
        per_op[op]["bytes"] += moved
        total += moved
    return total, dict(per_op)


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
    hw: HW = V5E,
) -> Dict[str, float]:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = coll_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(compute, memory, collective)
    terms["roofline_fraction_compute"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops_for_cell(cfg, shape, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode counts one
    token per sequence, prefill/train count every token."""
    n = cfg.active_params()
    if mode == "decode":
        tokens = shape.global_batch
        return 2.0 * n * tokens  # forward only
    tokens = shape.global_batch * shape.seq_len
    if mode == "prefill":
        return 2.0 * n * tokens
    return 6.0 * n * tokens  # fwd + bwd
