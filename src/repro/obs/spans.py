"""Descriptor-lifecycle span model (the per-operation Fig. 5).

A traced descriptor accumulates a small dict of write-once perf_counter
timestamps ("marks") as it moves through the offload pipeline:

  create -> submit_enter -> validate0/1 -> accept -> dispatch
         -> exec0/exec1 -> resolved -> observed -> cb0/cb1

Consecutive marks bound the lifecycle *phases* the paper's latency
breakdown reasons about:

  create            descriptor allocation until Device.submit is entered
  validate          desclint validation (submit-time descriptor checks)
  submit            policy selection + WQ enqueue (ENQCMD/MOVDIR64B path)
  wq_wait           queued in the WQ (plus fence hold for after= deps)
  engine_dispatch   group arbiter pop -> PE worker pickup
  pe_exec           kernel dispatch on the PE worker
  completion_write  dispatch done -> completion record resolved
  host_wait         resolved -> the host observes completion
  callback          user done-callbacks

Marks are written causally along the descriptor's path (submit thread ->
arbiter -> PE worker -> retire thread -> observer), each exactly once, so
a plain dict is safe under the GIL; ``clean_marks`` clamps any residual
cross-thread clock skew so derived spans are always monotonic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

#: lifecycle phases in pipeline order (every derived series/export uses
#: these names)
PHASES: Tuple[str, ...] = (
    "create",
    "validate",
    "submit",
    "wq_wait",
    "engine_dispatch",
    "pe_exec",
    "completion_write",
    "host_wait",
    "callback",
)

#: raw mark names in causal order
MARK_ORDER: Tuple[str, ...] = (
    "create",
    "submit_enter",
    "validate0",
    "validate1",
    "accept",
    "dispatch",
    "exec0",
    "exec1",
    "resolved",
    "observed",
    "cb0",
    "cb1",
)

#: phase -> (start mark, end mark) for engine-submitted descriptors
_PHASE_BOUNDS: Dict[str, Tuple[str, str]] = {
    "create": ("create", "submit_enter"),
    "validate": ("validate0", "validate1"),
    "submit": ("validate1", "accept"),
    "wq_wait": ("accept", "dispatch"),
    "engine_dispatch": ("dispatch", "exec0"),
    "pe_exec": ("exec0", "exec1"),
    "completion_write": ("exec1", "resolved"),
    "host_wait": ("resolved", "observed"),
    "callback": ("cb0", "cb1"),
}

#: host-side continuations (Future.then) reuse two phases: waiting on the
#: parent, then running the continuation function
_THEN_BOUNDS: Dict[str, Tuple[str, str]] = {
    "host_wait": ("create", "exec0"),
    "callback": ("exec0", "exec1"),
}

#: phases that run on the submitting host vs the engine fabric (Perfetto
#: track assignment)
HOST_PHASES = frozenset(
    {"create", "validate", "submit", "host_wait", "callback"})


@dataclasses.dataclass
class Span:
    """One derived lifecycle interval of a traced descriptor."""

    phase: str
    t0: float
    t1: float
    track: str  # "host" | "engine"

    @property
    def dur(self) -> float:
        return max(self.t1 - self.t0, 0.0)


class DescTrace:
    """The span tree of one traced submittable.

    Identity: ``trace_id`` groups every descriptor of one logical request
    (request-scoped contexts in the serving pipeline); ``desc_id`` is the
    per-descriptor node the critical-path DAG is keyed on.
    """

    __slots__ = ("trace_id", "desc_id", "op", "nbytes", "marks", "attrs",
                 "_tracer", "_folded")

    def __init__(self, trace_id: str, desc_id: int, op: str,
                 nbytes: int = 0, tracer: Optional[Any] = None):
        self.trace_id = trace_id
        self.desc_id = desc_id
        self.op = op
        self.nbytes = nbytes
        self.marks: Dict[str, float] = {}
        self.attrs: Dict[str, Any] = {}
        self._tracer = tracer
        self._folded: set = set()

    def __repr__(self) -> str:  # keep record reprs readable
        return (f"DescTrace({self.trace_id!r}, desc_id={self.desc_id}, "
                f"op={self.op!r}, marks={len(self.marks)})")

    # -- marks ---------------------------------------------------------------
    def mark(self, name: str, t: Optional[float] = None) -> float:
        """Stamp ``name`` once (repeat marks keep the first timestamp, so
        concurrent observers can't rewrite history).  Terminal marks fold
        this trace's finished phases into the tracer's monotonic
        occupancy counters."""
        have = self.marks.get(name)
        if have is not None:
            return have
        if t is None:
            t = time.perf_counter()
        self.marks[name] = t
        if name in ("resolved", "observed", "cb1") and self._tracer is not None:
            self._tracer._fold(self)
        return t

    @property
    def start(self) -> Optional[float]:
        ts = self.marks.values()
        return min(ts) if ts else None

    @property
    def end(self) -> Optional[float]:
        ts = self.marks.values()
        return max(ts) if ts else None

    @property
    def duration_s(self) -> float:
        if not self.marks:
            return 0.0
        return max(self.end - self.start, 0.0)

    def clean_marks(self) -> Dict[str, float]:
        """Marks clamped monotonically non-decreasing along MARK_ORDER
        (cross-thread perf_counter skew must never yield negative spans)."""
        out: Dict[str, float] = {}
        floor: Optional[float] = None
        for name in MARK_ORDER:
            t = self.marks.get(name)
            if t is None:
                continue
            if floor is not None and t < floor:
                t = floor
            out[name] = t
            floor = t
        return out

    # -- derived spans -------------------------------------------------------
    def _bounds(self) -> Dict[str, Tuple[str, str]]:
        return (_THEN_BOUNDS if self.attrs.get("kind") == "then"
                else _PHASE_BOUNDS)

    def phase_durations(self) -> Dict[str, float]:
        """Seconds per completed lifecycle phase (phases whose boundary
        marks have not both landed yet are absent)."""
        marks = self.clean_marks()
        out: Dict[str, float] = {}
        for phase, (m0, m1) in self._bounds().items():
            t0, t1 = marks.get(m0), marks.get(m1)
            if t0 is not None and t1 is not None:
                out[phase] = max(t1 - t0, 0.0)
        return out

    def spans(self) -> List[Span]:
        """The trace as ordered Span intervals (Perfetto slices)."""
        marks = self.clean_marks()
        bounds = self._bounds()
        out: List[Span] = []
        for phase in PHASES:
            bound = bounds.get(phase)
            if bound is None:
                continue
            t0, t1 = marks.get(bound[0]), marks.get(bound[1])
            if t0 is None or t1 is None:
                continue
            track = ("host" if phase in HOST_PHASES
                     or self.attrs.get("kind") == "then" else "engine")
            out.append(Span(phase, t0, t1, track))
        return out
