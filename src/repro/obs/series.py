"""Bounded ring-buffer time series — the storage primitive of the sampler.

pcm-accel keeps a sliding window of per-interval accelerator counters; a
``Series`` is that window for one metric: ``(t, value)`` pairs in a deque
with a hard capacity, so a sampler left running for hours holds a bounded
tail (capacity x interval seconds of history) instead of growing without
limit.  ``summary()`` gives the windowed p50/p95/max/mean rollup the
overload experiments read."""
from __future__ import annotations

import collections
import math
from typing import Iterator, List, Optional, Tuple


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) over a non-empty list."""
    if not values:
        raise ValueError("percentile of empty series")
    ordered = sorted(values)
    rank = max(int(math.ceil(q / 100.0 * len(ordered))), 1)
    return ordered[rank - 1]


class Series:
    """One metric's bounded time series of ``(t, value)`` samples."""

    def __init__(self, name: str, capacity: int = 600, unit: str = ""):
        if capacity < 1:
            raise ValueError(f"Series capacity must be >= 1, got {capacity}")
        self.name = name
        self.unit = unit
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)

    def append(self, t: float, value: float) -> None:
        self._buf.append((t, float(value)))

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Tuple[float, float]]:
        return iter(self._buf)

    @property
    def times(self) -> List[float]:
        return [t for t, _ in self._buf]

    @property
    def values(self) -> List[float]:
        return [v for _, v in self._buf]

    def last(self) -> Optional[float]:
        return self._buf[-1][1] if self._buf else None

    def window(self, window_s: Optional[float] = None) -> List[Tuple[float, float]]:
        """The samples of the trailing ``window_s`` seconds (all when None)."""
        if window_s is None or not self._buf:
            return list(self._buf)
        cutoff = self._buf[-1][0] - window_s
        return [(t, v) for t, v in self._buf if t >= cutoff]

    def sum(self) -> float:
        """Sum of the buffered values — for delta series (bytes/ops per
        tick) this is the total over the retained window, which equals the
        all-time total while nothing has rotated out."""
        return sum(v for _, v in self._buf)

    def summary(self, window_s: Optional[float] = None) -> dict:
        """p50/p95/max/mean/last over the trailing window (empty -> zeros)."""
        vals = [v for _, v in self.window(window_s)]
        if not vals:
            return {"n": 0, "p50": 0.0, "p95": 0.0, "max": 0.0,
                    "mean": 0.0, "last": 0.0}
        return {
            "n": len(vals),
            "p50": percentile(vals, 50),
            "p95": percentile(vals, 95),
            "max": max(vals),
            "mean": sum(vals) / len(vals),
            "last": vals[-1],
        }

    def __repr__(self) -> str:
        tail = f", last={self.last():.3g}" if self._buf else ""
        return (f"Series({self.name!r}, n={len(self)}/{self.capacity}"
                f"{tail})")
