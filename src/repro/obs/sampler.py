"""pcm-accel-style periodic sampler over a ``Device``.

The paper's §5 telemetry (and Intel PCM's pcm-accel tool) works by
sampling accelerator counters at a fixed interval and reporting per-
interval rates — inbound/outbound traffic and request count per DSA
instance — because raw cumulative counters are unusable without periodic
rollup.  ``Sampler`` is that loop for this repo's engine fabric:

  * every tick reads each engine's MONOTONIC counters
    (``StreamEngine.counters``, bumped once per resolved record) and each
    WQ's stats dict, and folds the DELTA since the previous tick into
    bounded ring-buffer time series — O(engines + WQs) per tick, never a
    rescan of completion records;
  * per-engine bandwidth and utilization, per-WQ occupancy / inflow /
    queueing delay, per-NUMA-node local vs cross traffic and link
    occupancy, per-WaitPolicy host-free fraction, and QueueFull/backoff
    pressure are all first-class metrics (docs/observability.md has the
    glossary);
  * ``start()`` runs the tick on a background thread at ``interval_s``
    (registering with ``Device.attach_observer``); ``tick()`` can equally
    be driven by hand with an injected clock — that is how the
    deterministic tests and ``--once`` monitoring run;
  * exporters: ``to_csv()`` / ``to_jsonl()`` (one row per tick, one column
    per metric) and ``summary()`` (p50/p95/max/mean per metric over a
    trailing window).

Reconciliation contract: the sum of a delta series (``engine.*.bytes``,
``engine.*.ops``) equals the corresponding total in
``Telemetry.snapshot()`` taken over the same run — both count exactly the
resolved completion records — as long as the ring buffer has not rotated
(capacity x interval covers the run).  tests/test_obs.py pins this.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs.series import Series


class Sampler:
    """Periodic delta sampler over a Device's engines/WQs/nodes/waits."""

    def __init__(self, device: Any, interval_s: float = 0.1,
                 capacity: int = 600,
                 clock: Callable[[], float] = time.perf_counter):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.device = device
        self.interval_s = interval_s
        self.capacity = capacity
        self.clock = clock
        self.series: Dict[str, Series] = {}
        # one dict per tick: {"time_s": t, "dt_s": dt, metric: value, ...}
        self._rows: collections.deque = collections.deque(maxlen=capacity)
        self._columns: List[str] = ["time_s", "dt_s"]  # first-seen order
        # running totals of the delta counters (reconciliation anchor);
        # unlike the ring buffers these never rotate out
        self.totals: Dict[str, Dict[str, float]] = {
            "engines": {}, "nodes": {}, "device": {"ticks": 0},
        }
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # first exception a tick raised on the background thread (e.g. the
        # device was torn down mid-run): the thread stops sampling instead
        # of crashing with a traceback, and stop()/callers can inspect it
        self.error: Optional[BaseException] = None
        # gauges pushed between ticks (serving stages etc.); folded into the
        # next tick's row so exports stay one-row-per-tick
        self._pending_gauges: Dict[str, float] = {}
        self.t0 = self.clock()
        self._last_t = self.t0
        self._prev = self._read_counters()

    # ------------------------------------------------------------------ raw reads
    def _read_counters(self) -> dict:
        """One coherent pass over every monotonic counter the tick deltas
        against: engine counters, per-WQ stats, wait stats, policy stats."""
        prev: dict = {"engines": {}, "wqs": {}, "wait": {}, "policy": {}}
        for e in self.device.engines:
            prev["engines"][e.name] = e.counters_snapshot()
            for g in e.config.groups:
                for w in g.wqs:
                    prev["wqs"][(e.name, w.name)] = dict(w.stats)
        for name, ws in list(getattr(self.device, "wait_stats", {}).items()):
            prev["wait"][name] = {"busy_s": ws.busy_s, "free_s": ws.free_s,
                                  "wakes": ws.wakes, "irqs": ws.irqs,
                                  "completions": ws.completions}
        ps = getattr(self.device, "policy_stats", None)
        if ps is not None:
            prev["policy"] = {"backoff_retries": ps["backoff_retries"],
                              "queue_full": ps["queue_full"],
                              "desclint_warnings":
                                  ps.get("desclint_warnings", 0)}
        tracer = getattr(self.device, "tracer", None)
        if tracer is not None:
            prev["trace"] = tracer.counters_snapshot()
        return prev

    # ------------------------------------------------------------------ recording
    def _series(self, name: str, unit: str = "") -> Series:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = Series(name, capacity=self.capacity,
                                           unit=unit)
        return s

    def _record(self, row: dict, name: str, value: float, t: float,
                unit: str = "") -> None:
        self._series(name, unit).append(t, value)
        row[name] = float(value)
        if name not in self._columns:
            self._columns.append(name)

    def gauge(self, name: str, value: float,
              now: Optional[float] = None) -> None:
        """Record an externally-produced gauge (e.g. the serving pipeline's
        per-stage occupancy) into its own bounded series.  Gauges land in
        the NEXT tick's row so exports stay one-row-per-tick."""
        t = self.clock() if now is None else now
        with self._lock:
            self._series(name).append(t, value)
            self._pending_gauges[name] = float(value)

    # ------------------------------------------------------------------ the tick
    def tick(self, now: Optional[float] = None) -> dict:
        """Take one sample: delta every monotonic counter against the
        previous tick, append per-metric series, and return this tick's
        row.  ``now`` injects a deterministic clock for tests."""
        with self._lock:
            t = self.clock() if now is None else now
            dt = max(t - self._last_t, 1e-9)
            cur = self._read_counters()
            row: dict = {"time_s": t - self.t0, "dt_s": dt}

            node_delta: Dict[int, Dict[str, float]] = {}
            for e in self.device.engines:
                name = e.name
                c = cur["engines"][name]
                p = self._prev["engines"].get(name, {})
                d = {k: c[k] - p.get(k, 0) for k in c}
                self._record(row, f"engine.{name}.bytes", d["bytes"], t, "B")
                self._record(row, f"engine.{name}.ops", d["completed"], t)
                self._record(row, f"engine.{name}.errors", d["errors"], t)
                self._record(row, f"engine.{name}.gbps",
                             d["bytes"] / dt / 1e9, t, "GB/s")
                # submission-side rates: accepted submits this tick and the
                # fraction that arrived through a fused doorbell
                # (submit_many / submit ring) — the batch-amortization
                # health gauge for the pcm_repro SUB/s + FUSED% columns
                subs = d.get("submitted", 0)
                self._record(row, f"engine.{name}.submits", subs, t)
                self._record(row, f"engine.{name}.submits_per_s",
                             subs / dt, t, "/s")
                self._record(row, f"engine.{name}.fused_frac",
                             d.get("fused_descs", 0) / max(subs, 1), t)
                # modeled busy-time over wall interval: the engine-side
                # utilization estimate (can exceed 1 when PEs run parallel)
                self._record(row, f"engine.{name}.util",
                             d["modeled_us"] * 1e-6 / dt, t)
                tot = self.totals["engines"].setdefault(
                    name, {"bytes": 0.0, "ops": 0.0, "errors": 0.0})
                tot["bytes"] += d["bytes"]
                tot["ops"] += d["completed"]
                tot["errors"] += d["errors"]

                occs, depths = [], []
                retried = dispatched = delay_us = inflow = 0.0
                for g in e.config.groups:
                    for w in g.wqs:
                        ws = cur["wqs"][(name, w.name)]
                        wp = self._prev["wqs"].get((name, w.name), {})
                        wd = {k: ws[k] - wp.get(k, 0) for k in ws}
                        occs.append(w.occupancy)
                        depths.append(len(w))
                        retried += wd["retried"]
                        dispatched += wd["dispatched"]
                        delay_us += wd["queue_delay_us"]
                        inflow += wd["bytes_submitted"]
                        self._record(row, f"wq.{name}.{w.name}.occupancy",
                                     w.occupancy, t)
                        self._record(row, f"wq.{name}.{w.name}.inflow_gbps",
                                     wd["bytes_submitted"] / dt / 1e9, t,
                                     "GB/s")
                        self._record(
                            row, f"wq.{name}.{w.name}.queue_delay_us",
                            wd["queue_delay_us"] / max(wd["dispatched"], 1),
                            t, "us")
                self._record(row, f"engine.{name}.wq_occupancy",
                             sum(occs) / max(len(occs), 1), t)
                self._record(row, f"engine.{name}.wq_depth", sum(depths), t)
                self._record(row, f"engine.{name}.retries", retried, t)
                self._record(row, f"engine.{name}.queue_delay_us",
                             delay_us / max(dispatched, 1), t, "us")

                nid = getattr(e, "node_id", 0)
                nd = node_delta.setdefault(
                    nid, {"local_bytes": 0.0, "cross_bytes": 0.0,
                          "link_bytes": 0.0, "local_ops": 0.0,
                          "cross_ops": 0.0})
                for k in nd:
                    nd[k] += d[k]

            topo = getattr(self.device, "topology", None)
            link_bw = (topo.link.bw if topo is not None
                       and getattr(topo, "n_nodes", 1) > 1 else None)
            for nid in sorted(node_delta):
                nd = node_delta[nid]
                self._record(row, f"node.{nid}.local_gbps",
                             nd["local_bytes"] / dt / 1e9, t, "GB/s")
                self._record(row, f"node.{nid}.cross_gbps",
                             nd["cross_bytes"] / dt / 1e9, t, "GB/s")
                self._record(row, f"node.{nid}.link_occupancy",
                             nd["link_bytes"] / link_bw / dt if link_bw
                             else 0.0, t)
                tot = self.totals["nodes"].setdefault(
                    nid, {k: 0.0 for k in nd})
                for k in nd:
                    tot[k] += nd[k]

            for pname, ws in cur["wait"].items():
                wp = self._prev["wait"].get(
                    pname, {k: 0 for k in ("busy_s", "free_s", "wakes",
                                           "irqs", "completions")})
                busy = ws["busy_s"] - wp["busy_s"]
                free = ws["free_s"] - wp["free_s"]
                if busy + free > 0:
                    self._record(row,
                                 f"wait.{pname}.host_free_frac",
                                 free / (busy + free), t)
                self._record(row, f"wait.{pname}.wakes",
                             ws["wakes"] - wp["wakes"], t)
                self._record(row, f"wait.{pname}.irqs",
                             ws["irqs"] - wp["irqs"], t)

            if cur["policy"]:
                pp = self._prev.get("policy") or {"backoff_retries": 0,
                                                  "queue_full": 0}
                self._record(row, "device.backoff_retries",
                             cur["policy"]["backoff_retries"]
                             - pp["backoff_retries"], t)
                self._record(row, "device.queue_full",
                             cur["policy"]["queue_full"]
                             - pp["queue_full"], t)
                self._record(row, "device.desclint_warnings",
                             cur["policy"].get("desclint_warnings", 0)
                             - pp.get("desclint_warnings", 0), t)

            tr_cur = cur.get("trace")
            if tr_cur:
                tr_prev = self._prev.get("trace", {})
                self._record(row, "trace.sampled",
                             tr_cur["sampled"] - tr_prev.get("sampled", 0), t)
                # live phase occupancy: seconds of each lifecycle phase
                # completed per wall second this tick (the pcm_repro
                # phases line; >1 means parallel descriptors in flight)
                for key, val in tr_cur.items():
                    if not (key.startswith("phase.") and key.endswith("_s")):
                        continue
                    phase = key[len("phase."):-len("_s")]
                    self._record(row, f"trace.phase.{phase}.occupancy",
                                 (val - tr_prev.get(key, 0.0)) / dt, t)

            for gname, gval in self._pending_gauges.items():
                row[gname] = gval
                if gname not in self._columns:
                    self._columns.append(gname)
            self._pending_gauges = {}

            self._rows.append(row)
            self.totals["device"]["ticks"] += 1
            self._prev = cur
            self._last_t = t
            return row

    # ------------------------------------------------------------------ lifecycle
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "Sampler":
        """Start the background sampling thread (one tick per interval)
        and register with the device.  Idempotent while running."""
        if self.running:
            return self
        self._stop.clear()
        attach = getattr(self.device, "attach_observer", None)
        if attach is not None:
            attach(self)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="obs-sampler")
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.tick()
            except Exception as e:  # noqa: BLE001 — device torn down mid-tick
                # racing a shutdown must not crash the daemon thread with a
                # traceback; record the failure and stop sampling
                self.error = e
                self._stop.set()
                return

    def stop(self, final_tick: bool = True) -> "Sampler":
        """Stop the background thread (taking one last sample so the tail
        of the run is not lost) and detach from the device.  Safe to call
        when the device has already been torn down: a failing final tick
        is recorded on ``self.error`` instead of raising."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_tick:
            try:
                self.tick()
            except Exception as e:  # noqa: BLE001 — shutdown must be clean
                self.error = e
        detach = getattr(self.device, "detach_observer", None)
        if detach is not None:
            try:
                detach(self)
            except Exception as e:  # noqa: BLE001
                self.error = self.error or e
        return self

    def __enter__(self) -> "Sampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------ export
    def rows(self) -> List[dict]:
        with self._lock:
            return [dict(r) for r in self._rows]

    def columns(self) -> List[str]:
        with self._lock:
            return list(self._columns)

    def to_csv(self, path: Optional[str] = None) -> str:
        from repro.obs.export import to_csv

        return to_csv(self, path)

    def to_jsonl(self, path: Optional[str] = None) -> str:
        from repro.obs.export import to_jsonl

        return to_jsonl(self, path)

    def summary(self, window_s: Optional[float] = None) -> Dict[str, dict]:
        """Windowed rollup per metric: {metric: {n, p50, p95, max, mean,
        last}} over the trailing ``window_s`` seconds (all history when
        None, bounded by the ring capacity)."""
        with self._lock:
            return {name: s.summary(window_s)
                    for name, s in sorted(self.series.items())}
