"""Sampler exporters: CSV / JSONL time series, pcm-accel style — plus the
Chrome/Perfetto ``trace_event`` exporter for descriptor-lifecycle traces.

CSV/JSONL are one record per tick.  CSV is wide-form — one column per
metric, mirroring ``pcm-accel -csv`` — with the column set fixed at export
time (metrics that appear mid-run backfill earlier rows with empty cells).
JSONL writes each tick's row as one JSON object, which round-trips ragged
rows exactly; non-finite values (NaN/inf) are serialized as ``null`` so
every emitted line is strict JSON (Python's default would write bare
``NaN`` tokens no JSON parser accepts).

``to_perfetto`` renders a ``repro.obs.trace.Tracer`` as trace_event JSON
loadable as-is in chrome://tracing or https://ui.perfetto.dev: one process
track for the host plus one per engine, one thread lane per descriptor,
complete ("X") slices per lifecycle phase, flow arrows for ``after=`` /
``then`` dependency edges, and a host lane of WaitPolicy wait spans.
"""
from __future__ import annotations

import csv as _csv
import io
import json
import math
from pathlib import Path
from typing import Any, Dict, Optional


def to_csv(sampler, path: Optional[str] = None) -> str:
    """Render the sampler's buffered ticks as CSV; optionally also write
    the text to ``path``.  Returns the CSV text."""
    rows = sampler.rows()
    columns = sampler.columns()
    buf = io.StringIO()
    writer = _csv.DictWriter(buf, fieldnames=columns, restval="",
                             extrasaction="ignore", lineterminator="\n")
    writer.writeheader()
    for row in rows:
        writer.writerow({k: _fmt(v) for k, v in row.items()})
    text = buf.getvalue()
    if path is not None:
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(text)
    return text


def to_jsonl(sampler, path: Optional[str] = None) -> str:
    """Render the buffered ticks as JSON Lines (one strict-JSON object per
    tick; NaN/inf become null); optionally also write to ``path``."""
    lines = [
        json.dumps({k: _json_safe(v) for k, v in row.items()},
                   sort_keys=True, allow_nan=False)
        for row in sampler.rows()
    ]
    text = "\n".join(lines) + ("\n" if lines else "")
    if path is not None:
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(text)
    return text


def to_perfetto(tracer, path: Optional[str] = None, *,
                flows: bool = True) -> str:
    """Render a Tracer's retained traces as Chrome/Perfetto trace_event
    JSON ({"traceEvents": [...]}); optionally also write to ``path``.

    Layout: pid 1 is the host (tid = descriptor id per lane, tid 0 holds
    the WaitPolicy wait spans); each engine that dispatched a sampled
    descriptor gets its own pid.  Timestamps are microseconds from the
    earliest retained mark, clamped non-negative with dur >= 0, so the
    file always passes strict-JSON and monotonicity validation."""
    traces = tracer.traces()
    waits = tracer.wait_spans()
    starts = [dt.start for dt in traces if dt.marks]
    starts += [w.t0 for w in waits]
    base = min(starts, default=0.0)

    def us(t: float) -> float:
        return round(max((t - base) * 1e6, 0.0), 3)

    pids: Dict[str, int] = {"host": 1}

    def pid_for(track: str) -> int:
        pid = pids.get(track)
        if pid is None:
            pid = pids[track] = 1 + len(pids)
        return pid

    events = []
    by_id = {}
    for dt in traces:
        if not dt.marks:
            continue
        by_id[dt.desc_id] = dt
        engine = dt.attrs.get("engine")
        args = {"trace_id": dt.trace_id, "op": dt.op,
                "nbytes": dt.nbytes}
        for k, v in dt.attrs.items():
            args[k] = _json_safe(v)
        for sp in dt.spans():
            track = "host" if sp.track == "host" else (engine or "engine")
            events.append({
                "name": sp.phase,
                "cat": "desc",
                "ph": "X",
                "ts": us(sp.t0),
                "dur": round(max(sp.t1 - sp.t0, 0.0) * 1e6, 3),
                "pid": pid_for(track),
                "tid": int(dt.desc_id),
                "args": args,
            })
    if flows:
        for parent, child, kind in tracer.edges():
            pdt, cdt = by_id.get(parent), by_id.get(child)
            if pdt is None or cdt is None:
                continue
            flow_id = f"{parent}-{child}"
            events.append({
                "name": kind, "cat": "dep", "ph": "s", "id": flow_id,
                "ts": us(pdt.end), "pid": pids["host"], "tid": int(parent),
            })
            events.append({
                "name": kind, "cat": "dep", "ph": "f", "bp": "e",
                "id": flow_id,
                "ts": us(max(cdt.start, pdt.end)),
                "pid": pids["host"], "tid": int(child),
            })
    for w in waits:
        events.append({
            "name": f"wait/{w.policy}",
            "cat": "wait",
            "ph": "X",
            "ts": us(w.t0),
            "dur": round(max(w.t1 - w.t0, 0.0) * 1e6, 3),
            "pid": pids["host"],
            "tid": 0,
            "args": {"busy_s": _json_safe(w.busy_s),
                     "free_s": _json_safe(w.free_s),
                     "completions": w.completions},
        })
    for track, pid in pids.items():
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"dsa-repro/{track}"}})
    if waits:
        events.append({"name": "thread_name", "ph": "M", "pid": pids["host"],
                       "tid": 0, "args": {"name": "waits"}})
    text = json.dumps({"traceEvents": events, "displayTimeUnit": "ms"},
                      sort_keys=True, allow_nan=False)
    if path is not None:
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(text)
    return text


def _json_safe(v: Any) -> Any:
    """Strict-JSON value: non-finite floats become None, everything the
    JSON encoder can't take becomes its repr."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return repr(v)


def _fmt(v) -> str:
    """Compact numeric cells: integers stay integral, floats keep enough
    digits to reconcile byte counts exactly; non-finite floats render as
    empty cells (spreadsheet-safe, matching JSONL's null)."""
    if isinstance(v, float) and not math.isfinite(v):
        return ""
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    if isinstance(v, float):
        return f"{v:.9g}"
    return str(v)
