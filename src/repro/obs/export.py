"""Sampler exporters: CSV / JSONL time series, pcm-accel style.

Both formats are one record per tick.  CSV is wide-form — one column per
metric, mirroring ``pcm-accel -csv`` — with the column set fixed at export
time (metrics that appear mid-run backfill earlier rows with empty cells).
JSONL writes each tick's row as one JSON object, which round-trips ragged
rows exactly.
"""
from __future__ import annotations

import csv as _csv
import io
import json
from pathlib import Path
from typing import Optional


def to_csv(sampler, path: Optional[str] = None) -> str:
    """Render the sampler's buffered ticks as CSV; optionally also write
    the text to ``path``.  Returns the CSV text."""
    rows = sampler.rows()
    columns = sampler.columns()
    buf = io.StringIO()
    writer = _csv.DictWriter(buf, fieldnames=columns, restval="",
                             extrasaction="ignore", lineterminator="\n")
    writer.writeheader()
    for row in rows:
        writer.writerow({k: _fmt(v) for k, v in row.items()})
    text = buf.getvalue()
    if path is not None:
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(text)
    return text


def to_jsonl(sampler, path: Optional[str] = None) -> str:
    """Render the buffered ticks as JSON Lines (one object per tick);
    optionally also write to ``path``.  Returns the JSONL text."""
    lines = [json.dumps(row, sort_keys=True) for row in sampler.rows()]
    text = "\n".join(lines) + ("\n" if lines else "")
    if path is not None:
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(text)
    return text


def _fmt(v) -> str:
    """Compact numeric cells: integers stay integral, floats keep enough
    digits to reconcile byte counts exactly."""
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    if isinstance(v, float):
        return f"{v:.9g}"
    return str(v)
