"""Opt-in descriptor-lifecycle tracing (the per-operation view of §3.3/§5).

``Tracer`` owns a bounded ring of ``DescTrace`` span trees plus the
dependency edges (``after=`` fences, ``Future.then`` continuations) and
host wait spans needed to reconstruct the offload critical path.  It is
wired in by ``make_device(trace=...)``:

    device = make_device(trace=0.1)          # sample 10% of submissions
    ... workload ...
    from repro.obs import to_perfetto, critical_path, phase_breakdown
    to_perfetto(device.tracer, "trace.json")  # chrome://tracing / Perfetto

Design constraints, in order:

  * hot path untouched when off: ``Device.submit`` does one attribute
    check; an unsampled submission costs one accumulator update;
  * bounded memory: traces / edges / wait spans live in fixed-capacity
    deques, while per-phase occupancy folds into MONOTONIC counters the
    ``Sampler`` delta-ticks (so live views survive ring rotation);
  * deterministic sampling: a fractional accumulator admits exactly
    ``rate`` of anonymous submissions (no RNG), and request-scoped
    contexts (``tracer.request(id)``) decide once per request id via a
    stable hash so every descriptor of a request is traced together;
  * typed configuration errors: a sampling rate outside [0, 1] raises
    ``TraceRateError`` (dsalint rule DSA105 flags literal occurrences
    statically).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import zlib
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.obs.series import percentile
from repro.obs.spans import PHASES, DescTrace


class TraceRateError(ValueError):
    """A ``trace=`` sampling rate outside [0, 1] (dsalint DSA105).

    Probabilities don't extrapolate: a rate of 1.5 silently tracing every
    submission (or -0.1 tracing none) hides a config bug, so the bad value
    is rejected at device construction with this typed error.
    """

    code = "DSA105"

    def __init__(self, rate: Any):
        super().__init__(
            f"trace sampling rate must be a number in [0, 1], got {rate!r} "
            f"[{self.code}]"
        )
        self.rate = rate


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Tracer knobs: sampling ``rate`` in [0, 1] (fraction of submissions
    traced; request contexts decide per request id) and ring ``capacity``
    (retained traces; edges/wait spans keep a few multiples)."""

    rate: float = 1.0
    capacity: int = 4096

    def __post_init__(self):
        try:
            ok = 0.0 <= float(self.rate) <= 1.0
        except (TypeError, ValueError):
            ok = False
        if not ok:
            raise TraceRateError(self.rate)
        if self.capacity < 1:
            raise ValueError(f"TraceConfig.capacity must be >= 1, "
                             f"got {self.capacity}")


@dataclasses.dataclass
class WaitSpan:
    """One WaitPolicy.wait interval with its host-cycle split — the same
    busy/free seconds the policy folds into the device's ``WaitStats``
    bucket, so span-derived host-free fractions reconcile exactly."""

    policy: str
    t0: float
    t1: float
    busy_s: float
    free_s: float
    completions: int = 0


def _op_name(desc: Any) -> str:
    op = getattr(desc, "op", None)
    if op is not None:
        return getattr(op, "value", None) or str(op)
    return "batch"


class Tracer:
    """Bounded, sampled collector of descriptor lifecycle traces."""

    def __init__(self, config: Optional[TraceConfig] = None):
        self.config = config or TraceConfig()
        cap = self.config.capacity
        # plain (uninstrumented) leaf lock: the tracer never calls out
        # while holding it, so it cannot extend the lockcheck lock graph
        self._lock = threading.Lock()
        self._ring: "collections.deque[DescTrace]" = collections.deque(maxlen=cap)
        self._edges: "collections.deque[Tuple[int, int, str]]" = (
            collections.deque(maxlen=8 * cap))
        self._waits: "collections.deque[WaitSpan]" = (
            collections.deque(maxlen=8 * cap))
        self._acc = 0.0  # fractional sampling accumulator
        self._tls = threading.local()
        # monotonic counters (delta-sampled by repro.obs.Sampler)
        self.counters: Dict[str, float] = {
            "sampled": 0, "skipped": 0,
            "wait_spans": 0, "wait_busy_s": 0.0, "wait_free_s": 0.0,
        }
        self.phase_s: Dict[str, float] = {p: 0.0 for p in PHASES}
        self.phase_n: Dict[str, int] = {p: 0 for p in PHASES}

    # ------------------------------------------------------------------ sampling
    def _sample(self) -> bool:
        """Deterministic fractional-accumulator admission: over any run of
        N anonymous submissions, floor/ceil(N * rate) are sampled."""
        self._acc += self.config.rate
        if self._acc >= 1.0:
            self._acc -= 1.0
            return True
        return False

    def _sample_id(self, trace_id: str) -> bool:
        """Stable per-id decision (same id -> same answer on every entry,
        so a request re-entering its context keeps one verdict)."""
        rate = self.config.rate
        if rate >= 1.0:
            return True
        if rate <= 0.0:
            return False
        h = zlib.crc32(str(trace_id).encode()) & 0xFFFFFFFF
        return h < rate * 0x100000000

    @contextlib.contextmanager
    def request(self, trace_id: str):
        """Request-scoped trace context: every submission on this thread
        inside the block shares ``trace_id`` (and its sampling verdict).
        Re-entrant; restores the enclosing context on exit."""
        prev = getattr(self._tls, "ctx", None)
        self._tls.ctx = (str(trace_id), self._sample_id(str(trace_id)))
        try:
            yield
        finally:
            self._tls.ctx = prev

    def current_trace_id(self) -> Optional[str]:
        ctx = getattr(self._tls, "ctx", None)
        return ctx[0] if ctx is not None else None

    # ------------------------------------------------------------------ recording
    def begin(self, desc: Any) -> Optional[DescTrace]:
        """Start a trace for one submittable (Device.submit entry), or
        None when sampling skips it.  Inside a request context the
        request's id and verdict apply; otherwise the accumulator decides
        and the trace id derives from the descriptor id."""
        ctx = getattr(self._tls, "ctx", None)
        if ctx is not None:
            trace_id, sampled = ctx
        else:
            with self._lock:
                sampled = self._sample()
            trace_id = f"d{getattr(desc, 'desc_id', 0)}"
        if not sampled:
            with self._lock:
                self.counters["skipped"] += 1
            return None
        dt = DescTrace(trace_id, getattr(desc, "desc_id", -1), _op_name(desc),
                       nbytes=getattr(desc, "nbytes", 0), tracer=self)
        members = getattr(desc, "descriptors", None)
        if members is not None:
            dt.attrs["batch"] = len(members)
            created = [getattr(d, "created_t", None) for d in members]
            created = [t for t in created if t is not None]
        else:
            created = []
        t_create = getattr(desc, "created_t", None)
        if created:
            t_create = min(created) if t_create is None else min(
                [t_create] + created)
        if t_create is not None:
            dt.marks["create"] = t_create
        dt.mark("submit_enter")
        with self._lock:
            self._ring.append(dt)
            self.counters["sampled"] += 1
        return dt

    def begin_host(self, trace_id: str, desc_id: int, op: str) -> DescTrace:
        """Trace for a host-side continuation (Future.then): two phases —
        host_wait until the parent retires, callback for the function."""
        dt = DescTrace(trace_id, desc_id, op, tracer=self)
        dt.attrs["kind"] = "then"
        dt.mark("create")
        with self._lock:
            self._ring.append(dt)
            self.counters["sampled"] += 1
        return dt

    def edge(self, parent_desc_id: int, child_desc_id: int, kind: str) -> None:
        """Record a dependency edge ("after" fence or "then" continuation)
        for the critical-path DAG."""
        with self._lock:
            self._edges.append((int(parent_desc_id), int(child_desc_id), kind))

    def wait_span(self, policy: str, t0: float, t1: float,
                  busy_s: float, free_s: float, completions: int = 0) -> None:
        with self._lock:
            self._waits.append(WaitSpan(policy, t0, t1, busy_s, free_s,
                                        completions))
            c = self.counters
            c["wait_spans"] += 1
            c["wait_busy_s"] += busy_s
            c["wait_free_s"] += free_s

    def _fold(self, dt: DescTrace) -> None:
        """Fold ``dt``'s newly-completed phases into the monotonic
        occupancy counters (each phase of each trace counts once; called
        from terminal marks, possibly from several threads)."""
        durs = dt.phase_durations()
        with self._lock:
            for phase, d in durs.items():
                if phase in dt._folded:
                    continue
                dt._folded.add(phase)
                self.phase_s[phase] += d
                self.phase_n[phase] += 1

    # ------------------------------------------------------------------ snapshots
    def traces(self) -> List[DescTrace]:
        with self._lock:
            return list(self._ring)

    def edges(self) -> List[Tuple[int, int, str]]:
        with self._lock:
            return list(self._edges)

    def wait_spans(self) -> List[WaitSpan]:
        with self._lock:
            return list(self._waits)

    def counters_snapshot(self) -> Dict[str, float]:
        """Monotonic counters incl. per-phase folded seconds/counts
        (delta-sampling safe, like ``StreamEngine.counters_snapshot``)."""
        with self._lock:
            snap = dict(self.counters)
            for p in PHASES:
                snap[f"phase.{p}_s"] = self.phase_s[p]
                snap[f"phase.{p}_n"] = float(self.phase_n[p])
            return snap


def make_tracer(spec: Union[None, bool, int, float, TraceConfig, Tracer]
                ) -> Optional[Tracer]:
    """Resolve a ``trace=`` spec: None/False -> off, True -> rate 1.0, a
    number -> sampling rate (validated: TraceRateError outside [0, 1]), a
    TraceConfig or prebuilt Tracer pass through."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, Tracer):
        return spec
    if isinstance(spec, TraceConfig):
        return Tracer(spec)
    if spec is True:
        return Tracer(TraceConfig(rate=1.0))
    if isinstance(spec, (int, float)):
        return Tracer(TraceConfig(rate=float(spec)))
    raise TypeError(f"trace= expects None, bool, a rate in [0, 1], a "
                    f"TraceConfig, or a Tracer; got {type(spec).__name__}")


# --------------------------------------------------------------------------- analyzers
def _as_traces(tracer_or_traces: Union[Tracer, Iterable[DescTrace]]
               ) -> List[DescTrace]:
    if isinstance(tracer_or_traces, Tracer):
        return tracer_or_traces.traces()
    return list(tracer_or_traces)


def phase_breakdown(tracer_or_traces: Union[Tracer, Iterable[DescTrace]]
                    ) -> Dict[str, Dict[str, float]]:
    """Aggregate per-phase stats across traces — the generalized Fig. 5:
    {phase: {count, total_s, mean_s, p95_s, share}} where ``share`` is the
    phase's fraction of summed span time."""
    traces = _as_traces(tracer_or_traces)
    per: Dict[str, List[float]] = {p: [] for p in PHASES}
    for dt in traces:
        for phase, d in dt.phase_durations().items():
            per[phase].append(d)
    grand = sum(sum(v) for v in per.values()) or 1.0
    out: Dict[str, Dict[str, float]] = {}
    for phase in PHASES:
        vals = per[phase]
        if not vals:
            continue
        total = sum(vals)
        out[phase] = {
            "count": float(len(vals)),
            "total_s": total,
            "mean_s": total / len(vals),
            "p95_s": percentile(vals, 95.0),
            "share": total / grand,
        }
    return out


def critical_path(tracer: Tracer) -> Dict[str, Any]:
    """Longest dependency chain through the retained traces.

    Nodes are traced descriptors; edges are the recorded ``after=``
    fences and ``then`` continuations.  A node only contributes the part
    of its span extent AFTER its chain predecessor's end — a ``then``
    continuation's host_wait runs concurrently with its parent's
    execution and must not double-count that wall time — so the chain's
    on-path total never exceeds its wall extent.  Edges point forward in
    time, so ordering nodes by start time is a valid topological order
    for the DP.  Returns the chain (desc ids), its on-path seconds, wall
    extent, per-phase seconds along the chain (clipped the same way),
    and each phase's share — where the end-to-end time actually went
    (the real Fig. 5, generalized across dependencies)."""
    traces = {dt.desc_id: dt for dt in tracer.traces() if dt.marks}
    parents: Dict[int, List[int]] = {d: [] for d in traces}
    for p, c, _kind in tracer.edges():
        if p in traces and c in traces:
            parents[c].append(p)
    order = sorted(traces, key=lambda d: traces[d].start)
    best: Dict[int, float] = {}
    pred: Dict[int, Optional[int]] = {}
    for d in order:
        dt = traces[d]
        b, pr = dt.duration_s, None
        for p in parents[d]:
            if p not in best:
                continue
            contrib = max(dt.end - max(dt.start, traces[p].end), 0.0)
            if best[p] + contrib > b:
                b, pr = best[p] + contrib, p
        best[d] = b
        pred[d] = pr
    if not best:
        return {"chain": [], "total_s": 0.0, "elapsed_s": 0.0,
                "phases": {}, "shares": {}}
    endpoint = max(best, key=lambda d: best[d])
    chain: List[int] = []
    at: Optional[int] = endpoint
    while at is not None:
        chain.append(at)
        at = pred[at]
    chain.reverse()
    phases: Dict[str, float] = {}
    for i, d in enumerate(chain):
        # clip to time after the predecessor's end (matches the DP weight)
        cut = traces[chain[i - 1]].end if i else float("-inf")
        for sp in traces[d].spans():
            clipped = max(sp.t1 - max(sp.t0, cut), 0.0)
            if clipped > 0:
                phases[sp.phase] = phases.get(sp.phase, 0.0) + clipped
    total = best[endpoint]
    elapsed = max(traces[chain[-1]].end - traces[chain[0]].start, 0.0)
    denom = sum(phases.values()) or 1.0
    shares = {p: v / denom for p, v in phases.items()}
    return {"chain": chain, "total_s": total, "elapsed_s": elapsed,
            "phases": phases, "shares": shares}


def host_free_fraction(tracer: Tracer) -> float:
    """Fraction of waited host time spent parked (free), from the
    tracer's wait spans.  Folded from the same local WaitStats each
    WaitPolicy.wait merges into ``device.wait_stats``, so this agrees
    with the Fig. 11 accounting by construction."""
    c = tracer.counters_snapshot()
    total = c["wait_busy_s"] + c["wait_free_s"]
    return c["wait_free_s"] / total if total > 0 else 0.0


def slowest(tracer_or_traces: Union[Tracer, Iterable[DescTrace]],
            k: int = 10) -> List[DescTrace]:
    """The k traces with the largest span extent, slowest first."""
    traces = [t for t in _as_traces(tracer_or_traces) if t.marks]
    return sorted(traces, key=lambda t: t.duration_s, reverse=True)[:k]
