"""Live observability over the streaming-engine fabric (paper §5).

``Telemetry`` (core/telemetry.py) answers "what happened" after a run;
this package answers "what is happening" while it runs — the pcm-accel
analogue.  A ``Sampler`` snapshots every engine / WQ / NUMA node / wait
policy at a fixed interval into bounded ring-buffer ``Series`` (delta
sampling over monotonic counters, O(engines) per tick) with CSV/JSONL
export and windowed percentile summaries; ``tools/pcm_repro.py`` renders
the live terminal view.  See docs/observability.md for the metric
glossary and lifecycle.
"""
from repro.obs.export import to_csv, to_jsonl
from repro.obs.sampler import Sampler
from repro.obs.series import Series, percentile

__all__ = ["Sampler", "Series", "percentile", "to_csv", "to_jsonl"]
