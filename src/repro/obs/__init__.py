"""Live observability over the streaming-engine fabric (paper §5).

``Telemetry`` (core/telemetry.py) answers "what happened" after a run;
this package answers "what is happening" while it runs — the pcm-accel
analogue.  A ``Sampler`` snapshots every engine / WQ / NUMA node / wait
policy at a fixed interval into bounded ring-buffer ``Series`` (delta
sampling over monotonic counters, O(engines) per tick) with CSV/JSONL
export and windowed percentile summaries; ``tools/pcm_repro.py`` renders
the live terminal view.  See docs/observability.md for the metric
glossary and lifecycle.

Descriptor-lifecycle tracing (docs/tracing.md) rides on the same package:
``make_device(trace=...)`` attaches a ``Tracer`` that records a span tree
per sampled descriptor (create -> validate -> submit -> wq_wait ->
engine_dispatch -> pe_exec -> completion_write -> host_wait -> callback),
dependency edges, and WaitPolicy wait spans; ``to_perfetto`` exports the
lot as Chrome/Perfetto trace_event JSON, and ``critical_path`` /
``phase_breakdown`` / ``host_free_fraction`` are the span analyzers
(``tools/trace_view.py`` is the CLI).
"""
from repro.obs.export import to_csv, to_jsonl, to_perfetto
from repro.obs.sampler import Sampler
from repro.obs.series import Series, percentile
from repro.obs.spans import HOST_PHASES, PHASES, DescTrace, Span
from repro.obs.trace import (
    TraceConfig,
    Tracer,
    TraceRateError,
    WaitSpan,
    critical_path,
    host_free_fraction,
    make_tracer,
    phase_breakdown,
    slowest,
)

__all__ = [
    "Sampler", "Series", "percentile",
    "to_csv", "to_jsonl", "to_perfetto",
    "PHASES", "HOST_PHASES", "DescTrace", "Span",
    "Tracer", "TraceConfig", "TraceRateError", "WaitSpan", "make_tracer",
    "critical_path", "phase_breakdown", "host_free_fraction", "slowest",
]
