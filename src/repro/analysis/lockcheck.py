"""Lockdep-style runtime race detector (opt-in; zero cost when disabled).

The ReorderArray reentrant-drain race fixed by hand in PR 7 is a whole bug
class: a completion callback fires while engine-adjacent state is locked,
re-enters the locked path, and commits against state the outer frame is
mid-way through mutating.  Linux lockdep showed that this class is
detectable at runtime from two invariants:

  1. **Acquisition order** — for every pair of lock CLASSES ever nested,
     the nesting order must be globally consistent.  The detector records
     an edge ``A -> B`` whenever a thread acquires a ``B`` lock while
     holding an ``A`` lock; a path ``B -> ... -> A`` already in the graph
     means two threads can deadlock (ABBA), flagged at the moment the
     second order is OBSERVED — no actual deadlock required.  Nesting two
     instances of the same class is flagged for the same reason.
  2. **No user code under a lock** — completion callbacks / listeners must
     never be invoked while an instrumented lock is held: the callback can
     re-enter the locked subsystem (the PR 7 drain race) or block on a
     wait that needs the lock to make progress (deadlock).  Dispatch
     points mark themselves with ``notify_region``; entering one with any
     instrumented lock held is a ``notify-under-lock`` violation.

Like lockdep, violations are recorded by lock CLASS (the ``lockclass``
string given at construction), deduplicated, and carry the acquisition
stacks, so one run over a representative workload certifies the ordering
discipline of the whole tree.

Wiring: the locks in ``StreamEngine`` (counters, PE pool),
``CompletionSet``, ``WorkQueue``, ``Device``, and the serving
``ReorderArray`` are created through :func:`checked_lock` /
:func:`checked_rlock`.  While the detector is disabled (the default) those
factories return plain ``threading`` locks — no wrapper, no overhead.
After :func:`enable` (e.g. ``pytest --lockcheck``, see tests/conftest.py)
newly created locks are instrumented and violations accumulate on the
global detector; the pytest session fails if any are recorded.

Tests that deliberately manufacture hazards should build a private
``LockCheck(enabled=True)`` instance so the global report stays clean.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import traceback
from typing import Dict, List, Optional, Set, Tuple, Union


@dataclasses.dataclass
class LockViolation:
    """One recorded hazard.  ``kind`` is "order-cycle" (ABBA / same-class
    nesting) or "notify-under-lock" (user-callback dispatch while holding
    an instrumented lock)."""

    kind: str
    detail: str
    stack: str = ""

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail}"


class CheckedLock:
    """Instrumented lock: a plain ``threading`` lock plus acquisition
    bookkeeping on its owning :class:`LockCheck`.  Supports the standard
    ``acquire``/``release``/context-manager protocol."""

    __slots__ = ("lockclass", "reentrant", "_lock", "_check")

    def __init__(self, check: "LockCheck", lockclass: str, reentrant: bool):
        self.lockclass = lockclass
        self.reentrant = reentrant
        self._lock = threading.RLock() if reentrant else threading.Lock()
        self._check = check

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            self._check._on_acquire(self)
        return ok

    def release(self) -> None:
        self._check._on_release(self)
        self._lock.release()

    def _is_owned(self) -> bool:
        """RLock duck-compat: does the calling thread hold this lock?"""
        return self._lock._is_owned()  # type: ignore[union-attr]

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "CheckedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<CheckedLock {self.lockclass!r} reentrant={self.reentrant}>"


class LockCheck:
    """One detector: an acquisition-order graph over lock classes, per-
    thread held stacks, and a deduplicated violation list."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        # lockclass -> set of lockclasses acquired while it was held
        self._edges: Dict[str, Set[str]] = {}
        self._violations: List[LockViolation] = []
        self._seen_keys: Set[Tuple[str, str]] = set()
        self._mu = threading.Lock()  # guards edges/violations (plain: internal)
        self._tls = threading.local()

    # ------------------------------------------------------------------ factories
    def lock(self, lockclass: str) -> Union[CheckedLock, threading.Lock]:
        """A mutex of class ``lockclass`` — instrumented iff enabled NOW."""
        if not self.enabled:
            return threading.Lock()
        return CheckedLock(self, lockclass, reentrant=False)

    def rlock(self, lockclass: str) -> Union[CheckedLock, threading.RLock]:
        """A reentrant mutex of class ``lockclass`` (reentrant re-acquires
        are tracked but never edge-recorded)."""
        if not self.enabled:
            return threading.RLock()
        return CheckedLock(self, lockclass, reentrant=True)

    # ------------------------------------------------------------------ tracking
    def _stack(self) -> List[List]:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s  # entries: [CheckedLock, hold_count]

    def _on_acquire(self, lock: CheckedLock) -> None:
        if not self.enabled:
            return
        stack = self._stack()
        for ent in stack:
            if ent[0] is lock:  # reentrant re-acquire of the same instance
                ent[1] += 1
                return
        held = [ent[0].lockclass for ent in stack]
        if held:
            with self._mu:
                for hc in dict.fromkeys(held):  # unique, order-preserving
                    if hc == lock.lockclass:
                        self._violate(
                            "order-cycle",
                            f"same-class nesting: a {lock.lockclass!r} lock "
                            f"acquired while another {hc!r} instance is held "
                            f"(ABBA hazard between instances)",
                            key=(hc, lock.lockclass),
                        )
                        continue
                    self._edges.setdefault(hc, set()).add(lock.lockclass)
                    if self._reaches(lock.lockclass, hc):
                        self._violate(
                            "order-cycle",
                            f"lock order inversion: acquiring "
                            f"{lock.lockclass!r} while holding {hc!r}, but "
                            f"the graph already orders {lock.lockclass!r} "
                            f"before {hc!r} (ABBA deadlock possible)",
                            key=(hc, lock.lockclass),
                        )
        stack.append([lock, 1])

    def _on_release(self, lock: CheckedLock) -> None:
        stack = getattr(self._tls, "stack", None)
        if not stack:
            return  # acquired before instrumentation/enable: nothing tracked
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] is lock:
                stack[i][1] -= 1
                if stack[i][1] == 0:
                    del stack[i]
                return

    def _reaches(self, src: str, dst: str) -> bool:
        """DFS: is there a recorded path src -> ... -> dst?"""
        seen: Set[str] = set()
        frontier = [src]
        while frontier:
            n = frontier.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            frontier.extend(self._edges.get(n, ()))
        return False

    def _violate(self, kind: str, detail: str,
                 key: Optional[Tuple[str, str]] = None) -> None:
        k = (kind, key if key is not None else detail)
        if k in self._seen_keys:
            return
        self._seen_keys.add(k)
        stack = "".join(traceback.format_stack(limit=12)[:-2])
        self._violations.append(LockViolation(kind, detail, stack))

    # ------------------------------------------------------------------ notify regions
    def held(self) -> List[str]:
        """Lock classes held by the calling thread, outermost first."""
        return [ent[0].lockclass for ent in getattr(self._tls, "stack", ())]

    @contextlib.contextmanager
    def notify_region(self, label: str):
        """Mark a dispatch point that runs USER code (completion callbacks,
        listeners).  Entering it with an instrumented lock held is the PR 7
        reentrant-drain hazard: the callback can re-enter the locked
        subsystem or block on work that needs the lock."""
        if self.enabled:
            held = self.held()
            if held:
                with self._mu:
                    self._violate(
                        "notify-under-lock",
                        f"{label}: user callbacks dispatched while holding "
                        f"{held} — a callback re-entering the locked "
                        f"subsystem deadlocks or double-commits",
                        key=(label, ",".join(held)),
                    )
        yield

    # ------------------------------------------------------------------ reporting
    @property
    def violations(self) -> List[LockViolation]:
        with self._mu:
            return list(self._violations)

    def clear(self) -> None:
        with self._mu:
            self._violations.clear()
            self._seen_keys.clear()
            self._edges.clear()

    def edges(self) -> Dict[str, Set[str]]:
        with self._mu:
            return {k: set(v) for k, v in self._edges.items()}

    def report(self) -> str:
        vs = self.violations
        if not vs:
            return "lockcheck: clean (no ordering or notify hazards recorded)"
        lines = [f"lockcheck: {len(vs)} violation(s)"]
        for v in vs:
            lines.append(f"  {v}")
            if v.stack:
                lines.append("    recorded at:")
                lines.extend("    " + ln for ln in v.stack.rstrip().splitlines())
        return "\n".join(lines)


#: The process-global detector the core locks register with.  Disabled by
#: default: ``checked_lock``/``checked_rlock`` then return PLAIN threading
#: locks, so production paths carry no wrapper at all.  ``enable()`` must
#: run before the objects whose locks should be watched are constructed
#: (pytest --lockcheck enables it in pytest_configure, before collection
#: imports anything from repro).
GLOBAL = LockCheck(enabled=False)


def enable() -> None:
    GLOBAL.enabled = True


def disable() -> None:
    GLOBAL.enabled = False


def enabled() -> bool:
    return GLOBAL.enabled


def checked_lock(lockclass: str):
    """A mutex for core subsystems: plain when the global detector is off,
    instrumented (class-tagged) when it is on."""
    return GLOBAL.lock(lockclass)


def checked_rlock(lockclass: str):
    return GLOBAL.rlock(lockclass)


def notify_region(label: str):
    """Context manager marking a user-callback dispatch point (see
    :meth:`LockCheck.notify_region`).  Cheap no-op when disabled."""
    return GLOBAL.notify_region(label)


def violations() -> List[LockViolation]:
    return GLOBAL.violations


def report() -> str:
    return GLOBAL.report()
