"""AST lint for Future/Device API misuse (DSA1xx codes).

The asynchronous submission API has four misuse patterns that type-check
fine, run fine in the small, and rot a real deployment:

  DSA101  dropped-future        the result of ``submit`` / an ``*_async``
                                helper is discarded as a bare statement.
                                The completion record leaks (nothing will
                                ever ``pop_completed`` it) and errors are
                                silently lost.
  DSA102  blocking-in-callback  ``result()`` / ``wait()`` / ``wait_all()``
                                etc. inside a ``then`` / ``add_done_callback``
                                / ``add_listener`` body.  Callbacks run on
                                the completion path — blocking there stalls
                                (or deadlocks) the engine that must make
                                the awaited work complete.  ``timeout=0``
                                polls are exempt.
  DSA103  raw-kick-loop         a ``while`` loop that drives progress by
                                calling ``.kick()`` directly instead of a
                                ``WaitPolicy`` — busy-spins the host CPU
                                the offload was supposed to free (paper
                                §3.3/Fig. 5).  The WaitPolicy internals
                                themselves carry suppressions.
  DSA104  swallowed-queuefull   a submit call inside ``try`` whose bare /
                                ``Exception`` handler neither re-raises nor
                                names ``QueueFull`` — overload becomes
                                silent data loss instead of backpressure.
  DSA105  trace-rate            a literal ``trace=`` / ``rate=`` sampling
                                rate outside [0, 1] at a ``make_device`` /
                                ``Device`` / ``TraceConfig`` call site.
                                The runtime rejects it too (the typed
                                ``TraceRateError``), but the lint catches
                                it before anything runs.
  DSA106  unbatched-submit-loop a ``for`` loop submitting one descriptor
                                per iteration — every iteration pays a full
                                doorbell (and on shared WQs the ENQCMD
                                round trip) that ``submit_many`` / a
                                ``submit_ring`` would amortize across the
                                burst (paper Fig. 3 / G1).  Conditional
                                submits (under ``if``/``try``), retry loops
                                (containing ``break``), and the batch entry
                                points themselves are exempt.

Suppression: append ``# dsalint: disable`` (all rules) or
``# dsalint: disable=DSA103`` / ``=DSA101,DSA104`` to the offending line.

Entry points: :func:`lint_source`, :func:`lint_file`, :func:`lint_paths`;
CLI wrapper in ``tools/dsalint.py``.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Union

#: rule code -> one-line description (the docs/analysis.md catalogue)
RULES: Dict[str, str] = {
    "DSA101": "dropped-future: submit result discarded, completion record "
              "leaks",
    "DSA102": "blocking-in-callback: result()/wait() inside a completion "
              "callback body",
    "DSA103": "raw-kick-loop: while-loop driving progress via .kick() "
              "instead of a WaitPolicy",
    "DSA104": "swallowed-queuefull: submit inside a bare/Exception handler "
              "that neither re-raises nor handles QueueFull",
    "DSA105": "trace-rate: literal trace=/rate= sampling rate outside "
              "[0, 1] at a make_device/Device/TraceConfig call site",
    "DSA106": "unbatched-submit-loop: per-descriptor submit in a loop — "
              "batch via submit_many/submit_ring to amortize the doorbell",
}

#: callee name -> keyword carrying a sampling rate in [0, 1] (DSA105)
TRACE_RATE_KWARGS: Dict[str, str] = {
    "make_device": "trace",
    "Device": "trace",
    "TraceConfig": "rate",
}

#: Device/engine methods whose return value is a Future (or a completion
#: handle) that must not be dropped.
SUBMIT_METHODS: Set[str] = {
    "submit", "submit_many",
    "memcpy_async", "dualcast_async", "fill_async", "compare_async",
    "compare_pattern_async", "crc32_async", "delta_create_async",
    "delta_apply_async", "dif_insert_async", "dif_check_async",
    "dif_strip_async", "batch_copy_async", "batch_async",
    "cache_flush_async", "copy_crc_async", "fill_verify_async",
}

#: batched submit entry points — one doorbell per burst, so calling them in
#: a loop is already amortized (exempt from DSA106).
BATCH_SUBMIT_METHODS: Set[str] = {
    "submit_many", "batch_async", "batch_copy_async",
}

#: Calls that block on completion (illegal inside callback bodies).
BLOCKING_METHODS: Set[str] = {
    "result", "wait", "wait_all", "wait_any", "as_completed", "drain",
}

#: Methods whose callable arguments are completion callbacks.
CALLBACK_REGISTRARS: Set[str] = {
    "then", "add_done_callback", "done_callback", "add_listener",
    "on_done",
}

_SUPPRESS_RE = re.compile(
    r"#\s*dsalint:\s*disable(?:\s*=\s*(?P<codes>[A-Z0-9,\s]+))?")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    col: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


def _suppressions(source: str) -> Dict[int, Optional[Set[str]]]:
    """line number -> None (suppress all) or the set of suppressed codes."""
    out: Dict[int, Optional[Set[str]]] = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        codes = m.group("codes")
        if codes is None:
            out[lineno] = None
        else:
            out[lineno] = {c.strip() for c in codes.split(",") if c.strip()}
    return out


def _call_attr(node: ast.AST) -> Optional[str]:
    """The attribute name if ``node`` is a ``x.attr(...)`` call."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _is_zero_timeout(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "timeout" and isinstance(kw.value, ast.Constant):
            if kw.value.value == 0:
                return True
    return False


def _callee_name(call: ast.Call) -> Optional[str]:
    """Bare or dotted callee name: ``make_device(...)`` / ``m.Device(...)``."""
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


def _const_number(node: ast.AST) -> Optional[float]:
    """The numeric value of a literal, seeing through unary +/- (a negative
    literal like ``-0.5`` parses as UnaryOp(USub, Constant), not Constant).
    Bools are excluded — ``trace=True`` means rate 1.0 and is always legal."""
    sign = 1.0
    while isinstance(node, ast.UnaryOp) and isinstance(
            node.op, (ast.UAdd, ast.USub)):
        if isinstance(node.op, ast.USub):
            sign = -sign
        node = node.operand
    if (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and not isinstance(node.value, bool)):
        return sign * float(node.value)
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.violations: List[Violation] = []
        self._suppress = _suppressions(source)
        # bodies of named functions registered as callbacks, found lazily
        self._local_funcs: Dict[str, ast.AST] = {}
        self._callback_checked: Set[int] = set()  # id() of visited bodies

    # ------------------------------------------------------------------ plumbing
    def _emit(self, node: ast.AST, code: str, message: str) -> None:
        line = getattr(node, "lineno", 0)
        sup = self._suppress.get(line)
        if sup is not None or line in self._suppress:
            if sup is None or code in sup:
                return
        self.violations.append(
            Violation(self.path, line, getattr(node, "col_offset", 0),
                      code, message))

    # ------------------------------------------------------------------ collection
    def visit_Module(self, node: ast.Module) -> None:
        for child in ast.walk(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._local_funcs[child.name] = child
        self.generic_visit(node)

    # ------------------------------------------------------------------ DSA101
    def visit_Expr(self, node: ast.Expr) -> None:
        attr = _call_attr(node.value)
        if attr in SUBMIT_METHODS:
            self._emit(node, "DSA101",
                       f"result of '{attr}(...)' discarded — the Future (and "
                       f"its completion record) leaks; bind it or wait on it")
        self.generic_visit(node)

    # ------------------------------------------------------------------ DSA102 / DSA105
    def visit_Call(self, node: ast.Call) -> None:
        attr = _call_attr(node)
        if attr in CALLBACK_REGISTRARS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                self._check_callback_body(arg)
        self._check_trace_rate(node)
        self.generic_visit(node)

    def _check_trace_rate(self, node: ast.Call) -> None:
        callee = _callee_name(node)
        kwarg = TRACE_RATE_KWARGS.get(callee or "")
        if kwarg is None:
            return
        for kw in node.keywords:
            if kw.arg != kwarg:
                continue
            value = _const_number(kw.value)
            if value is not None and not (0.0 <= value <= 1.0):
                self._emit(kw.value, "DSA105",
                           f"sampling rate {kwarg}={value:g} passed to "
                           f"'{callee}' is outside [0, 1] — the runtime "
                           f"raises TraceRateError; use a fraction of "
                           f"descriptors to sample")

    def _check_callback_body(self, arg: ast.AST) -> None:
        body: Optional[ast.AST] = None
        if isinstance(arg, ast.Lambda):
            body = arg.body
        elif isinstance(arg, ast.Name) and arg.id in self._local_funcs:
            body = self._local_funcs[arg.id]
        if body is None or id(body) in self._callback_checked:
            return
        self._callback_checked.add(id(body))
        for child in ast.walk(body):
            attr = _call_attr(child)
            if attr in BLOCKING_METHODS and not _is_zero_timeout(child):
                self._emit(child, "DSA102",
                           f"blocking '{attr}()' inside a completion "
                           f"callback — callbacks run on the completion "
                           f"path; use then()-chaining or timeout=0 polls")

    # ------------------------------------------------------------------ DSA103
    def visit_While(self, node: ast.While) -> None:
        for child in ast.walk(node):
            if _call_attr(child) == "kick":
                self._emit(node, "DSA103",
                           "while-loop drives progress via raw '.kick()' — "
                           "busy-spins the host; use a WaitPolicy "
                           "(wait/wait_all) instead")
                break
        self.generic_visit(node)

    # ------------------------------------------------------------------ DSA106
    #: subtrees skipped when hunting per-descriptor submits: conditional
    #: paths (if/try), nested scopes, and inner loops (which get their own
    #: visit_For pass and verdict)
    _DSA106_PRUNE = (ast.If, ast.IfExp, ast.Try, ast.For, ast.AsyncFor,
                     ast.While, ast.FunctionDef, ast.AsyncFunctionDef,
                     ast.Lambda)

    def visit_For(self, node: ast.For) -> None:
        self._check_submit_loop(node)
        self.generic_visit(node)

    visit_AsyncFor = visit_For

    def _check_submit_loop(self, node: ast.For) -> None:
        # a loop that can break or return out is a retry/backoff wrapper
        # around one logical submit, not a homogeneous fan-out — exempt
        own_exit = self._walk_pruned(
            node.body, (ast.For, ast.AsyncFor, ast.While,
                        ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
        if any(isinstance(n, (ast.Break, ast.Return)) for n in own_exit):
            return
        for child in self._walk_pruned(node.body, self._DSA106_PRUNE):
            attr = _call_attr(child)
            if attr in SUBMIT_METHODS and attr not in BATCH_SUBMIT_METHODS:
                self._emit(child, "DSA106",
                           f"per-descriptor '{attr}(...)' inside a loop — "
                           f"every iteration pays a full doorbell; batch "
                           f"the burst via submit_many()/submit_ring() "
                           f"(or batch_async) to amortize it")

    @staticmethod
    def _walk_pruned(stmts: Sequence[ast.AST], prune) -> Iterable[ast.AST]:
        """Walk statement subtrees, skipping pruned-type nodes entirely —
        whether they appear as direct body statements or deeper down."""
        stack = list(stmts)
        while stack:
            n = stack.pop()
            if isinstance(n, prune):
                continue
            yield n
            stack.extend(ast.iter_child_nodes(n))

    # ------------------------------------------------------------------ DSA104
    def visit_Try(self, node: ast.Try) -> None:
        has_submit = any(
            _call_attr(child) in SUBMIT_METHODS
            for stmt in node.body for child in ast.walk(stmt))
        if has_submit:
            for handler in node.handlers:
                if not self._catches_broadly(handler):
                    continue
                if self._handler_reraises_or_names_queuefull(handler):
                    continue
                self._emit(handler, "DSA104",
                           "submit wrapped in a bare/broad except that "
                           "neither re-raises nor handles QueueFull — "
                           "overload becomes silent loss")
        self.generic_visit(node)

    @staticmethod
    def _catches_broadly(handler: ast.ExceptHandler) -> bool:
        t = handler.type
        if t is None:
            return True
        names: List[str] = []
        for n in [t] + (list(t.elts) if isinstance(t, ast.Tuple) else []):
            if isinstance(n, ast.Name):
                names.append(n.id)
            elif isinstance(n, ast.Attribute):
                names.append(n.attr)
        return any(n in ("Exception", "BaseException") for n in names)

    @staticmethod
    def _handler_reraises_or_names_queuefull(
            handler: ast.ExceptHandler) -> bool:
        for child in ast.walk(handler):
            if isinstance(child, ast.Raise):
                return True
            if isinstance(child, ast.Name) and child.id == "QueueFull":
                return True
            if isinstance(child, ast.Attribute) and child.attr == "QueueFull":
                return True
        return False


# --------------------------------------------------------------------------- entry points
def lint_source(source: str, path: str = "<string>",
                select: Optional[Iterable[str]] = None) -> List[Violation]:
    """Lint one source string; returns violations sorted by position."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Violation(path, exc.lineno or 0, exc.offset or 0, "DSA100",
                          f"syntax error: {exc.msg}")]
    linter = _Linter(path, source)
    linter.visit(tree)
    out = sorted(linter.violations, key=lambda v: (v.line, v.col, v.code))
    if select is not None:
        wanted = set(select)
        out = [v for v in out if v.code in wanted]
    return out


def lint_file(path: Union[str, pathlib.Path],
              select: Optional[Iterable[str]] = None) -> List[Violation]:
    p = pathlib.Path(path)
    return lint_source(p.read_text(encoding="utf-8"), str(p), select=select)


def lint_paths(paths: Sequence[Union[str, pathlib.Path]],
               select: Optional[Iterable[str]] = None) -> List[Violation]:
    """Lint files and/or directory trees (``*.py``, skipping __pycache__)."""
    files: List[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py") if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            files.append(p)
    out: List[Violation] = []
    for f in files:
        out.extend(lint_file(f, select=select))
    return out
