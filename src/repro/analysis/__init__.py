"""Static + runtime analysis for the descriptor/Future programming model.

Three checkers, one theme: once offload is asynchronous (and, with
descriptor chaining, host-invisible), correctness must be established
BEFORE submission, not observed after a late engine failure.

  desclint   descriptor validity (paper §3.2: the 64-byte contract) —
             op-specific operand checks enforced at ``Device.submit`` via
             ``make_device(validate="strict"|"warn"|"off")``; typed
             ``DescriptorError`` taxonomy (DESC1xx codes).
  apilint    AST lint over source trees for Future/Device API misuse
             (DSA1xx codes): dropped futures, blocking waits inside
             completion callbacks, raw ``kick()`` busy-loops, swallowed
             ``QueueFull``.  CLI: ``tools/dsalint.py``.
  lockcheck  opt-in lockdep-style runtime detector: lock-acquisition-order
             graph over the engine/completion/serving locks, cycle and
             held-lock-while-notifying hazards.  Enabled under pytest with
             ``--lockcheck``.

Import discipline: ``repro.core`` modules import
``repro.analysis.lockcheck`` at module-import time and ``desclint``
imports ``repro.core.descriptor`` — this package ``__init__`` therefore
stays LAZY (no eager submodule imports) to keep the graph acyclic.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("apilint", "desclint", "lockcheck")

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
