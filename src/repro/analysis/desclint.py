"""Descriptor validity checking (paper §3.2: the 64-byte contract).

A DSA work descriptor is a fixed-layout record; a malformed one (missing
operand, wrong flags, bad transfer size) fails LATE — inside the engine,
as an opaque completion error — and once descriptor chaining takes the CPU
out of the datapath such failures become host-invisible.  ``desclint``
enforces each op's operand contract at submit time instead:

  DESC101  missing-operand       required operand absent (FILL without a
                                 pattern / n_words, DELTA without src2,
                                 BATCH_COPY without dst_pool/indices, ...)
  DESC102  operand-mismatch      operands disagree (COMPARE shape/dtype,
                                 DELTA ref vs src, DIF word dtype/framing,
                                 BATCH_COPY row shape vs dst_pool, bad cap)
  DESC103  index-shape           index operands malformed (BATCH_COPY
                                 src_idx/dst_idx shape disagreement or not
                                 1-D, DELTA_APPLY offsets vs data length)
  DESC104  locality              src_node/dst_node hints outside the
                                 device topology, or conflicting with the
                                 buffer-locality registry's registered home
  DESC105  batch-inhomogeneous   (warn) a near-fusable F2 copy batch whose
                                 members disagree on flags/shape — legal,
                                 but silently falls back to per-descriptor
                                 execution, losing the batch amortization
  DESC106  degenerate-size       (warn) descriptor moves zero bytes (empty
                                 BATCH_COPY, operand without dtype/size)

Wiring: ``make_device(validate="strict"|"warn"|"off")``.  strict raises
the typed :class:`DescriptorError` taxonomy below from ``Device.submit``;
warn bumps the device's ``desclint_warnings`` counter (surfaced as the
``device.desclint_warnings`` series by the ``repro.obs`` sampler); off
skips the checks entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.descriptor import BatchDescriptor, OpType, WorkDescriptor


# --------------------------------------------------------------------------- taxonomy
class DescriptorError(ValueError):
    """Base of the typed malformed-descriptor taxonomy (strict mode).
    Carries the rule ``code`` and the full diagnostic list so callers can
    branch on the failure family without parsing messages."""

    code = "DESC100"

    def __init__(self, message: str,
                 diagnostics: Optional[Sequence["Diagnostic"]] = None,
                 desc: Any = None):
        super().__init__(message)
        self.diagnostics = list(diagnostics or ())
        self.desc = desc


class MissingOperandError(DescriptorError):
    code = "DESC101"


class OperandMismatchError(DescriptorError):
    code = "DESC102"


class IndexShapeError(DescriptorError):
    code = "DESC103"


class LocalityError(DescriptorError):
    code = "DESC104"


ERROR_TYPES: Dict[str, Type[DescriptorError]] = {
    cls.code: cls
    for cls in (DescriptorError, MissingOperandError, OperandMismatchError,
                IndexShapeError, LocalityError)
}

#: rule code -> one-line description (the docs/analysis.md catalogue)
RULES: Dict[str, str] = {
    "DESC100": "generic malformed descriptor",
    "DESC101": "missing-operand: a required operand is absent",
    "DESC102": "operand-mismatch: operand shapes/dtypes/values disagree",
    "DESC103": "index-shape: index operands malformed or inconsistent",
    "DESC104": "locality: node hints outside the topology or conflicting "
               "with the buffer-locality registry",
    "DESC105": "batch-inhomogeneous (warn): near-fusable F2 batch falls "
               "back to per-descriptor execution",
    "DESC106": "degenerate-size (warn): descriptor moves zero bytes",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a rule code, error|warn severity, and the message."""

    code: str
    severity: str  # "error" | "warn"
    message: str

    def __str__(self) -> str:
        return f"{self.code} [{self.severity}] {self.message}"


def _err(code: str, msg: str) -> Diagnostic:
    return Diagnostic(code, "error", msg)


def _warn(code: str, msg: str) -> Diagnostic:
    return Diagnostic(code, "warn", msg)


# --------------------------------------------------------------------------- helpers
def _shape(x: Any) -> Optional[Tuple[int, ...]]:
    s = getattr(x, "shape", None)
    return tuple(s) if s is not None else None


def _dtype(x: Any):
    dt = getattr(x, "dtype", None)
    if dt is None:
        return None
    try:
        return np.dtype(dt)
    except TypeError:
        return None


def _is_arrayish(x: Any) -> bool:
    return _shape(x) is not None and _dtype(x) is not None


def _size(x: Any) -> Optional[int]:
    n = getattr(x, "size", None)
    try:
        return int(n) if n is not None else None
    except TypeError:
        return None


def _require(d: WorkDescriptor, field: str, what: str,
             out: List[Diagnostic]) -> Any:
    v = getattr(d, field, None)
    if v is None:
        out.append(_err("DESC101",
                        f"{d.op.value}: required operand {field!r} ({what}) "
                        f"is missing"))
    return v


def _require_array(d: WorkDescriptor, field: str, what: str,
                   out: List[Diagnostic]) -> Any:
    v = _require(d, field, what, out)
    if v is not None and not _is_arrayish(v):
        out.append(_err("DESC102",
                        f"{d.op.value}: operand {field!r} ({what}) is not "
                        f"array-like (no shape/dtype: "
                        f"{type(v).__name__})"))
        return None
    return v


def _agree(d: WorkDescriptor, a: Any, b: Any, a_name: str, b_name: str,
           out: List[Diagnostic]) -> None:
    """Shape AND dtype agreement between two operands."""
    if a is None or b is None:
        return
    sa, sb = _shape(a), _shape(b)
    if sa != sb:
        out.append(_err("DESC102",
                        f"{d.op.value}: {a_name} shape {sa} != {b_name} "
                        f"shape {sb}"))
    da, db = _dtype(a), _dtype(b)
    if da is not None and db is not None and da != db:
        out.append(_err("DESC102",
                        f"{d.op.value}: {a_name} dtype {da} != {b_name} "
                        f"dtype {db}"))


def _word_dtype_ok(x: Any) -> bool:
    """DIF/fill word streams are 4-byte integer words (the kernels reshape
    and CRC them as u32 grids)."""
    dt = _dtype(x)
    return dt is not None and dt.kind in "iu" and dt.itemsize == 4


# --------------------------------------------------------------------------- per-op checks
def _check_fill(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    if d.pattern is None:
        out.append(_err("DESC101",
                        f"{d.op.value}: required operand 'pattern' is "
                        f"missing"))
    n = getattr(d, "n_words", None)
    if not isinstance(n, (int, np.integer)) or n < 1:
        out.append(_err("DESC101",
                        f"{d.op.value}: 'n_words' must be a positive int "
                        f"(transfer size), got {n!r}"))


def _check_compare(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    a = _require_array(d, "src", "left operand", out)
    b = _require_array(d, "src2", "right operand", out)
    _agree(d, a, b, "src", "src2", out)


def _check_compare_pattern(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    _require_array(d, "src", "buffer", out)
    if d.pattern is None:
        out.append(_err("DESC101",
                        "compare_pattern: required operand 'pattern' is "
                        "missing"))


def _check_delta_create(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    src = _require_array(d, "src", "new data", out)
    ref = _require_array(d, "src2", "reference", out)
    _agree(d, src, ref, "src", "src2 (reference)", out)
    cap = getattr(d, "cap", None)
    if not isinstance(cap, (int, np.integer)) or cap < 1:
        out.append(_err("DESC102",
                        f"delta_create: 'cap' (delta record capacity) must "
                        f"be >= 1, got {cap!r}"))


def _check_delta_apply(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    _require_array(d, "src", "reference", out)
    offsets = _require_array(d, "src_idx", "delta offsets", out)
    data = _require_array(d, "src2", "delta data", out)
    if offsets is not None and data is not None:
        so, sd = _shape(offsets), _shape(data)
        if so and sd and so[0] != sd[0]:
            out.append(_err("DESC103",
                            f"delta_apply: offsets length {so[0]} != data "
                            f"length {sd[0]}"))


def _check_dif(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    src = _require_array(d, "src", "word stream", out)
    if src is None:
        return
    if not _word_dtype_ok(src):
        out.append(_err("DESC102",
                        f"{d.op.value}: DIF operates on 4-byte integer "
                        f"words, got dtype {_dtype(src)}"))
    s = _shape(src)
    if d.op == OpType.DIF_INSERT:
        if s is not None and len(s) != 1:
            out.append(_err("DESC102",
                            f"dif_insert: expects a flat word stream "
                            f"[n_words], got shape {s}"))
    else:  # DIF_CHECK / DIF_STRIP consume framed [n_blocks, words+2] grids
        if s is not None and (len(s) != 2 or s[1] < 3):
            out.append(_err("DESC102",
                            f"{d.op.value}: expects framed blocks "
                            f"[n_blocks, block_words+2], got shape {s}"))


def _check_batch_copy(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    src = _require_array(d, "src", "source pool", out)
    dst = _require_array(d, "dst_pool", "destination pool", out)
    sidx = _require_array(d, "src_idx", "source page indices", out)
    didx = _require_array(d, "dst_idx", "destination page indices", out)
    si, di = _shape(sidx), _shape(didx)
    if si is not None and len(si) != 1:
        out.append(_err("DESC103",
                        f"batch_copy: src_idx must be 1-D, got shape {si}"))
    if di is not None and len(di) != 1:
        out.append(_err("DESC103",
                        f"batch_copy: dst_idx must be 1-D, got shape {di}"))
    if si is not None and di is not None and si != di:
        out.append(_err("DESC103",
                        f"batch_copy: src_idx shape {si} != dst_idx shape "
                        f"{di} (one destination page per source page)"))
    ss, ds = _shape(src), _shape(dst)
    if ss is not None and ds is not None and ss[1:] != ds[1:]:
        out.append(_err("DESC102",
                        f"batch_copy: per-page shape disagreement: src rows "
                        f"{ss[1:]} vs dst_pool rows {ds[1:]}"))
    if ss is not None and len(ss) and ss[0] == 0:
        out.append(_warn("DESC106",
                         "batch_copy: empty source pool (shape[0] == 0) — "
                         "descriptor moves zero bytes"))
    elif si == (0,):
        out.append(_warn("DESC106",
                         "batch_copy: empty index set — descriptor moves "
                         "zero bytes"))


def _check_src_only(d: WorkDescriptor, out: List[Diagnostic]) -> None:
    src = _require_array(d, "src", "source buffer", out)
    if src is not None and _size(src) == 0:
        out.append(_warn("DESC106",
                         f"{d.op.value}: source buffer is empty — "
                         f"descriptor moves zero bytes"))


_OP_CHECKS = {
    OpType.MEMCPY: _check_src_only,
    OpType.DUALCAST: _check_src_only,
    OpType.CRC32: _check_src_only,
    OpType.FILL: _check_fill,
    OpType.COMPARE: _check_compare,
    OpType.COMPARE_PATTERN: _check_compare_pattern,
    OpType.DELTA_CREATE: _check_delta_create,
    OpType.DELTA_APPLY: _check_delta_apply,
    OpType.DIF_INSERT: _check_dif,
    OpType.DIF_CHECK: _check_dif,
    OpType.DIF_STRIP: _check_dif,
    OpType.BATCH_COPY: _check_batch_copy,
    OpType.CACHE_FLUSH: lambda d, out: None,  # modeled only, no operands
    # fused pairs share the operand contracts of their unfused halves:
    # copy_crc reads one source buffer (memcpy + crc32), fill_verify takes
    # the fill contract (pattern + n_words) and emits the verify record
    OpType.COPY_CRC: _check_src_only,
    OpType.FILL_VERIFY: _check_fill,
}


# --------------------------------------------------------------------------- locality
def _check_locality(d: Any, device: Any, out: List[Diagnostic]) -> None:
    """Node hints must fall inside the device topology, and an explicit
    hint must not contradict the registry's registered home — the engine
    charges links from these stamps, so a wrong one silently mis-bills
    (or mis-places, under numa_local) every byte."""
    topo = getattr(device, "topology", None)
    n_nodes = getattr(topo, "n_nodes", None)
    for field in ("src_node", "dst_node"):
        node = getattr(d, field, None)
        if node is None:
            continue
        if n_nodes is not None and not 0 <= node < n_nodes:
            out.append(_err("DESC104",
                            f"{field}={node} outside the {n_nodes}-node "
                            f"topology"))
    home = getattr(device, "home", None)
    if home is None or not isinstance(d, WorkDescriptor):
        return
    for field, operand in (("src_node", d.src), ("dst_node", d.dst_pool)):
        node = getattr(d, field, None)
        if node is None or operand is None:
            continue
        registered = home(operand)
        if registered is not None and registered != node:
            out.append(_err("DESC104",
                            f"{field}={node} contradicts the locality "
                            f"registry (operand registered on node "
                            f"{registered})"))


# --------------------------------------------------------------------------- batches
def _check_batch(b: BatchDescriptor, device: Any,
                 out: List[Diagnostic]) -> None:
    members = list(b.descriptors)
    if not members:
        out.append(_warn("DESC106", "batch: no member descriptors — the "
                                    "submission moves zero bytes"))
        return
    for i, d in enumerate(members):
        for diag in check_descriptor(d, device=device):
            out.append(Diagnostic(diag.code, diag.severity,
                                  f"batch[{i}]: {diag.message}"))
    # F2 homogeneity: an all-MEMCPY batch is the fusable family — if flags
    # or shapes disagree the engine silently falls back to per-descriptor
    # execution (one launch per member), losing the amortization the batch
    # was presumably built for (paper Fig. 3 / G1).
    if len(members) > 1 and all(d.op == OpType.MEMCPY for d in members):
        hints = {d.cache_hint for d in members}
        shapes = {(_shape(d.src), str(_dtype(d.src))) for d in members}
        pools = any(d.dst_pool is not None for d in members)
        reasons = []
        if len(hints) > 1:
            reasons.append("mixed cache hints")
        if len(shapes) > 1:
            reasons.append("mixed member shapes/dtypes")
        if pools:
            reasons.append("explicit dst_pool on a member")
        if reasons:
            out.append(_warn("DESC105",
                             f"near-fusable copy batch falls back to "
                             f"per-descriptor execution "
                             f"({'; '.join(reasons)})"))


# --------------------------------------------------------------------------- entry points
def check_descriptor(d: WorkDescriptor,
                     device: Any = None) -> List[Diagnostic]:
    """Validate one WorkDescriptor; returns diagnostics (possibly empty).
    Never raises and never forces device arrays — safe on the submit path
    in warn mode."""
    out: List[Diagnostic] = []
    op = getattr(d, "op", None)
    checker = _OP_CHECKS.get(op)
    if checker is None:
        out.append(_err("DESC100", f"unknown op {op!r}"))
        return out
    checker(d, out)
    if device is not None:
        _check_locality(d, device, out)
    return out


def check(desc: Any, device: Any = None) -> List[Diagnostic]:
    """Validate any submittable (WorkDescriptor or BatchDescriptor)."""
    out: List[Diagnostic] = []
    if isinstance(desc, BatchDescriptor):
        _check_batch(desc, device, out)
        if device is not None:
            _check_locality(desc, device, out)
    else:
        out.extend(check_descriptor(desc, device=device))
    return out


def error_for(diagnostics: Sequence[Diagnostic],
              desc: Any = None) -> DescriptorError:
    """Build the typed error for a diagnostic list: the first error-severity
    finding picks the exception class; the message carries every finding."""
    errors = [d for d in diagnostics if d.severity == "error"]
    first = errors[0] if errors else diagnostics[0]
    cls = ERROR_TYPES.get(first.code, DescriptorError)
    msg = "; ".join(str(d) for d in diagnostics)
    return cls(msg, diagnostics=diagnostics, desc=desc)
