"""Apply Delta Record kernel (paper Table 1, "Compare").

Scatters (offset, word) pairs into a copy of the reference buffer.  Offsets
arrive via scalar prefetch (SMEM); the kernel walks the record serially with
dynamic stores — delta records are small by design (DSA caps them at 4KB),
so the serial loop is latency- not bandwidth-bound.  The ops layer provides
a vectorized jnp fallback for very large records.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _delta_apply_kernel(off_ref, data_ref, ref_ref, out_ref):
    out_ref[...] = ref_ref[...]
    cap = off_ref.shape[0]
    lanes = out_ref.shape[1]

    def body(i, _):
        off = off_ref[i]

        @pl.when(off >= 0)
        def _apply():
            r = off // lanes
            c = off % lanes
            blk = pl.load(out_ref, (pl.ds(r, 1), pl.ds(0, lanes)))
            lane = jax.lax.broadcasted_iota(jnp.int32, (1, lanes), 1)
            blk = jnp.where(lane == c, data_ref[i], blk)
            pl.store(out_ref, (pl.ds(r, 1), pl.ds(0, lanes)), blk)

        return 0

    jax.lax.fori_loop(0, cap, body, 0)


def delta_apply_words(
    ref: jax.Array,  # [rows, 128] uint32
    offsets: jax.Array,  # [cap] i32, -1 padded
    data: jax.Array,  # [cap] u32
    *,
    interpret: bool = False,
) -> jax.Array:
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(1,),
        in_specs=[pl.BlockSpec(ref.shape, lambda i, off, dat: (0, 0))],
        out_specs=pl.BlockSpec(ref.shape, lambda i, off, dat: (0, 0)),
    )
    return pl.pallas_call(
        _delta_apply_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(ref.shape, ref.dtype),
        interpret=interpret,
    )(offsets, data, ref)
