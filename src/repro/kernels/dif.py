"""Data Integrity Field (DIF) operations (paper Table 1, "Move").

DSA checks/inserts/strips an 8-byte DIF per 512/4096-byte block while moving
data.  TPU adaptation: blocks map to rows of a [n_blocks, block_words] word
grid; the per-block CRC reuses the chunk-parallel CRC kernel (every block is
a "chunk", all checked in one vector pass), and the tag framing is a pure
reshape/concat.  Used for checkpoint-shard integrity framing.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import crc32 as _crc
from repro.kernels import ops as _ops


def _block_crcs(blocks: jax.Array, interpret: bool) -> jax.Array:
    """blocks [n_blocks, block_words] u32 -> per-block CRC32 [n_blocks] u32."""
    return _crc.crc32_chunk_states(blocks, _ops._CRC_TABLES, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_words", "ref_tag", "interpret"))
def dif_insert(words: jax.Array, *, block_words: int = 128, ref_tag: int = 0,
               interpret: Optional[bool] = None) -> jax.Array:
    """[n_blocks*block_words] u32 -> framed [n_blocks, block_words+2]."""
    interpret = _ops._interpret_default() if interpret is None else interpret
    blocks = words.reshape(-1, block_words)
    crcs = _block_crcs(blocks, interpret)
    n = blocks.shape[0]
    tags = (jnp.uint32(ref_tag) << 16) | (jnp.arange(n, dtype=jnp.uint32) & jnp.uint32(0xFFFF))
    return jnp.concatenate([blocks, crcs[:, None], tags[:, None]], axis=1)


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def dif_check(framed: jax.Array, *, block_words: int = 128,
              interpret: Optional[bool] = None) -> jax.Array:
    """framed [n_blocks, block_words+2] -> per-block ok mask [n_blocks]."""
    interpret = _ops._interpret_default() if interpret is None else interpret
    blocks = framed[:, :block_words]
    crcs = _block_crcs(blocks, interpret)
    return crcs == framed[:, block_words]


def dif_strip(framed: jax.Array, *, block_words: int = 128) -> jax.Array:
    return framed[:, :block_words].reshape(-1)


@functools.partial(jax.jit, static_argnames=("block_words", "ref_tag", "interpret"))
def dif_update(framed: jax.Array, *, block_words: int = 128, ref_tag: int = 0,
               interpret: Optional[bool] = None) -> jax.Array:
    """Recompute tags over (possibly modified) framed data."""
    return dif_insert(dif_strip(framed, block_words=block_words),
                      block_words=block_words, ref_tag=ref_tag, interpret=interpret)
