"""Flash attention (fwd) — Pallas TPU kernel.

Motivation (EXPERIMENTS.md §Perf): the baseline pure-JAX chunked attention
materializes every [q_blk x kv_blk] score block through HBM at XLA fusion
granularity; the dry-run roofline shows this score traffic DOMINATING the
memory term for train/prefill cells.  This kernel keeps scores, softmax
state, and the output accumulator in VMEM scratch — per-tile HBM traffic
drops to the q/k/v reads + o write.

Layout: q [BH, Sq, hd], k/v [BKV, Skv, hd] (GQA: kv row = (bh // H) * KV +
(bh % H) // G resolved in the BlockSpec index_map).  Grid (BH, n_q, n_kv)
with the kv axis innermost (sequential on TPU) accumulating into VMEM
scratch; causal/window masking is positional, supporting meta-token prefixes
(hymba) via ``n_meta``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal,
            window, n_meta, q_blk, kv_blk, n_kv):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [q_blk, hd]
    k = k_ref[0].astype(jnp.float32)  # [kv_blk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [q_blk, kv_blk]

    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
    mask = jnp.ones((q_blk, kv_blk), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        in_win = (q_pos - k_pos) < window
        if n_meta > 0:
            in_win |= k_pos < n_meta
        mask &= in_win
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]
    ).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    n_meta: int = 0,
    scale: Optional[float] = None,
    q_blk: int = 512,
    kv_blk: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Drop-in replacement for models.layers.attention (fwd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    while Sq % q_blk:
        q_blk //= 2
    while Skv % kv_blk:
        kv_blk //= 2
    n_q, n_kv = Sq // q_blk, Skv // kv_blk

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    def kv_row(bh, qi, ki):
        return ((bh // H) * KV + (bh % H) // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window, n_meta=n_meta,
            q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv,
        ),
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_blk, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_blk, hd), kv_row),
            pl.BlockSpec((1, kv_blk, hd), kv_row),
        ],
        out_specs=pl.BlockSpec((1, q_blk, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
