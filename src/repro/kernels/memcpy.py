"""Memory Copy kernel (paper Table 1, "Move").

Tiled HBM -> VMEM -> HBM stream.  The grid is (n_pe, blocks_per_pe): the
leading grid dim models DSA processing-engine lanes (G5 — PE-level
parallelism); each PE streams its contiguous span of (rows x 128) tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _memcpy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def memcpy_words(
    src: jax.Array,  # [rows, 128] uint32
    *,
    block_rows: int = 8,
    n_pe: int = 1,
    interpret: bool = False,
) -> jax.Array:
    rows = src.shape[0]
    assert src.shape[1] == LANES and rows % (block_rows * n_pe) == 0, (src.shape, block_rows, n_pe)
    blocks_per_pe = rows // block_rows // n_pe

    return pl.pallas_call(
        _memcpy_kernel,
        grid=(n_pe, blocks_per_pe),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda pe, j, bpp=blocks_per_pe: (pe * bpp + j, 0))
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda pe, j, bpp=blocks_per_pe: (pe * bpp + j, 0)),
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        interpret=interpret,
    )(src)
