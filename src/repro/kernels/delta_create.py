"""Create Delta Record kernel (paper Table 1, "Compare").

The DSA emits (offset, 8-byte data) pairs for differing granules.  TPU
adaptation: the kernel computes the vectorized word-granule diff mask and
per-block mismatch counts (the streaming part); the ops layer compacts the
mask into the fixed-capacity record with ``jnp.nonzero(size=cap)`` — the
record capacity mirrors DSA's max delta record size, with the same overflow
status semantics in the completion record.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _delta_mask_kernel(src_ref, ref_ref, mask_ref, count_ref):
    diff = src_ref[...] != ref_ref[...]
    mask_ref[...] = diff
    count_ref[0, 0] = jnp.sum(diff.astype(jnp.int32))


def delta_mask_words(
    src: jax.Array,  # [rows, 128] uint32
    ref: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
):
    """Returns (mask [rows,128] bool, per-block counts [n_blocks,1] i32)."""
    rows = src.shape[0]
    assert src.shape == ref.shape and rows % block_rows == 0
    n_blocks = rows // block_rows
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _delta_mask_kernel,
        grid=(n_blocks,),
        in_specs=[spec, spec],
        out_specs=[spec, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct(src.shape, jnp.bool_),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(src, ref)
