"""Pure-jnp / numpy oracles for every streaming kernel (Table 1 of the paper).

These define the SEMANTICS; the Pallas kernels must match them bit-exactly
(tests/test_kernels.py sweeps shapes x dtypes and asserts equality).

Buffers are modeled as 1-D uint32 word arrays (the TPU-native 4-byte lane
granule; the paper's DSA operates on bytes — we document the granule change
in DESIGN.md).  CRC32 matches zlib.crc32 over the little-endian byte view.
"""
from __future__ import annotations

import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------- CRC32 tables
_POLY = 0xEDB88320  # reflected IEEE


def _make_crc_table() -> np.ndarray:
    tab = np.zeros(256, dtype=np.uint64)
    for i in range(256):
        c = np.uint64(i)
        for _ in range(8):
            c = (c >> np.uint64(1)) ^ (np.uint64(_POLY) * (c & np.uint64(1)))
        tab[i] = c
    return tab.astype(np.uint32)


def make_crc_tables(n: int = 4) -> np.ndarray:
    """Slice-by-n tables [n, 256] uint32 (T0 = classic byte table)."""
    t0 = _make_crc_table()
    tabs = [t0]
    for _ in range(n - 1):
        prev = tabs[-1]
        nxt = (t0[prev & 0xFF] ^ (prev >> np.uint32(8))).astype(np.uint32)
        tabs.append(nxt)
    return np.stack(tabs)  # [n, 256]


# GF(2) combine machinery (zlib crc32_combine) -------------------------------
def _gf2_matrix_times(mat: np.ndarray, vec: int) -> int:
    s = 0
    i = 0
    while vec:
        if vec & 1:
            s ^= int(mat[i])
        vec >>= 1
        i += 1
    return s


def _gf2_matrix_square(mat: np.ndarray) -> np.ndarray:
    return np.array([_gf2_matrix_times(mat, int(m)) for m in mat], dtype=np.uint64)


def crc32_shift_matrix(length_bytes: int) -> np.ndarray:
    """Matrix advancing a CRC state over ``length_bytes`` zero bytes: [32] u32
    columns (column i = image of bit i)."""
    # operator for one zero BIT
    odd = np.zeros(32, dtype=np.uint64)
    odd[0] = np.uint64(_POLY)
    for i in range(1, 32):
        odd[i] = np.uint64(1) << np.uint64(i - 1)
    even = _gf2_matrix_square(odd)  # 2 bits
    odd = _gf2_matrix_square(even)  # 4 bits
    # now square/apply over len*8 bits
    mat_pairs = [even, odd]
    n = length_bytes
    if n == 0:
        ident = np.array([1 << i for i in range(32)], dtype=np.uint64)
        return ident.astype(np.uint32)
    result = None
    cur = 0
    # first application: even = 4-bit?? — follow zlib: loop applying squares of 4-zero-BYTE ops
    # zlib: even starts as "2 zero bytes" after 3 squarings of the 1-bit op.
    # Rebuild cleanly: op1 = 1 zero byte = (1-bit op)^8
    op = np.zeros(32, dtype=np.uint64)
    op[0] = np.uint64(_POLY)
    for i in range(1, 32):
        op[i] = np.uint64(1) << np.uint64(i - 1)
    for _ in range(3):  # ^8 = square 3x
        op = _gf2_matrix_square(op)
    # binary exponentiation over bytes
    ident = np.array([1 << i for i in range(32)], dtype=np.uint64)
    result = ident.copy()
    base = op
    while n:
        if n & 1:
            result = np.array([_gf2_matrix_times(base, int(r)) for r in result], dtype=np.uint64)
        base = _gf2_matrix_square(base)
        n >>= 1
    return result.astype(np.uint32)


def crc32_combine(crc1: int, crc2: int, len2: int) -> int:
    if len2 == 0:
        return crc1
    mat = crc32_shift_matrix(len2)
    return _gf2_matrix_times(mat.astype(np.uint64), crc1) ^ crc2


# --------------------------------------------------------------------------- oracles
def words_to_bytes(words: np.ndarray) -> bytes:
    return np.asarray(words, dtype="<u4").tobytes()


def memcpy_ref(src: jnp.ndarray) -> jnp.ndarray:
    return jnp.array(src)  # identity copy


def fill_ref(shape: Tuple[int, ...], pattern_words: jnp.ndarray) -> jnp.ndarray:
    """Fill a uint32 word buffer with a repeating pattern (2 or 4 words = the
    paper's 8/16-byte patterns)."""
    n = int(np.prod(shape))
    p = len(pattern_words)
    reps = -(-n // p)
    return jnp.tile(jnp.asarray(pattern_words, jnp.uint32), reps)[:n].reshape(shape)


def compare_ref(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(equal?, first-diff flat index or -1)."""
    diff = (a != b).reshape(-1)
    any_diff = diff.any()
    idx = jnp.argmax(diff)  # first True
    return ~any_diff, jnp.where(any_diff, idx, -1)


def compare_pattern_ref(a: jnp.ndarray, pattern_words: jnp.ndarray):
    expect = fill_ref(a.shape, pattern_words)
    return compare_ref(a, expect)


def dualcast_ref(src: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return jnp.array(src), jnp.array(src)


def crc32_ref(words: jnp.ndarray) -> int:
    """zlib.crc32 of the little-endian byte view (ground truth)."""
    return zlib.crc32(words_to_bytes(np.asarray(words))) & 0xFFFFFFFF


def delta_create_ref(
    src: jnp.ndarray, ref: jnp.ndarray, cap: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Delta record vs a reference buffer, 1-word granules.

    Returns (offsets [cap] i32 (-1 pad), data [cap] u32, count, overflow?).
    """
    s = src.reshape(-1)
    r = ref.reshape(-1)
    diff = s != r
    count = diff.sum()
    (idx,) = jnp.nonzero(diff, size=cap, fill_value=-1)
    data = jnp.where(idx >= 0, s[jnp.clip(idx, 0)], 0)
    return idx.astype(jnp.int32), data.astype(jnp.uint32), count.astype(jnp.int32), count > cap


def delta_apply_ref(ref: jnp.ndarray, offsets: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    flat = ref.reshape(-1)
    valid = offsets >= 0
    flat = flat.at[jnp.clip(offsets, 0)].set(
        jnp.where(valid, data, flat[jnp.clip(offsets, 0)])
    )
    return flat.reshape(ref.shape)


def dif_insert_ref(words: jnp.ndarray, block_words: int = 128, ref_tag: int = 0) -> jnp.ndarray:
    """Append an 8-byte DIF (2 words: crc32, ref_tag|block#) per data block
    (block_words*4 bytes = 512B for 128).  Output [n_blocks, block_words+2]."""
    w = np.asarray(words).reshape(-1, block_words)
    out = np.zeros((w.shape[0], block_words + 2), dtype=np.uint32)
    out[:, :block_words] = w
    for i in range(w.shape[0]):
        out[i, block_words] = zlib.crc32(words_to_bytes(w[i])) & 0xFFFFFFFF
        out[i, block_words + 1] = (ref_tag << 16) | (i & 0xFFFF)
    return jnp.asarray(out)


def dif_check_ref(framed: jnp.ndarray, block_words: int = 128) -> jnp.ndarray:
    f = np.asarray(framed).reshape(-1, block_words + 2)
    ok = np.zeros(f.shape[0], dtype=bool)
    for i in range(f.shape[0]):
        ok[i] = (zlib.crc32(words_to_bytes(f[i, :block_words])) & 0xFFFFFFFF) == int(
            f[i, block_words]
        )
    return jnp.asarray(ok)


def dif_strip_ref(framed: jnp.ndarray, block_words: int = 128) -> jnp.ndarray:
    f = np.asarray(framed).reshape(-1, block_words + 2)
    return jnp.asarray(f[:, :block_words].reshape(-1))


def batch_copy_ref(
    src_pool: jnp.ndarray, dst_pool: jnp.ndarray, src_idx: jnp.ndarray, dst_idx: jnp.ndarray
) -> jnp.ndarray:
    """Copy pages src_pool[src_idx[i]] -> dst_pool[dst_idx[i]] (later
    descriptors win on collision, matching sequential DSA semantics)."""
    out = jnp.array(dst_pool)
    for i in range(src_idx.shape[0]):
        out = out.at[dst_idx[i]].set(src_pool[src_idx[i]])
    return out
