"""Memory Fill kernel (paper Table 1, "Fill").

Fills a word buffer with a repeating 2- or 4-word pattern (the paper's
8/16-byte patterns).  ``nt=True`` models the non-allocating variant
(cache-control flag G3): on real TPU the difference is the destination
memory-space hint; the data path is identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _fill_kernel(pat_ref, dst_ref):
    rows, lanes = dst_ref.shape
    p = pat_ref.shape[-1]
    pat = pat_ref[0]  # [p]
    # lane l of row r holds word index (block_offset + r*lanes + l); the
    # pattern index depends only on (global word index % p) — p divides LANES
    # for p in (2, 4), so the tile pattern is position-independent.
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1) % p
    dst_ref[...] = jnp.take(pat, lane_idx, axis=0)


def fill_words(
    rows: int,
    pattern: jax.Array,  # [p] uint32, p in (1, 2, 4)
    *,
    block_rows: int = 8,
    n_pe: int = 1,
    interpret: bool = False,
) -> jax.Array:
    assert rows % (block_rows * n_pe) == 0
    p = pattern.shape[0]
    assert LANES % p == 0, "pattern must divide the lane width"
    blocks_per_pe = rows // block_rows // n_pe
    return pl.pallas_call(
        _fill_kernel,
        grid=(n_pe, blocks_per_pe),
        in_specs=[pl.BlockSpec((1, p), lambda pe, j: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda pe, j, bpp=blocks_per_pe: (pe * bpp + j, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
        interpret=interpret,
    )(pattern.reshape(1, p))
