"""Fused streaming kernels — the hot-path op pairs in ONE Pallas launch.

The paper's per-descriptor cost model (Fig. 2/3) says small-op throughput is
launch-bound: two descriptors that always travel together pay two launch
overheads and stream the data twice.  These kernels fuse the two pairs the
repo actually submits back-to-back:

  copy_crc     memcpy + CRC32: each grid step copies its tile to the
               destination AND folds it into the chunk CRC states — one
               launch, one read pass (checkpointing copies a leaf out and
               checksums it; unfused that is a 1.0x copy plus a 0.5x CRC
               read across two launches).
  fill_verify  fill + compare_pattern: each grid step writes the pattern
               tile and immediately reads it back for the per-block
               (mismatches, first_idx) verification record — one launch
               instead of a 0.5x fill plus a 0.5x compare.

Both are bit-exact against the unfused pairs (tests/test_hotpath.py sweeps
sizes and payloads); the ops layer wraps them with the same word-grid
conventions as the unfused kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.crc32 import INIT, _crc_step

LANES = 128


# ------------------------------------------------------------------ copy+crc
def _copy_crc_kernel(tabs_ref, data_ref, state_ref, dst_ref):
    """Grid step i: copy ``wb`` words of every chunk to the destination and
    advance the per-chunk CRC states over the same tile (states carry
    across sequential grid steps in the output ref, as in _crc_kernel)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        state_ref[...] = jnp.full(state_ref.shape, jnp.uint32(INIT), jnp.uint32)

    tabs = tabs_ref[...]
    blk = data_ref[...]  # [C, wb]
    dst_ref[...] = blk  # the copy: same tile, one read feeds both outputs
    wb = blk.shape[1]
    st = state_ref[...][:, 0]

    def body(i, st):
        return _crc_step(st, blk[:, i], tabs)

    st = jax.lax.fori_loop(0, wb, body, st)
    state_ref[...] = st[:, None]


def copy_crc_words(
    data: jax.Array,  # [C, W] uint32 — C chunks of W words
    tables: jax.Array,  # [4, 256] uint32
    *,
    words_per_step: int = 512,
    interpret: bool = False,
):
    """Returns (per-chunk CRC states [C] u32 post final-xor, copy [C, W])."""
    C, W = data.shape
    wb = min(words_per_step, W)
    while W % wb != 0:
        wb -= 1
    n_steps = W // wb
    states, dst = pl.pallas_call(
        _copy_crc_kernel,
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((4, 256), lambda i: (0, 0)),
            pl.BlockSpec((C, wb), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec((C, wb), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, 1), jnp.uint32),
            jax.ShapeDtypeStruct((C, W), jnp.uint32),
        ],
        interpret=interpret,
    )(tables, data)
    return states[:, 0] ^ jnp.uint32(INIT), dst


# ------------------------------------------------------------------ fill+verify
def _fill_verify_kernel(pat_ref, dst_ref, chk_ref):
    """Write the pattern tile, then read the destination back and emit the
    per-block (mismatch_count, first_idx|-1) verification record — the
    compare_pattern contract computed from the just-written memory."""
    rows, lanes = dst_ref.shape
    p = pat_ref.shape[-1]
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1) % p
    expect = jnp.take(pat_ref[0], lane_idx, axis=0)
    dst_ref[...] = expect
    diff = dst_ref[...] != expect  # readback verify of the written tile
    n = jnp.sum(diff.astype(jnp.int32))
    idx = jnp.argmax(diff.reshape(-1)).astype(jnp.int32)
    chk_ref[0, 0] = n
    chk_ref[0, 1] = jnp.where(n > 0, idx, -1)


def fill_verify_words(
    rows: int,
    pattern: jax.Array,  # [p] uint32, p in (1, 2, 4)
    *,
    block_rows: int = 8,
    interpret: bool = False,
):
    """Returns (filled [rows, 128] u32, per-block [n_blocks, 2] i32)."""
    assert rows % block_rows == 0
    p = pattern.shape[0]
    assert LANES % p == 0, "pattern must divide the lane width"
    n_blocks = rows // block_rows
    return pl.pallas_call(
        _fill_verify_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, p), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(pattern.reshape(1, p))
