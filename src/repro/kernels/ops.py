"""jit'd public wrappers over the Pallas streaming kernels.

Handles: arbitrary input shapes/dtypes (word view + padding), interpret-mode
autodetection (CPU host -> interpret=True; TPU -> compiled), block/PE
parameter selection, and the jnp compaction/combination stages that pair
with each kernel (delta compaction, CRC chunk combine, compare reduce).

Every function has a bit-exact oracle in ref.py.
"""
from __future__ import annotations

import collections
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (
    batch_copy as _bc,
    compare as _cmp,
    crc32 as _crc,
    delta_apply as _da,
    delta_create as _dc,
    dualcast as _dual,
    fill as _fill,
    fused as _fused,
    memcpy as _mc,
    ref as _ref,
)

LANES = 128


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------- word view
def _bitcast_to_u32(x: jax.Array) -> jax.Array:
    itemsize = x.dtype.itemsize
    if itemsize == 4:
        return jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint32)
    if itemsize < 4:
        return jax.lax.bitcast_convert_type(
            x.reshape(-1, 4 // itemsize), jnp.uint32
        ).reshape(-1)
    return jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint32).reshape(-1)


def to_words(x: jax.Array, row_multiple: int = 1) -> Tuple[jax.Array, int, tuple, jnp.dtype]:
    """Bit-cast any array to a padded [rows, 128] uint32 word grid."""
    nbytes = x.size * x.dtype.itemsize
    assert nbytes % 4 == 0, "buffers must be 4-byte multiples"
    flat = _bitcast_to_u32(x)
    n_words = flat.shape[0]
    rows = -(-n_words // LANES)
    rows = -(-rows // row_multiple) * row_multiple
    pad = rows * LANES - n_words
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint32)])
    return flat.reshape(rows, LANES), n_words, x.shape, x.dtype


def from_words(words: jax.Array, n_words: int, shape: tuple, dtype) -> jax.Array:
    flat = words.reshape(-1)[:n_words]
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize == 4:
        out = jax.lax.bitcast_convert_type(flat, dtype)
    elif itemsize < 4:
        out = jax.lax.bitcast_convert_type(flat, dtype).reshape(-1)
    else:
        out = jax.lax.bitcast_convert_type(flat.reshape(-1, itemsize // 4), dtype).reshape(-1)
    return out.reshape(shape)


def _pick_block_rows(rows: int, n_pe: int, target: int = 64) -> int:
    """Largest block_rows <= target such that n_pe * block_rows | rows."""
    for br in range(min(target, rows), 0, -1):
        if rows % (br * n_pe) == 0:
            return br
    return 1


# --------------------------------------------------------------------------- ops
@functools.partial(jax.jit, static_argnames=("n_pe", "interpret"))
def memcpy(x: jax.Array, *, n_pe: int = 1, interpret: Optional[bool] = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    w, n, shape, dtype = to_words(x, row_multiple=n_pe)
    br = _pick_block_rows(w.shape[0], n_pe)
    out = _mc.memcpy_words(w, block_rows=br, n_pe=n_pe, interpret=interpret)
    return from_words(out, n, shape, dtype)


@functools.partial(jax.jit, static_argnames=("n_words", "n_pe", "interpret"))
def fill(
    pattern: jax.Array, n_words: int, *, n_pe: int = 1, interpret: Optional[bool] = None
) -> jax.Array:
    """Fill ``n_words`` uint32 words with a repeating 1/2/4-word pattern."""
    interpret = _interpret_default() if interpret is None else interpret
    rows = -(-n_words // LANES)
    rows = -(-rows // n_pe) * n_pe
    br = _pick_block_rows(rows, n_pe)
    out = _fill.fill_words(rows, pattern.astype(jnp.uint32), block_rows=br, n_pe=n_pe,
                           interpret=interpret)
    return out.reshape(-1)[:n_words]


def fill_like(x: jax.Array, pattern_words=(0,), **kw) -> jax.Array:
    """Engine-backed buffer (re)initialization — e.g. grad-accumulator zeroing."""
    nbytes = x.size * x.dtype.itemsize
    pat = jnp.asarray(pattern_words, jnp.uint32)
    words = fill(pat, nbytes // 4, **kw)
    return from_words(words.reshape(-1), nbytes // 4, x.shape, x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def compare(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None):
    """(equal?, first-diff word index | -1) — DSA completion-record style."""
    interpret = _interpret_default() if interpret is None else interpret
    wa, n, _, _ = to_words(a)
    wb, _, _, _ = to_words(b)
    br = _pick_block_rows(wa.shape[0], 1)
    per_block = _cmp.compare_words(wa, wb, block_rows=br, interpret=interpret)
    counts = per_block[:, 0]
    firsts = per_block[:, 1]
    any_diff = counts.sum() > 0
    block_words = br * LANES
    idx_global = jnp.arange(per_block.shape[0]) * block_words + firsts
    first = jnp.min(jnp.where(counts > 0, idx_global, np.iinfo(np.int32).max))
    first = jnp.where(first >= n, -1, first)  # diff only in padding -> equal
    real = any_diff & (first >= 0)
    return ~real, jnp.where(real, first, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def compare_pattern(a: jax.Array, pattern: jax.Array, *, interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    wa, n, _, _ = to_words(a)
    # padding words won't match the pattern -> compare only true words via mask
    br = _pick_block_rows(wa.shape[0], 1)
    per_block = _cmp.compare_pattern_words(wa, pattern.astype(jnp.uint32), block_rows=br,
                                           interpret=interpret)
    counts, firsts = per_block[:, 0], per_block[:, 1]
    block_words = br * LANES
    idx_global = jnp.arange(per_block.shape[0]) * block_words + firsts
    valid = (counts > 0) & (idx_global < n)
    first = jnp.min(jnp.where(valid, idx_global, np.iinfo(np.int32).max))
    real = valid.any()
    return ~real, jnp.where(real, first, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dualcast(x: jax.Array, *, interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    w, n, shape, dtype = to_words(x)
    br = _pick_block_rows(w.shape[0], 1)
    d1, d2 = _dual.dualcast_words(w, block_rows=br, interpret=interpret)
    return from_words(d1, n, shape, dtype), from_words(d2, n, shape, dtype)


# --------------------------------------------------------------------------- crc32
_CRC_TABLES = jnp.asarray(_ref.make_crc_tables(4))
# Bounded LRU of crc32_combine shift matrices, keyed by chunk byte length.
# Sweeps over many distinct sizes (gen_sweep, long-running services) would
# otherwise grow this without limit — one matrix per size ever seen.
_SHIFT_CACHE: "collections.OrderedDict[int, np.ndarray]" = collections.OrderedDict()
_SHIFT_CACHE_MAX = 64


def _shift_mat(chunk_bytes: int) -> jax.Array:
    mat = _SHIFT_CACHE.get(chunk_bytes)
    if mat is None:
        mat = _ref.crc32_shift_matrix(chunk_bytes)  # numpy
        _SHIFT_CACHE[chunk_bytes] = mat
        while len(_SHIFT_CACHE) > _SHIFT_CACHE_MAX:
            _SHIFT_CACHE.popitem(last=False)  # evict least-recently-used
    else:
        _SHIFT_CACHE.move_to_end(chunk_bytes)
    return jnp.asarray(mat)


def _pick_chunks(n_words: int, max_chunks: int = 256) -> int:
    c = 1
    for cand in range(1, max_chunks + 1):
        if n_words % cand == 0:
            c = cand
    return c


@functools.partial(jax.jit, static_argnames=("interpret", "max_chunks"))
def crc32(x: jax.Array, *, interpret: Optional[bool] = None, max_chunks: int = 256) -> jax.Array:
    """zlib-compatible CRC32 of the little-endian byte view (u32 scalar)."""
    interpret = _interpret_default() if interpret is None else interpret
    flat = _bitcast_to_u32(x)
    n_words = flat.shape[0]
    C = _pick_chunks(n_words, max_chunks)
    data = flat.reshape(C, n_words // C)
    states = _crc.crc32_chunk_states(data, _CRC_TABLES, interpret=interpret)
    if C == 1:
        return states[0]
    mat = _shift_mat((n_words // C) * 4)
    return _crc.combine_chunk_crcs(states, mat)


# --------------------------------------------------------------------------- fused pairs
@functools.partial(jax.jit, static_argnames=("interpret", "max_chunks"))
def copy_crc(x: jax.Array, *, interpret: Optional[bool] = None,
             max_chunks: int = 256):
    """Fused memcpy + CRC32 in ONE kernel launch: returns ``(copy, crc)``
    where ``copy`` is bit-identical to ``memcpy(x)`` and ``crc`` matches
    ``crc32(x)`` (zlib-compatible u32 scalar).  One read pass feeds both
    the write stream and the checksum — vs two launches and two read
    passes unfused."""
    interpret = _interpret_default() if interpret is None else interpret
    flat = _bitcast_to_u32(x)
    n_words = flat.shape[0]
    C = _pick_chunks(n_words, max_chunks)
    data = flat.reshape(C, n_words // C)
    states, dst = _fused.copy_crc_words(data, _CRC_TABLES, interpret=interpret)
    if C == 1:
        crc = states[0]
    else:
        crc = _crc.combine_chunk_crcs(states, _shift_mat((n_words // C) * 4))
    return from_words(dst, n_words, x.shape, x.dtype), crc


@functools.partial(jax.jit, static_argnames=("n_words", "interpret"))
def fill_verify(pattern: jax.Array, n_words: int, *,
                interpret: Optional[bool] = None):
    """Fused fill + compare_pattern in ONE kernel launch: returns
    ``(filled, (ok, first_bad_idx))`` where ``filled`` is bit-identical to
    ``fill(pattern, n_words)`` and the verification pair matches
    ``compare_pattern(filled, pattern)`` — computed in-kernel from the
    just-written tile (the DSA fill-then-verify integrity idiom)."""
    interpret = _interpret_default() if interpret is None else interpret
    rows = -(-n_words // LANES)
    br = _pick_block_rows(rows, 1)
    dst, per_block = _fused.fill_verify_words(
        rows, pattern.astype(jnp.uint32), block_rows=br, interpret=interpret)
    filled = dst.reshape(-1)[:n_words]
    counts, firsts = per_block[:, 0], per_block[:, 1]
    block_words = br * LANES
    idx_global = jnp.arange(per_block.shape[0]) * block_words + firsts
    valid = (counts > 0) & (idx_global < n_words)
    first = jnp.min(jnp.where(valid, idx_global, np.iinfo(np.int32).max))
    real = valid.any()
    return filled, (~real, jnp.where(real, first, -1))


# --------------------------------------------------------------------------- delta records
@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
def delta_create(src: jax.Array, ref: jax.Array, *, cap: int = 1024,
                 interpret: Optional[bool] = None):
    """Fixed-capacity delta record (offsets, data, count, overflow?)."""
    interpret = _interpret_default() if interpret is None else interpret
    ws, n, _, _ = to_words(src)
    wr, _, _, _ = to_words(ref)
    br = _pick_block_rows(ws.shape[0], 1)
    mask, _counts = _dc.delta_mask_words(ws, wr, block_rows=br, interpret=interpret)
    flat_mask = mask.reshape(-1)[:n] if ws.size != n else mask.reshape(-1)
    flat_mask = mask.reshape(-1)
    flat_mask = flat_mask & (jnp.arange(flat_mask.shape[0]) < n)
    count = flat_mask.sum().astype(jnp.int32)
    (idx,) = jnp.nonzero(flat_mask, size=cap, fill_value=-1)
    src_flat = ws.reshape(-1)
    data = jnp.where(idx >= 0, src_flat[jnp.clip(idx, 0)], 0).astype(jnp.uint32)
    return idx.astype(jnp.int32), data, count, count > cap


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def delta_apply(ref: jax.Array, offsets: jax.Array, data: jax.Array, *,
                interpret: Optional[bool] = None, use_kernel: bool = True) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    wr, n, shape, dtype = to_words(ref)
    if use_kernel:
        out = _da.delta_apply_words(wr, offsets, data, interpret=interpret)
    else:
        flat = wr.reshape(-1)
        valid = offsets >= 0
        safe = jnp.clip(offsets, 0)
        flat = flat.at[safe].set(jnp.where(valid, data, flat[safe]))
        out = flat.reshape(wr.shape)
    return from_words(out, n, shape, dtype)


# --------------------------------------------------------------------------- batch copy (paged)
@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(1,))
def batch_copy(src_pool: jax.Array, dst_pool: jax.Array, src_idx: jax.Array,
               dst_idx: jax.Array, *, interpret: Optional[bool] = None) -> jax.Array:
    """Batch-descriptor page copy: dst_pool[dst_idx[i]] = src_pool[src_idx[i]].

    Pools are [n_pages, ...page_shape...] of any dtype; pages are bit-cast to
    word tiles internally."""
    interpret = _interpret_default() if interpret is None else interpret
    P = src_pool.shape[0]
    Q = dst_pool.shape[0]
    page_shape = src_pool.shape[1:]
    page_words, n, _, dtype = to_words(src_pool.reshape((P,) + page_shape)[0])
    rows = page_words.shape[0]

    def pool_words(pool, k):
        flat = _bitcast_to_u32(pool).reshape(k, -1)
        pad = rows * LANES - flat.shape[1]
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((k, pad), jnp.uint32)], axis=1)
        return flat.reshape(k, rows, LANES)

    sw = pool_words(src_pool, P)
    dw = pool_words(dst_pool, Q)
    out = _bc.batch_copy_pages(sw, dw, src_idx.astype(jnp.int32), dst_idx.astype(jnp.int32),
                               interpret=interpret)
    flat = out.reshape(Q, -1)[:, : n]
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize == 4:
        res = jax.lax.bitcast_convert_type(flat, dtype).reshape((Q,) + page_shape)
    elif itemsize < 4:
        res = jax.lax.bitcast_convert_type(flat, dtype).reshape((Q,) + page_shape)
    else:
        res = jax.lax.bitcast_convert_type(flat.reshape(Q, -1, itemsize // 4), dtype).reshape(
            (Q,) + page_shape
        )
    return res
