"""Batch-descriptor page copy kernel (paper F2 — THE key DSA feature).

A batch descriptor delivers an array of work descriptors processed in one
submission.  TPU-native analogue: ONE pallas_call whose grid walks a
scalar-prefetched descriptor table (src_page -> dst_page), re-pointing each
grid step's DMA via the BlockSpec index_map.  This amortizes a single kernel
launch over N page copies exactly as DSA amortizes one ENQCMD over N
descriptors — and it is the engine behind paged-KV-cache block moves
(serving) and incremental-checkpoint page flushes.

The destination pool is donated (input_output_aliased), so untouched pages
keep their contents — matching DSA semantics of scattered writes into an
existing buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _batch_copy_kernel(src_idx_ref, dst_idx_ref, src_pool_ref, dst_in_ref, dst_pool_ref):
    del dst_in_ref  # aliased with the output; untouched pages persist
    dst_pool_ref[...] = src_pool_ref[...]


def batch_copy_pages(
    src_pool: jax.Array,  # [P, rows, 128]
    dst_pool: jax.Array,  # [Q, rows, 128] (donated)
    src_idx: jax.Array,  # [N] i32
    dst_idx: jax.Array,  # [N] i32
    *,
    interpret: bool = False,
) -> jax.Array:
    n = src_idx.shape[0]
    rows = src_pool.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i, sidx, didx: (sidx[i], 0, 0)),
            pl.BlockSpec((1, rows, LANES), lambda i, sidx, didx: (didx[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, LANES), lambda i, sidx, didx: (didx[i], 0, 0)),
    )
    return pl.pallas_call(
        _batch_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={3: 0},  # dst_pool arg (after 2 scalars + src) -> output
        interpret=interpret,
    )(src_idx, dst_idx, src_pool, dst_pool)
