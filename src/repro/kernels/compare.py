"""Memory Compare / Compare Pattern kernels (paper Table 1, "Compare").

Each grid block emits (mismatch_count, first_diff_index_or_-1) for its tile;
the ops layer reduces blocks to the global (equal?, first_diff) pair —
matching DSA's completion-record semantics (status + first-diff offset).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _compare_kernel(a_ref, b_ref, out_ref):
    diff = a_ref[...] != b_ref[...]
    n = jnp.sum(diff.astype(jnp.int32))
    flat = diff.reshape(-1)
    idx = jnp.argmax(flat).astype(jnp.int32)
    out_ref[0, 0] = n
    out_ref[0, 1] = jnp.where(n > 0, idx, -1)


def compare_words(
    a: jax.Array,  # [rows, 128] uint32
    b: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Returns per-block [n_blocks, 2] i32: (mismatches, first_idx|-1)."""
    rows = a.shape[0]
    assert a.shape == b.shape and rows % block_rows == 0
    n_blocks = rows // block_rows
    return pl.pallas_call(
        _compare_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 2), jnp.int32),
        interpret=interpret,
    )(a, b)


def _compare_pattern_kernel(a_ref, pat_ref, out_ref):
    rows, lanes = a_ref.shape
    p = pat_ref.shape[-1]
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1) % p
    expect = jnp.take(pat_ref[0], lane_idx, axis=0)
    diff = a_ref[...] != expect
    n = jnp.sum(diff.astype(jnp.int32))
    idx = jnp.argmax(diff.reshape(-1)).astype(jnp.int32)
    out_ref[0, 0] = n
    out_ref[0, 1] = jnp.where(n > 0, idx, -1)


def compare_pattern_words(
    a: jax.Array,
    pattern: jax.Array,  # [p] uint32
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    rows = a.shape[0]
    p = pattern.shape[0]
    assert rows % block_rows == 0 and LANES % p == 0
    n_blocks = rows // block_rows
    return pl.pallas_call(
        _compare_pattern_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 2), jnp.int32),
        interpret=interpret,
    )(a, pattern.reshape(1, p))
