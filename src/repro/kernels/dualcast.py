"""Dualcast kernel (paper Table 1): one source read, two destination writes.

The point of the DSA op is halving read traffic for replica writes; on TPU
the single pallas_call reads each tile into VMEM once and stores it twice —
used by the checkpoint manager for primary+replica shard fan-out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _dualcast_kernel(src_ref, d1_ref, d2_ref):
    blk = src_ref[...]
    d1_ref[...] = blk
    d2_ref[...] = blk


def dualcast_words(
    src: jax.Array,  # [rows, 128] uint32
    *,
    block_rows: int = 8,
    interpret: bool = False,
):
    rows = src.shape[0]
    assert rows % block_rows == 0
    n_blocks = rows // block_rows
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _dualcast_kernel,
        grid=(n_blocks,),
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(src.shape, src.dtype),
            jax.ShapeDtypeStruct(src.shape, src.dtype),
        ],
        interpret=interpret,
    )(src)
