"""CRC Generation kernel (paper Table 1, "Move"/CRC32), TPU-adapted.

CRC is bit-serial by definition; the DSA computes it in streaming hardware.
The TPU-native adaptation exploits CRC's GF(2) linearity:

  1. split the buffer into C contiguous chunks,
  2. compute all C chunk-CRCs IN PARALLEL — the serial slice-by-4 loop runs
     across the chunk axis as one 8x128-lane vector op per word step
     (table lookups via jnp.take on VMEM-resident [4,256] tables),
  3. fold the C chunk-CRCs with the zlib crc32_combine shift matrix
     (a 32x32 GF(2) operator — jnp bit ops, jittable; ops.py).

Matches zlib.crc32 bit-exactly (tests sweep sizes and random payloads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INIT = 0xFFFFFFFF
_M8 = 0xFF


def _crc_step(st: jax.Array, word: jax.Array, tabs: jax.Array) -> jax.Array:
    """One slice-by-4 step over a vector of chunk states.  st/word [C] u32."""
    m8 = jnp.uint32(_M8)
    x = st ^ word
    t0, t1, t2, t3 = tabs[0], tabs[1], tabs[2], tabs[3]
    return (
        jnp.take(t3, (x & m8).astype(jnp.int32))
        ^ jnp.take(t2, ((x >> 8) & m8).astype(jnp.int32))
        ^ jnp.take(t1, ((x >> 16) & m8).astype(jnp.int32))
        ^ jnp.take(t0, ((x >> 24) & m8).astype(jnp.int32))
    )


def _crc_kernel(tabs_ref, data_ref, state_ref):
    """Grid step processes ``wb`` words of every chunk; chunk states carry
    across sequential grid steps in the output ref."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        state_ref[...] = jnp.full(state_ref.shape, jnp.uint32(INIT), jnp.uint32)

    tabs = tabs_ref[...]
    blk = data_ref[...]  # [C, wb]
    wb = blk.shape[1]
    st = state_ref[...][:, 0]

    def body(i, st):
        return _crc_step(st, blk[:, i], tabs)

    st = jax.lax.fori_loop(0, wb, body, st)
    state_ref[...] = st[:, None]


def crc32_chunk_states(
    data: jax.Array,  # [C, W] uint32 — C chunks of W words
    tables: jax.Array,  # [4, 256] uint32
    *,
    words_per_step: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns per-chunk CRC states [C] u32 (post final-xor)."""
    C, W = data.shape
    wb = min(words_per_step, W)
    while W % wb != 0:
        wb -= 1
    n_steps = W // wb
    states = pl.pallas_call(
        _crc_kernel,
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((4, 256), lambda i: (0, 0)),
            pl.BlockSpec((C, wb), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((C, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 1), jnp.uint32),
        interpret=interpret,
    )(tables, data)
    return states[:, 0] ^ jnp.uint32(INIT)


# ------------------------------------------------------------------ combine (jnp, jittable)
def gf2_apply(mat: jax.Array, vec: jax.Array) -> jax.Array:
    """mat [32] u32 columns; vec scalar u32 -> scalar u32."""
    bits = (vec >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return jax.lax.reduce(
        jnp.where(bits.astype(bool), mat, jnp.uint32(0)),
        jnp.uint32(0),
        jax.lax.bitwise_xor,
        (0,),
    )


def combine_chunk_crcs(states: jax.Array, shift_mat: jax.Array) -> jax.Array:
    """Fold per-chunk CRCs (equal chunk lengths) left-to-right:
    crc = shift(crc) ^ next."""

    def step(crc, nxt):
        return gf2_apply(shift_mat, crc) ^ nxt, None

    crc, _ = jax.lax.scan(step, states[0], states[1:])
    return crc
