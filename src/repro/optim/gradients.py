"""Gradient utilities: global-norm clipping, microbatch accumulation, and
int8 error-feedback compression (distributed-optimization trick; flagged)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


class GradAccumulator:
    """Microbatch gradient accumulation via lax.scan.

    ``accumulate(loss_fn, params, batch, n)`` splits the leading batch dim of
    every leaf into ``n`` microbatches and averages grads in fp32.  Buffer
    zeroing between macro-steps is the engine's Memory Fill op in the real
    pipeline (see repro.core.api.fill_like).
    """

    @staticmethod
    def accumulate(loss_fn, params, batch, n: int):
        if n <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            bsz = x.shape[0] if x.ndim else 1
            # positions_thw has batch at axis 1
            return x.reshape((n, bsz // n) + x.shape[1:])

        def split_leaf(path, x):
            name = str(path[-1].key) if path else ""
            if name == "positions_thw":
                return x.reshape((x.shape[0], n, x.shape[1] // n) + x.shape[2:]).swapaxes(0, 1)
            return split(x)

        micro = jax.tree_util.tree_map_with_path(split_leaf, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_sum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda a: (a / n), acc)
        loss = loss_sum / n
        return loss, {"ce": loss, "aux": jnp.zeros(())}, grads


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (for gradient all-reduce)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
