from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.gradients import clip_by_global_norm, GradAccumulator

__all__ = ["AdamW", "cosine_schedule", "clip_by_global_norm", "GradAccumulator"]
