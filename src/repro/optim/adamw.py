"""Functional AdamW with fp32 moments over (possibly) bf16 params.

The moment buffers are where the Memory Fill engine op earns its keep at
init/reset time (paper Table 1: gradient-buffer zeroing is the canonical
ML use of DSA's Fill — see §5 "HPC/ML acceleration").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    m: Any  # fp32 tree
    v: Any  # fp32 tree


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_m = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        new_p = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v)
