"""Optimizer-state offload to the host tier (paper G4: the engine is the
mover for cross-tier bulk data; CXL tier -> TPU host DRAM).

AdamW moments are read+written once per step; parking them in host memory
between steps frees 8 bytes/param of HBM at the cost of 2 transfers/step
through the streaming engine.  ``plan()`` does the paper-style napkin math
(G4 + Fig 6 constants) to decide whether the trade is profitable for a given
step time; ``offload()/fetch()`` execute the moves via engine descriptors
(on real hardware these are device<->host DMAs; here the tier is simulated,
the byte accounting and timing model are real).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax

from repro.core.descriptor import OpType, WorkDescriptor
from repro.core.device import Device, Future
from repro.core.perfmodel import DEFAULT_MODEL, TIERS


@dataclasses.dataclass
class OffloadPlan:
    hbm_freed_bytes: int
    transfer_s_per_step: float
    profitable_below_step_s: float  # if step time exceeds this, offload hides

    def hides_under(self, step_time_s: float) -> bool:
        """True when the H2D prefetch of the moments fits under one step
        (G2: async always — the fetch overlaps the forward/backward)."""
        return step_time_s >= self.transfer_s_per_step


def plan(opt_state, fraction: float = 1.0, model=DEFAULT_MODEL) -> OffloadPlan:
    nbytes = int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt_state.m)) +
                 sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt_state.v)))
    nbytes = int(nbytes * fraction)
    # one D2H after the update + one H2D before the next (async depth 32)
    t = model.op_time(nbytes, async_depth=32, src_tier="hbm", dst_tier="host") + \
        model.op_time(nbytes, async_depth=32, src_tier="host", dst_tier="hbm")
    return OffloadPlan(
        hbm_freed_bytes=nbytes,
        transfer_s_per_step=t,
        profitable_below_step_s=t,
    )


class MomentOffloader:
    """Round-trips the moment trees through the engine, leaf by leaf
    (each leaf is one descriptor; the whole tree is one batch descriptor).

    Moves are asynchronous: ``_move_tree_async`` returns a Future that
    resolves to the reassembled tree (``.then`` re-unflattens on retire),
    so the m-tree and v-tree round-trips overlap (G2: async always)."""

    def __init__(self, device: Device):
        self.device = device
        self.stats = {"offloads": 0, "fetches": 0, "bytes_moved": 0}

    def _move_tree_async(self, tree: Any) -> Future:
        leaves, treedef = jax.tree.flatten(tree)
        descs = [WorkDescriptor(op=OpType.MEMCPY, src=x) for x in leaves]
        self.stats["bytes_moved"] += sum(d.nbytes for d in descs)
        fut = self.device.batch_async(descs, producer="moment-offload")

        def reassemble(outs):
            if len(descs) == 1 and not isinstance(outs, list):
                outs = [outs]
            return jax.tree.unflatten(treedef, outs)

        return fut.then(reassemble)

    def _move_both(self, opt_state):
        fm = self._move_tree_async(opt_state.m)
        fv = self._move_tree_async(opt_state.v)  # in flight together
        # one set-wait retires both round-trips (completion subsystem): the
        # host parks under the device's wait policy instead of pumping fm
        # to completion before even looking at fv
        self.device.wait_all([fm, fv])
        return opt_state._replace(m=fm.result(), v=fv.result())

    def offload(self, opt_state):
        self.stats["offloads"] += 1
        return self._move_both(opt_state)

    def fetch(self, opt_state):
        self.stats["fetches"] += 1
        return self._move_both(opt_state)
