"""Per-record counter accumulation — the "counters" half of telemetry.

The old ``Telemetry`` interleaved two jobs: walking engine completion
records into per-op / per-WQ / per-node counters, and rolling those
counters up into the PCM-style snapshot/report.  This module owns the
first job so both the post-hoc ``Telemetry`` rollup (core/telemetry.py)
and the live ``repro.obs`` sampler can share one accumulation path.

``CounterStore.drain_engine`` also fixes the old unbounded-growth leak:
a completion record is counted exactly once and then PRUNED from the
engine's ``records`` dict (and its id retired from the seen-set), so a
long-running serving loop no longer grows memory linearly with the
number of submitted descriptors.  Pass ``prune=False`` to keep records
alive (e.g. when several independent consumers walk the same engines);
the seen-set is then intersected with the live record ids each drain so
it stays bounded by the records dict itself.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Iterable, Set


@dataclasses.dataclass
class OpCounter:
    count: int = 0
    bytes: int = 0
    modeled_us: float = 0.0
    wall_us: float = 0.0


def size_bucket(nbytes: int) -> str:
    if nbytes < 4096:
        return "<4KB"
    if nbytes < 65536:
        return "4-64KB"
    if nbytes < 1 << 20:
        return "64KB-1MB"
    return ">=1MB"


def new_node_bucket() -> dict:
    return {"local_ops": 0, "local_bytes": 0,
            "cross_ops": 0, "cross_bytes": 0, "link_bytes": 0}


class CounterStore:
    """Accumulates completion records into per-op x size-class, per-WQ, and
    per-NUMA-node counters.  One store per telemetry consumer; engines are
    walked via ``drain_engine`` (records counted once, pruned by default)."""

    def __init__(self, engine_names: Iterable[str], prune: bool = True):
        self.prune = prune
        self.ops: Dict[str, Dict[str, OpCounter]] = {
            name: defaultdict(OpCounter) for name in engine_names
        }
        self.per_wq_ops: Dict[str, Dict[str, OpCounter]] = {
            name: defaultdict(OpCounter) for name in self.ops
        }
        self.node_traffic: Dict[int, dict] = defaultdict(new_node_bucket)
        # ids counted but intentionally left in engine.records (prune=False);
        # re-intersected with the live ids every drain so it cannot outgrow
        # the records dict
        self._seen: Dict[str, Set[int]] = {name: set() for name in self.ops}

    def observe(self, engine_name: str, node_id: int, rec) -> None:
        """Count one resolved completion record (exactly-once is the
        caller's contract — ``drain_engine`` enforces it)."""
        key = f"{rec.op or '?'}/{size_bucket(rec.bytes_processed)}"
        c = self.ops[engine_name][key]
        c.count += 1
        c.bytes += rec.bytes_processed
        c.modeled_us += rec.modeled_time_us
        c.wall_us += rec.wall_time_us
        nt = self.node_traffic[node_id]
        if rec.link_hops > 0:
            nt["cross_ops"] += 1
            nt["cross_bytes"] += rec.bytes_processed
            nt["link_bytes"] += rec.bytes_processed * rec.link_hops
        else:
            nt["local_ops"] += 1
            nt["local_bytes"] += rec.bytes_processed
        if rec.wq is not None:
            wc = self.per_wq_ops[engine_name][rec.wq]
            wc.count += 1
            wc.bytes += rec.bytes_processed
            wc.modeled_us += rec.modeled_time_us
            wc.wall_us += rec.wall_time_us

    def drain_engine(self, engine) -> int:
        """Walk one engine's completion records, counting each resolved
        record once.  Returns the number of records newly counted.

        prune=True (default): counted records are popped from
        ``engine.records`` and never re-enter the seen-set — O(resolved)
        work, O(in-flight) memory.
        prune=False: records stay; the seen-set marks them counted and is
        clipped to the ids still present."""
        name = engine.name
        node_id = getattr(engine, "node_id", 0)
        seen = self._seen.setdefault(name, set())
        self.ops.setdefault(name, defaultdict(OpCounter))
        self.per_wq_ops.setdefault(name, defaultdict(OpCounter))
        counted = 0
        live: Set[int] = set()
        for desc_id, rec in list(engine.records.items()):
            if not rec.is_done():
                live.add(desc_id)
                continue
            if desc_id in seen:
                live.add(desc_id)
                continue
            self.observe(name, node_id, rec)
            counted += 1
            if self.prune:
                engine.records.pop(desc_id, None)
            else:
                seen.add(desc_id)
                live.add(desc_id)
        if seen:
            seen &= live  # retire ids whose records are gone
        return counted

    def totals(self) -> dict:
        """Cross-engine totals (ops/bytes) — the reconciliation anchor the
        obs sampler tests compare their delta sums against."""
        count = sum(c.count for per in self.ops.values() for c in per.values())
        nbytes = sum(c.bytes for per in self.ops.values() for c in per.values())
        return {"count": count, "bytes": nbytes}
