"""Event-driven completion subsystem (paper Fig. 11 + the "choose your wait
scheme" guideline).

How the host waits on completions decides how many CPU cycles are left for
real work.  The paper measures four schemes on DSA; each maps onto a
``WaitPolicy`` here:

  spin       busy-poll the completion record: lowest observation latency,
             every waited cycle is host-busy.
  pause      spin throttled with PAUSE: the core stays occupied (still
             host-busy) but polls less often — kinder to the SMT sibling
             and the power budget.
  umwait     UMONITOR/UMWAIT on the completion record: the core parks
             (host-FREE) until the engine's completion write wakes it, at a
             modeled C0.2 exit latency per wake.
  interrupt  completion interrupt: the host is fully free until the IRQ;
             each wake bills a modeled delivery+handler cost, and one IRQ
             retires every completion that is ready (coalescing).

The simulator analogue: host-busy time is the measured wall time spent
pumping the engine (kick + completion-queue scan); host-free time is the
measured wall time blocked in ``jax.block_until_ready`` on the in-flight
kernels — the engine genuinely streams during that interval, exactly like
hardware behind UMWAIT.  Modeled wake/IRQ costs (perfmodel constants) are
billed into busy time and tracked separately in ``modeled_overhead_s``.

Set-oriented waiting replaces per-Future pump loops: a ``CompletionSet`` is
a device-level completion queue — ``StreamEngine`` notifies the ``Device``
on record resolution, the device delivers the owning ``Future`` to every
registered set, and ``wait_any`` / ``wait_all`` / ``as_completed`` drive ONE
policy loop over the whole set instead of N independent busy-waits.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional, Union

import jax

from repro.analysis import lockcheck as _lockcheck


class WaitTimeout(TimeoutError):
    """A bounded wait expired before the required completions arrived."""


def _is_done(fut: Any) -> bool:
    """Completion check over anything future-shaped (Future, Promise,
    CompletionRecord, or any object exposing done()/is_done())."""
    check = getattr(fut, "done", None) or getattr(fut, "is_done")
    return bool(check())


# --------------------------------------------------------------------------- stats
@dataclasses.dataclass
class WaitStats:
    """Host-cycle accounting for one wait policy (the measured Fig. 11).

    busy_s  wall time the host spent pumping (kick/scan/poll) plus the
            modeled wake/IRQ overheads — cycles NOT available for real work.
    free_s  wall time the host spent parked (UMWAIT block / IRQ sleep) while
            the engine streamed — cycles available for other threads/work.
    """

    waits: int = 0
    polls: int = 0
    wakes: int = 0
    irqs: int = 0
    completions: int = 0
    busy_s: float = 0.0
    free_s: float = 0.0
    modeled_overhead_s: float = 0.0

    @property
    def host_free_frac(self) -> float:
        total = self.busy_s + self.free_s
        return self.free_s / total if total > 0 else 0.0

    def merge(self, other: "WaitStats") -> "WaitStats":
        """Fold another WaitStats in (each WaitPolicy.wait bills a local
        instance, merged into the device's per-policy bucket at the end —
        totals identical to incremental billing, and the same numbers feed
        the tracer's wait span, so both views always reconcile)."""
        self.waits += other.waits
        self.polls += other.polls
        self.wakes += other.wakes
        self.irqs += other.irqs
        self.completions += other.completions
        self.busy_s += other.busy_s
        self.free_s += other.free_s
        self.modeled_overhead_s += other.modeled_overhead_s
        return self

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["host_free_frac"] = self.host_free_frac
        return d


# --------------------------------------------------------------------------- completion sets
class CompletionSet:
    """Device-level completion queue over a fixed set of futures.

    The owning device pushes every resolved future into each registered set
    (engine notification -> ``Device._on_future_done`` -> ``_deliver``); a
    ``scan()`` fallback catches futures that resolve outside the engine
    notification path (host promises, chained continuations, completions
    observed before the set existed).  Thread-safe; completion order is the
    delivery order.
    """

    def __init__(self, device, futures: Iterable[Any]):
        self.device = device
        self.futures = list(futures)
        self._lock = _lockcheck.checked_lock("completion.set")
        self._pending: Dict[int, Any] = {id(f): f for f in self.futures}
        self._ready: Deque[Any] = collections.deque()
        self.delivered = 0
        self._unattributed = 0  # delivered but not yet billed to a WaitStats
        device._add_sink(self)
        self.scan()

    # -- delivery ------------------------------------------------------------
    def _deliver(self, fut: Any):
        with self._lock:
            if id(fut) not in self._pending:
                return
            del self._pending[id(fut)]
            self._ready.append(fut)
            self.delivered += 1
            self._unattributed += 1

    def take_delivered(self) -> int:
        """Completions delivered since the last call — consumed by the wait
        policy that observed them, so pre-wait (seeded) completions are
        billed to the first wait over the set rather than lost."""
        with self._lock:
            n, self._unattributed = self._unattributed, 0
            return n

    def scan(self):
        """Sweep watched futures for completions the push path missed."""
        with self._lock:
            pending = list(self._pending.values())
        for f in pending:
            if _is_done(f):
                self._deliver(f)

    # -- consumption ---------------------------------------------------------
    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def n_ready(self) -> int:
        return len(self._ready)

    def pop(self) -> Optional[Any]:
        with self._lock:
            return self._ready.popleft() if self._ready else None

    def close(self):
        self.device._remove_sink(self)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# --------------------------------------------------------------------------- policies
class WaitPolicy:
    """One host-side wait scheme.  ``wait`` pumps the device and scans the
    completion set until ``satisfied()`` or the timeout; subclasses decide
    what happens between polls (nothing / PAUSE / park / IRQ sleep) and how
    the interval is billed (busy vs free)."""

    name = "base"

    def wait(self, device, sink: CompletionSet,
             satisfied: Callable[[], bool],
             timeout: Optional[float] = None) -> bool:
        # bill into a LOCAL WaitStats, folded into the device's per-policy
        # bucket once on exit: totals are preserved exactly (Fig. 11
        # unchanged) and the tracer records this wait's busy/free split as
        # one wait span from the same numbers
        stats = WaitStats(waits=1)
        t_begin = time.perf_counter()
        deadline = None if timeout is None else t_begin + timeout
        try:
            while True:  # dsalint: disable=DSA103 — WaitPolicy internals ARE the sanctioned pump
                t0 = time.perf_counter()
                device.kick()
                sink.scan()
                stats.polls += 1
                stats.busy_s += time.perf_counter() - t0
                if satisfied():
                    return True
                if deadline is not None and time.perf_counter() >= deadline:
                    return False
                self._idle(device, stats, deadline)
        finally:
            stats.completions += sink.take_delivered()
            device._wait_bucket(self.name).merge(stats)
            tracer = getattr(device, "tracer", None)
            if tracer is not None:
                tracer.wait_span(self.name, t_begin, time.perf_counter(),
                                 stats.busy_s, stats.free_s,
                                 stats.completions)

    def _idle(self, device, stats: WaitStats, deadline: Optional[float]):
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------
    @staticmethod
    def _model(device):
        return device.engines[0].model if device.engines else None

    @staticmethod
    def _park(device, stats: WaitStats, deadline: Optional[float],
              idle_poll_s: float) -> float:
        """Block host-free until in-flight engine work lands (the monitored
        completion write): first completion among the PE workers, else the
        device-side readiness of already-dispatched outputs.  With nothing
        locally in flight — e.g. everything is fenced on a host promise —
        nap briefly instead.  Returns the parked interval; the caller bills
        it as free time."""
        work, leaves = device._inflight_work()
        t0 = time.perf_counter()
        budget = None if deadline is None else max(deadline - t0, 0.0)
        if work:
            concurrent.futures.wait(
                work, timeout=budget,
                return_when=concurrent.futures.FIRST_COMPLETED,
            )
        elif leaves and budget is None:
            jax.block_until_ready(leaves)
        else:
            # bounded wait: block_until_ready has no deadline, so honor the
            # budget with a nap-and-repoll instead of an unbounded block
            nap = idle_poll_s if budget is None else min(idle_poll_s, budget)
            if nap > 0:
                time.sleep(nap)
        parked = time.perf_counter() - t0
        stats.free_s += parked
        return parked


class SpinWait(WaitPolicy):
    """Busy-poll: every waited cycle is host-busy, wake latency ~0."""

    name = "spin"

    def _idle(self, device, stats, deadline):
        pass  # tight loop — the next pump is the next poll


class PauseWait(WaitPolicy):
    """PAUSE-throttled spin: the core is still occupied (busy), but the poll
    loop backs off, modeling the paper's lower-power spin variant."""

    name = "pause"

    def __init__(self, pause_s: Optional[float] = None):
        self.pause_s = pause_s

    def _idle(self, device, stats, deadline):
        model = self._model(device)
        pause = self.pause_s if self.pause_s is not None else (
            model.pause_poll_s if model else 0.1e-6
        )
        t0 = time.perf_counter()
        if pause > 0:
            time.sleep(pause)  # the core is NOT free in PAUSE: bill busy
        stats.busy_s += time.perf_counter() - t0


class UmwaitWait(WaitPolicy):
    """UMONITOR/UMWAIT: park host-free until the completion write, then pay
    a modeled C0.2 exit latency per wake."""

    name = "umwait"

    def __init__(self, wake_latency_s: Optional[float] = None,
                 idle_poll_s: float = 50e-6):
        self.wake_latency_s = wake_latency_s
        self.idle_poll_s = idle_poll_s

    def _idle(self, device, stats, deadline):
        self._park(device, stats, deadline, self.idle_poll_s)
        stats.wakes += 1
        model = self._model(device)
        wake = self.wake_latency_s if self.wake_latency_s is not None else (
            model.umwait_wake_s if model else 0.5e-6
        )
        stats.busy_s += wake
        stats.modeled_overhead_s += wake


class InterruptWait(WaitPolicy):
    """Completion interrupt: host fully free until the IRQ.  One IRQ retires
    every completion ready at wake (coalescing — in-flight descriptors land
    together), optionally widened by a coalescing window; each IRQ bills a
    modeled delivery + handler + reschedule cost."""

    name = "interrupt"

    def __init__(self, irq_cost_s: Optional[float] = None,
                 coalesce_window_s: float = 0.0,
                 idle_poll_s: float = 50e-6):
        self.irq_cost_s = irq_cost_s
        self.coalesce_window_s = coalesce_window_s
        self.idle_poll_s = idle_poll_s

    def _idle(self, device, stats, deadline):
        self._park(device, stats, deadline, self.idle_poll_s)
        if self.coalesce_window_s > 0:
            # hold the IRQ open so more completions land in this batch
            t0 = time.perf_counter()
            time.sleep(self.coalesce_window_s)
            stats.free_s += time.perf_counter() - t0
        stats.wakes += 1
        stats.irqs += 1
        model = self._model(device)
        irq = self.irq_cost_s if self.irq_cost_s is not None else (
            model.irq_cost_s if model else 4e-6
        )
        stats.busy_s += irq
        stats.modeled_overhead_s += irq


WAIT_POLICIES: Dict[str, Callable[[], WaitPolicy]] = {
    "spin": SpinWait,
    "pause": PauseWait,
    "umwait": UmwaitWait,
    "interrupt": InterruptWait,
}


def get_wait_policy(policy: Union[str, WaitPolicy, None]) -> WaitPolicy:
    """Resolve a wait-policy spec: name, instance, or None (-> umwait, the
    paper's default guideline: free the cycles unless latency is king)."""
    if policy is None:
        return UmwaitWait()
    if isinstance(policy, WaitPolicy):
        return policy
    try:
        return WAIT_POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown wait policy {policy!r}; "
                         f"expected one of {sorted(WAIT_POLICIES)}") from None


# --------------------------------------------------------------------------- set waits
def wait_any(device, futures, *, policy: Optional[Union[str, WaitPolicy]] = None,
             timeout: Optional[float] = None):
    """Wait until at least one future completes; returns (done, pending)
    lists in input order.  ``timeout=0`` is a single poll pass (pump + scan,
    never park); on timeout ``done`` may be empty."""
    futures = list(futures)
    pol = device._resolve_wait_policy(policy)
    with CompletionSet(device, futures) as sink:
        pol.wait(device, sink,
                 lambda: sink.n_ready > 0 or sink.n_pending == 0, timeout)
    done = [f for f in futures if _is_done(f)]
    pending = [f for f in futures if not _is_done(f)]
    return done, pending


def wait_all(device, futures, *, policy: Optional[Union[str, WaitPolicy]] = None,
             timeout: Optional[float] = None):
    """Wait until every future completes; returns the futures.  Raises
    WaitTimeout if the deadline passes first.  Completion != success: a
    failed descriptor is "complete" here — call ``result()`` to raise."""
    futures = list(futures)
    pol = device._resolve_wait_policy(policy)
    with CompletionSet(device, futures) as sink:
        pol.wait(device, sink, lambda: sink.n_pending == 0, timeout)
        if sink.n_pending:
            raise WaitTimeout(
                f"wait_all: {sink.n_pending}/{len(futures)} futures still "
                f"pending after {timeout}s"
            )
    return futures


def as_completed(device, futures, *, policy: Optional[Union[str, WaitPolicy]] = None,
                 timeout: Optional[float] = None):
    """Iterate futures in COMPLETION order (not submission order), driving
    one policy loop for the whole set.  Raises WaitTimeout if ``timeout``
    elapses with futures still pending."""
    futures = list(futures)
    pol = device._resolve_wait_policy(policy)
    deadline = None if timeout is None else time.perf_counter() + timeout
    sink = CompletionSet(device, futures)
    try:
        remaining = len(futures)
        while remaining:
            fut = sink.pop()
            if fut is None:
                left = None if deadline is None else deadline - time.perf_counter()
                pol.wait(device, sink, lambda: sink.n_ready > 0, left)
                fut = sink.pop()
                if fut is None:
                    raise WaitTimeout(
                        f"as_completed: {remaining}/{len(futures)} futures "
                        f"still pending after {timeout}s"
                    )
            remaining -= 1
            yield fut
    finally:
        sink.close()
