"""Work queues (paper §3.2, §3.4): dedicated (DWQ) vs shared (SWQ) plus the
WQCFG-style provisioning record.

DWQ: single producer, MOVDIR64B-style posted submit — always accepted while
capacity remains, owner-checked.
SWQ: multi-producer, ENQCMD-style non-posted submit — returns RETRY when
full; internal lock models the hardware's atomic enqueue (software needs no
locks, per the paper).  The non-posted round trip costs extra submit time,
which the engine charges into the modeled completion time.

``WQConfig`` mirrors the DSA WQCFG register block the paper sweeps in
Fig. 9: mode, size partition of the instance's 128 WQ entries, priority
(1-15, higher drains first under the group arbiter), and a traffic class
steering completions/destination writes toward LLC (DDIO, Fig. 12) or
memory.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Optional, Sequence, Tuple, Union

from repro.analysis import lockcheck as _lockcheck
from repro.core.descriptor import BatchDescriptor, Status, WorkDescriptor

Submittable = Union[WorkDescriptor, BatchDescriptor]

#: steering targets for a WQ's traffic class (paper Fig. 12 / G3): "to_cache"
#: is the DDIO analogue (completion + destination lines allocated in LLC /
#: VMEM tier), "to_memory" writes around the cache.
TRAFFIC_CLASSES = ("to_memory", "to_cache")

PRIORITY_MIN, PRIORITY_MAX = 1, 15


@dataclasses.dataclass(frozen=True)
class WQConfig:
    """One WQ's provisioning record (the WQCFG analogue).

    group      which engine group the WQ belongs to (WQ -> group -> PEs)
    mode       "dedicated" (MOVDIR64B, owner-checked) | "shared" (ENQCMD)
    size       entry partition; the paper's instances split 128 entries
               across enabled WQs
    priority   1-15, higher is drained preferentially by the group arbiter
    traffic_class  completion/destination steering: "to_cache" | "to_memory"
    owner      producer name enforced on dedicated WQs (None = any)
    """

    name: str
    mode: str = "dedicated"
    size: int = 32
    priority: int = 1
    traffic_class: str = "to_memory"
    owner: Optional[str] = None
    group: int = 0

    def __post_init__(self):
        if self.mode not in ("dedicated", "shared"):
            raise ValueError(f"WQConfig.mode must be dedicated|shared, got {self.mode!r}")
        if not PRIORITY_MIN <= self.priority <= PRIORITY_MAX:
            raise ValueError(
                f"WQConfig.priority must be in [{PRIORITY_MIN}, {PRIORITY_MAX}] "
                f"(DSA WQCFG priority field), got {self.priority}"
            )
        if self.size < 1:
            raise ValueError(f"WQConfig.size must be >= 1, got {self.size}")
        if self.traffic_class not in TRAFFIC_CLASSES:
            raise ValueError(
                f"WQConfig.traffic_class must be one of {TRAFFIC_CLASSES}, "
                f"got {self.traffic_class!r}"
            )
        if self.group < 0:
            raise ValueError(f"WQConfig.group must be >= 0, got {self.group}")


class WorkQueue:
    def __init__(self, name: str, mode: str = "dedicated", size: int = 32,
                 priority: int = 0, owner: Optional[str] = None,
                 traffic_class: str = "to_memory"):
        assert mode in ("dedicated", "shared")
        self.name = name
        self.mode = mode
        self.size = size
        self.priority = priority
        self.owner = owner
        self.traffic_class = traffic_class
        self._q: Deque[Tuple[Submittable, float]] = collections.deque()
        self._lock = _lockcheck.checked_lock("wq")
        # monotonic counters — the obs sampler reads deltas of these per
        # tick, so they only ever grow (bytes_submitted tracks descriptor
        # payload accepted into the queue, the WQ-inflow analogue)
        self.stats = {"submitted": 0, "retried": 0, "dispatched": 0,
                      "queue_delay_us": 0.0, "bytes_submitted": 0}
        # queueing delay of the most recent pop(); the engine reads this to
        # stamp the descriptor's CompletionRecord
        self.last_queue_delay_us: float = 0.0

    @classmethod
    def from_config(cls, cfg: WQConfig) -> "WorkQueue":
        return cls(cfg.name, mode=cfg.mode, size=cfg.size, priority=cfg.priority,
                   owner=cfg.owner, traffic_class=cfg.traffic_class)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def occupancy(self) -> float:
        return len(self._q) / self.size

    @property
    def headroom(self) -> int:
        """Free entries — the occupancy probe's admission view: an arrival
        whose class WQ has no headroom is better shed at the door than
        bounced off ENQCMD RETRY after burning backoff."""
        return max(self.size - len(self._q), 0)

    @property
    def mean_queue_delay_us(self) -> float:
        return self.stats["queue_delay_us"] / max(self.stats["dispatched"], 1)

    def submit(self, desc: Submittable, producer: Optional[str] = None) -> Status:
        now = time.perf_counter()
        if self.mode == "dedicated":
            if self.owner is not None and producer is not None and producer != self.owner:
                raise PermissionError(
                    f"DWQ {self.name} owned by {self.owner}; got producer {producer}"
                )
            if len(self._q) >= self.size:
                # a full DWQ is a programming error in DSA (posted write drops)
                self.stats["retried"] += 1
                return Status.RETRY
            self._q.append((desc, now))
            self.stats["submitted"] += 1
            self.stats["bytes_submitted"] += desc.nbytes
            return Status.PENDING
        # shared: atomic non-posted enqueue with RETRY status
        with self._lock:
            if len(self._q) >= self.size:
                self.stats["retried"] += 1
                return Status.RETRY
            self._q.append((desc, now))
            self.stats["submitted"] += 1
            self.stats["bytes_submitted"] += desc.nbytes
            return Status.PENDING

    def submit_many(self, descs: Sequence[Submittable],
                    producer: Optional[str] = None) -> Status:
        """Fused-doorbell enqueue: accept ``descs`` atomically under ONE lock
        acquisition (the single MOVDIR64B/ENQCMD analogue for a batch), or
        RETRY without enqueuing anything when the whole burst doesn't fit —
        all-or-nothing, so a retried burst can be resubmitted as a unit."""
        now = time.perf_counter()
        if self.mode == "dedicated":
            if self.owner is not None and producer is not None and producer != self.owner:
                raise PermissionError(
                    f"DWQ {self.name} owned by {self.owner}; got producer {producer}"
                )
            return self._enqueue_burst(descs, now)
        with self._lock:
            return self._enqueue_burst(descs, now)

    def _enqueue_burst(self, descs: Sequence[Submittable], now: float) -> Status:
        if len(self._q) + len(descs) > self.size:
            self.stats["retried"] += 1
            return Status.RETRY
        for d in descs:
            self._q.append((d, now))
        self.stats["submitted"] += len(descs)
        self.stats["bytes_submitted"] += sum(d.nbytes for d in descs)
        return Status.PENDING

    def pop(self) -> Optional[Submittable]:
        with self._lock:
            if self._q:
                desc, t_enq = self._q.popleft()
                delay_us = (time.perf_counter() - t_enq) * 1e6
                self.last_queue_delay_us = delay_us
                self.stats["dispatched"] += 1
                self.stats["queue_delay_us"] += delay_us
                return desc
            return None
