"""Work queues (paper §3.2): dedicated (DWQ) vs shared (SWQ).

DWQ: single producer, MOVDIR64B-style posted submit — always accepted while
capacity remains, owner-checked.
SWQ: multi-producer, ENQCMD-style non-posted submit — returns RETRY when
full; internal lock models the hardware's atomic enqueue (software needs no
locks, per the paper).
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, Optional, Union

from repro.core.descriptor import BatchDescriptor, Status, WorkDescriptor

Submittable = Union[WorkDescriptor, BatchDescriptor]


class WorkQueue:
    def __init__(self, name: str, mode: str = "dedicated", size: int = 32,
                 priority: int = 0, owner: Optional[str] = None):
        assert mode in ("dedicated", "shared")
        self.name = name
        self.mode = mode
        self.size = size
        self.priority = priority
        self.owner = owner
        self._q: Deque[Submittable] = collections.deque()
        self._lock = threading.Lock()
        self.stats = {"submitted": 0, "retried": 0, "dispatched": 0}

    def __len__(self) -> int:
        return len(self._q)

    @property
    def occupancy(self) -> float:
        return len(self._q) / self.size

    def submit(self, desc: Submittable, producer: Optional[str] = None) -> Status:
        if self.mode == "dedicated":
            if self.owner is not None and producer is not None and producer != self.owner:
                raise PermissionError(
                    f"DWQ {self.name} owned by {self.owner}; got producer {producer}"
                )
            if len(self._q) >= self.size:
                # a full DWQ is a programming error in DSA (posted write drops)
                self.stats["retried"] += 1
                return Status.RETRY
            self._q.append(desc)
            self.stats["submitted"] += 1
            return Status.PENDING
        # shared: atomic non-posted enqueue with RETRY status
        with self._lock:
            if len(self._q) >= self.size:
                self.stats["retried"] += 1
                return Status.RETRY
            self._q.append(desc)
            self.stats["submitted"] += 1
            return Status.PENDING

    def pop(self) -> Optional[Submittable]:
        with self._lock:
            if self._q:
                self.stats["dispatched"] += 1
                return self._q.popleft()
            return None
