"""High-level engine API (DML analogue) and transparent offload (DTO analogue).

The paper ships two software layers above raw descriptors:
  * DML — explicit C/C++ API with async offload and load balancing;
  * DTO — LD_PRELOAD interception of memcpy/memset/memcmp.

The DML-style facade now lives in core/device.py: ``Device`` owns N engine
instances behind a pluggable SubmitPolicy and returns ``Future`` objects
from every submit.  This module keeps:

  * ``Stream`` / ``make_stream`` — DEPRECATED one-release shims over Device
    that preserve the old (engine, record) tuple handles; new code should
    use ``Device`` / ``make_device`` and Futures.
  * ``dto`` — the drop-in layer: jnp-compatible copy/fill/compare functions
    that route through the active Device when one is installed, else fall
    back to plain jnp.
"""
from __future__ import annotations

import contextlib
import threading
import warnings
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.descriptor import CompletionRecord
from repro.core.device import Device, Future, QueueFull, make_device
from repro.core.engine import DeviceConfig, StreamEngine


class Stream(Device):
    """DEPRECATED: use Device.  Thin compatibility shim preserving the old
    raw-tuple handle API: ``submit`` (and the ``*_async`` helpers, which
    route through it) return ``(engine, record)`` instead of a Future, and
    ``wait``/``poll`` accept those tuples.  Removed after one release."""

    def __init__(self, engines: Optional[Sequence[StreamEngine]] = None):
        warnings.warn(
            "Stream is deprecated; use repro.core.Device (make_device) — "
            "submissions now return Future objects",
            DeprecationWarning, stacklevel=2,
        )
        super().__init__(engines if engines else None, policy="round_robin")

    def submit(self, desc, group: int = 0, wq: int = 0,
               **kw) -> Tuple[StreamEngine, CompletionRecord]:
        # legacy ENQCMD semantics: the old Stream spun on RETRY until the
        # submission landed and never failed, so the shim must not let
        # Device's bounded backoff surface QueueFull to old callers
        while True:
            try:
                fut = super().submit(desc, group=group, wq=wq, **kw)
            except QueueFull:
                continue
            return fut.engine, fut.record


def make_stream(n_instances: int = 1, **cfg_kw) -> Stream:
    """DEPRECATED: use make_device."""
    warnings.warn(
        "make_stream is deprecated; use repro.core.make_device",
        DeprecationWarning, stacklevel=2,
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return Stream(
            [StreamEngine(DeviceConfig.default(**cfg_kw), name=f"dsa{i}")
             for i in range(n_instances)]
        )


# --------------------------------------------------------------------------- DTO
_active: threading.local = threading.local()


@contextlib.contextmanager
def dto_enabled(device: Optional[Device] = None, min_bytes: int = 8192):
    """Transparent offload: inside this context, dto.memcpy/memset/memcmp
    route through the engine for transfers >= min_bytes (the paper's
    CacheLib study offloads >= 8KB — 4.8% of calls, 96.4% of bytes)."""
    prev = getattr(_active, "ctx", None)
    _active.ctx = (device or make_device(), min_bytes)
    try:
        yield _active.ctx[0]
    finally:
        _active.ctx = prev


class dto:
    """memcpy/memset/memcmp interposers (synchronous, like the DTO library)."""

    @staticmethod
    def memcpy(src: jax.Array) -> jax.Array:
        ctx = getattr(_active, "ctx", None)
        if ctx and src.size * src.dtype.itemsize >= ctx[1]:
            return ctx[0].memcpy(src)
        return jnp.array(src)

    @staticmethod
    def memset(x: jax.Array, byte: int = 0) -> jax.Array:
        ctx = getattr(_active, "ctx", None)
        nbytes = x.size * x.dtype.itemsize
        if ctx and nbytes >= ctx[1]:
            word = int.from_bytes(bytes([byte]) * 4, "little")
            d = ctx[0]
            out = d.wait(d.fill_async(jnp.asarray([word], jnp.uint32), nbytes // 4))
            from repro.kernels.ops import from_words

            return from_words(out.reshape(-1), nbytes // 4, x.shape, x.dtype)
        return jnp.full_like(x, 0 if byte == 0 else byte)

    @staticmethod
    def memcmp(a: jax.Array, b: jax.Array) -> bool:
        ctx = getattr(_active, "ctx", None)
        if ctx and a.size * a.dtype.itemsize >= ctx[1]:
            eq, _ = ctx[0].compare(a, b)
            return bool(eq)
        return bool(jnp.array_equal(a, b))
