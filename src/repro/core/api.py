"""Transparent offload (DTO analogue).

The paper ships two software layers above raw descriptors:
  * DML — explicit C/C++ API with async offload and load balancing;
  * DTO — LD_PRELOAD interception of memcpy/memset/memcmp.

The DML-style facade lives in core/device.py: ``Device`` owns N engine
instances behind a pluggable SubmitPolicy and returns ``Future`` objects
from every submit; completion waiting is core/completion.py.  This module
keeps ``dto`` — the drop-in layer: jnp-compatible copy/fill/compare
functions that route through the active Device when one is installed, else
fall back to plain jnp.

The deprecated ``Stream`` / ``make_stream`` shims were REMOVED (they
lasted the promised one release): port to ``make_device`` and Futures —
see docs/api.md, "Migration: Stream -> Device".
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.device import Device, make_device

_REMOVED_SHIMS = ("Stream", "make_stream")


def __getattr__(name: str):
    if name in _REMOVED_SHIMS:
        raise AttributeError(
            f"repro.core.api.{name} was removed: the deprecated Stream shim "
            "API is gone. Use repro.core.make_device / Device — submissions "
            "return Future objects. Migration guide: docs/api.md, "
            "'Migration: Stream -> Device'."
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# --------------------------------------------------------------------------- DTO
_active: threading.local = threading.local()


@contextlib.contextmanager
def dto_enabled(device: Optional[Device] = None, min_bytes: int = 8192):
    """Transparent offload: inside this context, dto.memcpy/memset/memcmp
    route through the engine for transfers >= min_bytes (the paper's
    CacheLib study offloads >= 8KB — 4.8% of calls, 96.4% of bytes)."""
    prev = getattr(_active, "ctx", None)
    _active.ctx = (device or make_device(), min_bytes)
    try:
        yield _active.ctx[0]
    finally:
        _active.ctx = prev


class dto:
    """memcpy/memset/memcmp interposers (synchronous, like the DTO library)."""

    @staticmethod
    def memcpy(src: jax.Array) -> jax.Array:
        ctx = getattr(_active, "ctx", None)
        if ctx and src.size * src.dtype.itemsize >= ctx[1]:
            return ctx[0].memcpy(src)
        return jnp.array(src)

    @staticmethod
    def memset(x: jax.Array, byte: int = 0) -> jax.Array:
        ctx = getattr(_active, "ctx", None)
        nbytes = x.size * x.dtype.itemsize
        if ctx and nbytes >= ctx[1]:
            word = int.from_bytes(bytes([byte]) * 4, "little")
            d = ctx[0]
            out = d.wait(d.fill_async(jnp.asarray([word], jnp.uint32), nbytes // 4))
            from repro.kernels.ops import from_words

            return from_words(out.reshape(-1), nbytes // 4, x.shape, x.dtype)
        return jnp.full_like(x, 0 if byte == 0 else byte)

    @staticmethod
    def memcmp(a: jax.Array, b: jax.Array) -> bool:
        ctx = getattr(_active, "ctx", None)
        if ctx and a.size * a.dtype.itemsize >= ctx[1]:
            eq, _ = ctx[0].compare(a, b)
            return bool(eq)
        return bool(jnp.array_equal(a, b))
