"""High-level engine API (DML analogue) and transparent offload (DTO analogue).

The paper ships two software layers above raw descriptors:
  * DML — explicit C/C++ API with async offload and load balancing;
  * DTO — LD_PRELOAD interception of memcpy/memset/memcmp.

Here ``Stream`` is the DML-style facade (explicit submit/wait over a
StreamEngine, multi-instance round-robin load balancing), and ``dto`` is the
drop-in layer: jnp-compatible copy/fill/compare functions that route
through the engine when one is active, else fall back to plain jnp.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.descriptor import (
    BatchDescriptor,
    CacheHint,
    CompletionRecord,
    OpType,
    Status,
    WorkDescriptor,
)
from repro.core.engine import DeviceConfig, StreamEngine


class Stream:
    """Explicit async API over one or more engine instances (paper Fig. 10:
    multi-instance scaling via round-robin load balancing)."""

    def __init__(self, engines: Optional[Sequence[StreamEngine]] = None):
        self.engines = list(engines) if engines else [StreamEngine()]
        self._next = 0
        self._lock = threading.Lock()

    def _pick(self) -> StreamEngine:
        with self._lock:
            e = self.engines[self._next % len(self.engines)]
            self._next += 1
            return e

    # ------------------------------------------------------------------ async API
    def submit(self, desc, group: int = 0, wq: int = 0) -> Tuple[StreamEngine, CompletionRecord]:
        eng = self._pick()
        status, rec = eng.submit(desc, group=group, wq=wq)
        if status == Status.RETRY:
            # ENQCMD retry loop (paper §3.3)
            while status == Status.RETRY:
                eng.kick()
                status, rec = eng.submit(desc, group=group, wq=wq)
        return eng, rec

    def memcpy_async(self, src: jax.Array, **kw):
        return self.submit(WorkDescriptor(op=OpType.MEMCPY, src=src, **kw))

    def dualcast_async(self, src: jax.Array, **kw):
        return self.submit(WorkDescriptor(op=OpType.DUALCAST, src=src, **kw))

    def fill_async(self, pattern, n_words: int, **kw):
        return self.submit(WorkDescriptor(op=OpType.FILL, pattern=pattern, n_words=n_words, **kw))

    def compare_async(self, a, b, **kw):
        return self.submit(WorkDescriptor(op=OpType.COMPARE, src=a, src2=b, **kw))

    def crc32_async(self, buf, **kw):
        return self.submit(WorkDescriptor(op=OpType.CRC32, src=buf, **kw))

    def delta_create_async(self, src, ref, cap: int = 1024, **kw):
        return self.submit(WorkDescriptor(op=OpType.DELTA_CREATE, src=src, src2=ref, cap=cap, **kw))

    def delta_apply_async(self, ref, offsets, data, **kw):
        return self.submit(
            WorkDescriptor(op=OpType.DELTA_APPLY, src=ref, src_idx=offsets, src2=data, **kw)
        )

    def batch_copy_async(self, src_pool, dst_pool, src_idx, dst_idx, **kw):
        return self.submit(
            WorkDescriptor(
                op=OpType.BATCH_COPY, src=src_pool, dst_pool=dst_pool,
                src_idx=src_idx, dst_idx=dst_idx, **kw,
            )
        )

    def batch_async(self, descriptors: Sequence[WorkDescriptor], **kw):
        return self.submit(BatchDescriptor(descriptors=list(descriptors), **kw))

    # ------------------------------------------------------------------ sync sugar
    def wait(self, handle) -> Any:
        eng, rec = handle
        return eng.wait(rec)

    def poll(self, handle) -> bool:
        eng, rec = handle
        return eng.poll(rec)

    def memcpy(self, src):
        return self.wait(self.memcpy_async(src))

    def crc32(self, buf) -> int:
        return int(self.wait(self.crc32_async(buf)))

    def compare(self, a, b):
        return self.wait(self.compare_async(a, b))

    def delta_create(self, src, ref, cap: int = 1024):
        return self.wait(self.delta_create_async(src, ref, cap=cap))

    def delta_apply(self, ref, offsets, data):
        return self.wait(self.delta_apply_async(ref, offsets, data))

    def drain(self):
        for e in self.engines:
            e.drain()


def make_stream(n_instances: int = 1, **cfg_kw) -> Stream:
    return Stream([StreamEngine(DeviceConfig.default(**cfg_kw), name=f"dsa{i}")
                   for i in range(n_instances)])


# --------------------------------------------------------------------------- DTO
_active: threading.local = threading.local()


@contextlib.contextmanager
def dto_enabled(stream: Optional[Stream] = None, min_bytes: int = 8192):
    """Transparent offload: inside this context, dto.memcpy/memset/memcmp
    route through the engine for transfers >= min_bytes (the paper's
    CacheLib study offloads >= 8KB — 4.8% of calls, 96.4% of bytes)."""
    prev = getattr(_active, "ctx", None)
    _active.ctx = (stream or make_stream(), min_bytes)
    try:
        yield _active.ctx[0]
    finally:
        _active.ctx = prev


class dto:
    """memcpy/memset/memcmp interposers (synchronous, like the DTO library)."""

    @staticmethod
    def memcpy(src: jax.Array) -> jax.Array:
        ctx = getattr(_active, "ctx", None)
        if ctx and src.size * src.dtype.itemsize >= ctx[1]:
            return ctx[0].memcpy(src)
        return jnp.array(src)

    @staticmethod
    def memset(x: jax.Array, byte: int = 0) -> jax.Array:
        ctx = getattr(_active, "ctx", None)
        nbytes = x.size * x.dtype.itemsize
        if ctx and nbytes >= ctx[1]:
            word = int.from_bytes(bytes([byte]) * 4, "little")
            s = ctx[0]
            out = s.wait(s.fill_async(jnp.asarray([word], jnp.uint32), nbytes // 4))
            from repro.kernels.ops import from_words

            return from_words(out.reshape(-1), nbytes // 4, x.shape, x.dtype)
        return jnp.full_like(x, 0 if byte == 0 else byte)

    @staticmethod
    def memcmp(a: jax.Array, b: jax.Array) -> bool:
        ctx = getattr(_active, "ctx", None)
        if ctx and a.size * a.dtype.itemsize >= ctx[1]:
            eq, _ = ctx[0].compare(a, b)
            return bool(eq)
        return bool(jnp.array_equal(a, b))
