"""Work descriptors and completion records (paper §3.2).

A DSA work descriptor is a 64-byte record naming the operation, source /
destination, transfer size, and flags; completion is reported through a
completion record the engine writes when done.  The JAX adaptation keeps the
same programming model: descriptors are small frozen records over jax.Arrays
(SVM analogue — no staging or pinning, the engine reads the arrays the
application already holds), and completion records carry result arrays plus
the modeled device timing.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import Any, Optional, Sequence, Tuple


class OpType(enum.Enum):
    MEMCPY = "memcpy"
    DUALCAST = "dualcast"
    FILL = "fill"
    COMPARE = "compare"
    COMPARE_PATTERN = "compare_pattern"
    CRC32 = "crc32"
    DELTA_CREATE = "delta_create"
    DELTA_APPLY = "delta_apply"
    DIF_INSERT = "dif_insert"
    DIF_CHECK = "dif_check"
    DIF_STRIP = "dif_strip"
    BATCH_COPY = "batch_copy"  # paged batch-descriptor copy
    CACHE_FLUSH = "cache_flush"  # modeled only (no TPU analogue)
    # fused pairs (one kernel launch, one descriptor): the hot-path ops that
    # otherwise always travel together (copy-then-checksum, fill-then-verify)
    COPY_CRC = "copy_crc"  # memcpy + CRC32 in one pass
    FILL_VERIFY = "fill_verify"  # fill + compare_pattern readback in one pass


class Status(enum.Enum):
    PENDING = 0
    RUNNING = 1
    SUCCESS = 2
    ERROR = 3
    RETRY = 4  # SWQ full (ENQCMD retry semantics)
    OVERFLOW = 5  # delta record exceeded capacity


class CacheHint(enum.Enum):
    """G3 destination steering: DDIO-style allocate-in-cache vs memory."""

    TO_MEMORY = 0  # non-allocating write (HBM; invalidate cached copies)
    TO_CACHE = 1  # allocate in cache (VMEM-resident / fused into consumer)


_ids = itertools.count()


@dataclasses.dataclass
class WorkDescriptor:
    op: OpType
    src: Any = None  # jax.Array or tuple of arrays
    src2: Any = None  # second operand (compare/delta ref)
    pattern: Any = None  # fill/compare_pattern pattern words
    n_words: int = 0  # fill length
    cap: int = 1024  # delta record capacity
    cache_hint: CacheHint = CacheHint.TO_MEMORY
    # batch copy:
    dst_pool: Any = None
    src_idx: Any = None
    dst_idx: Any = None
    # buffer locality (paper §4 / Fig. 13): home node of each operand.  None
    # means "wherever the engine runs" — the Device stamps registered homes
    # (or a per-submit ``node=`` hint) before placement, and the engine
    # charges the inter-node link for every operand on a foreign node.
    src_node: Optional[int] = None
    dst_node: Optional[int] = None
    # metadata
    desc_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    priority: int = 0
    # fused-submission width: how many descriptors shared this one's
    # doorbell (submit_many / submit ring).  The engine divides the
    # non-posted ENQCMD round trip by this, so a fused batch of N on a
    # shared WQ pays one round trip total instead of N.
    fused_n: int = 1
    # allocation timestamp: start of the lifecycle "create" span when the
    # descriptor is traced (repro.obs.trace)
    created_t: float = dataclasses.field(default_factory=time.perf_counter,
                                         repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        # Degenerate operands (empty pools, dtype-less duck types) size to 0
        # rather than raising: desclint flags them as DESC106, and sizing is
        # used on telemetry paths that must never throw.
        if self.op in (OpType.FILL, OpType.FILL_VERIFY):
            return max(self.n_words, 0) * 4
        if self.op == OpType.BATCH_COPY and self.src is not None:
            itemsize = getattr(getattr(self.src, "dtype", None), "itemsize", None)
            shape = getattr(self.src, "shape", None)
            idx_shape = getattr(self.src_idx, "shape", None)
            if itemsize is None or not shape or shape[0] == 0 or not idx_shape:
                return 0
            per = int(self.src.size * itemsize // shape[0])
            return per * int(idx_shape[0])
        if self.src is not None and hasattr(self.src, "size"):
            itemsize = getattr(getattr(self.src, "dtype", None), "itemsize", None)
            if itemsize is None:
                return 0
            return int(self.src.size * itemsize)
        return 0


@dataclasses.dataclass
class BatchDescriptor:
    """F2: one submission carrying many work descriptors.  The engine fuses
    homogeneous copy batches into a single batch-copy kernel launch; mixed
    batches are processed back-to-back under one completion record."""

    descriptors: Sequence[WorkDescriptor]
    # batch-level locality: the dominant home nodes across members (stamped
    # by the Device alongside each member's own src_node/dst_node)
    src_node: Optional[int] = None
    dst_node: Optional[int] = None
    desc_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    priority: int = 0
    created_t: float = dataclasses.field(default_factory=time.perf_counter,
                                         repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        return sum(d.nbytes for d in self.descriptors)


@dataclasses.dataclass
class CompletionRecord:
    desc_id: int
    status: Status = Status.PENDING
    op: Optional[str] = None  # op name ("memcpy", "batch", ...) for telemetry
    result: Any = None  # op-specific payload (arrays / scalars)
    bytes_processed: int = 0
    modeled_time_us: float = 0.0  # perfmodel estimate on the target TPU
    wall_time_us: float = 0.0  # measured host time (interpret mode)
    error: Optional[str] = None
    # WQ QoS attribution (paper Fig. 9 / Fig. 12): which WQ dispatched the
    # descriptor, how long it sat queued, and where completions were steered
    wq: Optional[str] = None
    queue_delay_us: float = 0.0
    steering: Optional[str] = None  # "to_cache" | "to_memory"
    # NUMA placement attribution (paper §4 / Fig. 13): where the servicing
    # engine lives, the operands' home nodes, and how many inter-node link
    # crossings the transfer was charged (0 = fully local)
    engine_node: int = 0
    src_node: int = 0
    dst_node: int = 0
    link_hops: int = 0
    # lifecycle trace (repro.obs.spans.DescTrace) when the submission was
    # sampled; every resolve/observe path checks ``is not None`` only, so
    # untraced records pay a single attribute read
    trace: Any = dataclasses.field(default=None, repr=False, compare=False)

    def is_done(self) -> bool:
        return self.status in (Status.SUCCESS, Status.ERROR, Status.OVERFLOW)


def next_desc_id() -> int:
    """Allocate a fresh descriptor id from the shared counter (used for
    synthetic records — e.g. traced ``then`` continuations — that must be
    addressable in the trace DAG alongside real descriptors)."""
    return next(_ids)


def op_name(desc) -> str:
    """Telemetry label for a submittable: the op type, or "batch" for a
    multi-descriptor submission."""
    op = getattr(desc, "op", None)
    if op is not None:
        return op.value if isinstance(op, OpType) else str(op)
    return "batch"
