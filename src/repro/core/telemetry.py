"""Engine telemetry — the paper's PCM counterpart (§5: "DSA performance
telemetry functionalities are provided by the PCM library ... inbound-
outbound traffic and request count on each DSA instance").

Counters per engine instance: per-op counts/bytes/latency, WQ occupancy
samples, PE busy fractions, retry totals.  ``report()`` renders the
PCM-style table; ``snapshot()`` returns a dict for programmatic use.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List

from repro.core.engine import StreamEngine


@dataclasses.dataclass
class OpCounter:
    count: int = 0
    bytes: int = 0
    modeled_us: float = 0.0
    wall_us: float = 0.0


class Telemetry:
    """Attach to one or more engines; samples are taken on poll()."""

    def __init__(self, engines: List[StreamEngine]):
        self.engines = engines
        self.ops: Dict[str, Dict[str, OpCounter]] = {
            e.name: defaultdict(OpCounter) for e in engines
        }
        self.wq_samples: Dict[str, List[float]] = {e.name: [] for e in engines}
        self._seen: set = set()
        self.t0 = time.perf_counter()

    def sample(self):
        for e in self.engines:
            occ = [w.occupancy for g in e.config.groups for w in g.wqs]
            self.wq_samples[e.name].append(sum(occ) / max(len(occ), 1))
            for desc_id, rec in list(e.records.items()):
                if desc_id in self._seen or not rec.is_done():
                    continue
                self._seen.add(desc_id)
                # op name from record payload is not retained; bucket by size class
                bucket = _size_bucket(rec.bytes_processed)
                c = self.ops[e.name][bucket]
                c.count += 1
                c.bytes += rec.bytes_processed
                c.modeled_us += rec.modeled_time_us
                c.wall_us += rec.wall_time_us

    def snapshot(self) -> dict:
        self.sample()
        out = {"elapsed_s": time.perf_counter() - self.t0, "engines": {}}
        for e in self.engines:
            retries = sum(w.stats["retried"] for g in e.config.groups for w in g.wqs)
            submitted = sum(w.stats["submitted"] for g in e.config.groups for w in g.wqs)
            samples = self.wq_samples[e.name]
            out["engines"][e.name] = {
                "submitted": submitted,
                "retries": retries,
                "mean_wq_occupancy": sum(samples) / max(len(samples), 1),
                "ops": {
                    k: dataclasses.asdict(v) for k, v in sorted(self.ops[e.name].items())
                },
            }
        return out

    def report(self) -> str:
        snap = self.snapshot()
        lines = [f"engine telemetry ({snap['elapsed_s']:.2f}s)"]
        for name, e in snap["engines"].items():
            lines.append(
                f"  {name}: submitted={e['submitted']} retries={e['retries']} "
                f"wq_occ={e['mean_wq_occupancy']:.2f}"
            )
            for bucket, c in e["ops"].items():
                gbps = c["bytes"] / max(c["modeled_us"] * 1e-6, 1e-12) / 1e9
                lines.append(
                    f"    {bucket:>8s}: n={c['count']:<5d} bytes={c['bytes']:<12d} "
                    f"modeled={c['modeled_us']:.1f}us ({gbps:.1f}GB/s projected)"
                )
        return "\n".join(lines)


def _size_bucket(nbytes: int) -> str:
    if nbytes < 4096:
        return "<4KB"
    if nbytes < 65536:
        return "4-64KB"
    if nbytes < 1 << 20:
        return "64KB-1MB"
    return ">=1MB"
