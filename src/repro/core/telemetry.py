"""Engine telemetry — the paper's PCM counterpart (§5: "DSA performance
telemetry functionalities are provided by the PCM library ... inbound-
outbound traffic and request count on each DSA instance").

This module is the ROLLUP half: ``snapshot()`` aggregates counters into the
per-engine / per-WQ / per-NUMA-node dict the benchmarks read, and
``report()`` renders the PCM-style table.  The per-record accumulation
lives in core/counters.py (``CounterStore``), shared with the live
``repro.obs`` sampler — which is the right tool when you need a TIME
SERIES instead of end-of-run sums (see docs/observability.md).

Counters per engine instance: per-op x size-class counts/bytes/latency, WQ
occupancy samples, retry totals.  When attached to a ``Device``, the
snapshot also attributes submissions per policy decision (which instance
the SubmitPolicy routed each op to, plus backoff pressure) and reports the
completion-wait accounting per WaitPolicy — host-busy vs host-free cycles,
wakes, IRQs, and the measured host-free fraction (the paper's Fig. 11
"umwait fraction", measured instead of assumed).

Memory: ``sample()`` consumes completion records — each resolved record is
counted once and pruned from the engine's ``records`` dict, so telemetry
over a long-running serving loop stays O(in-flight), not O(ops ever
submitted).  Attach ONE record-walking consumer per engine set (a
``Telemetry``, or the ``repro.obs.Sampler`` which reads the engines'
monotonic counters instead and composes fine with one Telemetry); a second
record-walker would miss records the first one pruned — build it with
``prune=False`` if you really need two.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Union

from repro.core.counters import CounterStore, OpCounter, size_bucket
from repro.core.device import Device
from repro.core.engine import StreamEngine

# backwards-compatible aliases (pre-split spellings)
_size_bucket = size_bucket

__all__ = ["Telemetry", "OpCounter", "size_bucket"]


class Telemetry:
    """Attach to a Device (preferred) or a list of engines; samples are
    taken on poll()/sample()."""

    def __init__(self, engines: Union["Device", List[StreamEngine], None] = None,
                 device: Optional["Device"] = None, prune: bool = True):
        if device is None and engines is not None and hasattr(engines, "engines"):
            device = engines  # Telemetry(device) convenience form
        if device is not None:
            self.device = device
            self.engines = list(device.engines)
        else:
            self.device = None
            self.engines = list(engines or [])
        self.store = CounterStore((e.name for e in self.engines), prune=prune)
        self.wq_samples = {e.name: [] for e in self.engines}
        # per-WQ rollups: occupancy samples and completion latency, keyed by
        # WQ name within each engine (Fig. 9 queueing-delay attribution)
        self.per_wq_samples = {
            e.name: {w.name: [] for g in e.config.groups for w in g.wqs}
            for e in self.engines
        }
        self.t0 = time.perf_counter()

    # counter views (same live dicts the store accumulates into), kept for
    # the pre-split attribute spellings
    @property
    def ops(self):
        return self.store.ops

    @property
    def per_wq_ops(self):
        return self.store.per_wq_ops

    @property
    def node_traffic(self):
        return self.store.node_traffic

    def sample(self):
        for e in self.engines:
            occ = [w.occupancy for g in e.config.groups for w in g.wqs]
            self.wq_samples[e.name].append(sum(occ) / max(len(occ), 1))
            for g in e.config.groups:
                for w in g.wqs:
                    self.per_wq_samples[e.name][w.name].append(w.occupancy)
            self.store.drain_engine(e)

    def snapshot(self) -> dict:
        self.sample()
        out = {"elapsed_s": time.perf_counter() - self.t0, "engines": {}}
        for e in self.engines:
            retries = sum(w.stats["retried"] for g in e.config.groups for w in g.wqs)
            submitted = sum(w.stats["submitted"] for g in e.config.groups for w in g.wqs)
            samples = self.wq_samples[e.name]
            wq_rollup = {}
            for g in e.config.groups:
                for w in g.wqs:
                    occ = self.per_wq_samples[e.name][w.name]
                    comp = self.store.per_wq_ops[e.name].get(w.name, OpCounter())
                    wq_rollup[w.name] = {
                        "mode": w.mode,
                        "priority": w.priority,
                        "traffic_class": w.traffic_class,
                        "size": w.size,
                        "submitted": w.stats["submitted"],
                        "retried": w.stats["retried"],
                        "dispatched": w.stats["dispatched"],
                        "mean_occupancy": sum(occ) / max(len(occ), 1),
                        "mean_queue_delay_us": w.mean_queue_delay_us,
                        "completed": comp.count,
                        "bytes": comp.bytes,
                        "modeled_us": comp.modeled_us,
                    }
            out["engines"][e.name] = {
                "submitted": submitted,
                "retries": retries,
                "mean_wq_occupancy": sum(samples) / max(len(samples), 1),
                "wqs": wq_rollup,
                "ops": {
                    k: dataclasses.asdict(v)
                    for k, v in sorted(self.store.ops[e.name].items())
                },
            }
        # per-node rollup: engines grouped by NUMA node, local vs cross-node
        # traffic, and the modeled inter-node link occupancy (link-seconds of
        # cross traffic over wall time).  Sums across nodes equal the device
        # totals — every record lands in exactly one node bucket.
        topo = getattr(self.device, "topology", None) if self.device else None
        if topo is None:
            for e in self.engines:
                topo = getattr(e, "topology", None)
                if topo is not None:
                    break
        link_bw = topo.link.bw if topo is not None and topo.n_nodes > 1 else None
        elapsed = max(out["elapsed_s"], 1e-12)
        out["nodes"] = {}
        for nid in sorted({getattr(e, "node_id", 0) for e in self.engines}):
            nt = dict(self.store.node_traffic.get(nid) or
                      {"local_ops": 0, "local_bytes": 0, "cross_ops": 0,
                       "cross_bytes": 0, "link_bytes": 0})
            nt["engines"] = [e.name for e in self.engines
                             if getattr(e, "node_id", 0) == nid]
            nt["link_occupancy"] = (
                nt["link_bytes"] / link_bw / elapsed if link_bw else 0.0
            )
            out["nodes"][nid] = nt
        if self.device is not None:
            ps = self.device.policy_stats
            out["policy"] = {
                "name": ps["policy"],
                "decisions": dict(ps["decisions"]),
                "decisions_by_op": dict(ps["decisions_by_op"]),
                "backoff_retries": ps["backoff_retries"],
                "queue_full": ps["queue_full"],
            }
            # per-WaitPolicy host-cycle accounting (Fig. 11, measured);
            # copy first: waiters on other threads may add policy buckets
            out["wait"] = {
                name: ws.as_dict()
                for name, ws in sorted(dict(self.device.wait_stats).items())
            }
        return out

    def report(self) -> str:
        snap = self.snapshot()
        lines = [f"engine telemetry ({snap['elapsed_s']:.2f}s)"]
        for name, e in snap["engines"].items():
            lines.append(
                f"  {name}: submitted={e['submitted']} retries={e['retries']} "
                f"wq_occ={e['mean_wq_occupancy']:.2f}"
            )
            for wname, w in e["wqs"].items():
                lines.append(
                    f"    wq {wname:<10s} [{w['mode'][:4]} pri={w['priority']:<2d} "
                    f"{w['traffic_class']}]: disp={w['dispatched']:<5d} "
                    f"retry={w['retried']:<4d} occ={w['mean_occupancy']:.2f} "
                    f"qdelay={w['mean_queue_delay_us']:.1f}us"
                )
            for key, c in e["ops"].items():
                gbps = c["bytes"] / max(c["modeled_us"] * 1e-6, 1e-12) / 1e9
                lines.append(
                    f"    {key:>20s}: n={c['count']:<5d} bytes={c['bytes']:<12d} "
                    f"modeled={c['modeled_us']:.1f}us ({gbps:.1f}GB/s projected)"
                )
        for nid, n in snap.get("nodes", {}).items():
            if len(snap.get("nodes", {})) == 1 and not n["cross_ops"]:
                continue  # flat single-node device: nothing to attribute
            lines.append(
                f"  node {nid} [{', '.join(n['engines'])}]: "
                f"local={n['local_bytes']}B/{n['local_ops']}ops "
                f"cross={n['cross_bytes']}B/{n['cross_ops']}ops "
                f"link_occ={n['link_occupancy']:.1%}"
            )
        pol = snap.get("policy")
        if pol:
            placed = ", ".join(f"{k}={v}" for k, v in sorted(pol["decisions"].items()))
            lines.append(
                f"  policy {pol['name']}: placements [{placed or 'none'}] "
                f"backoff_retries={pol['backoff_retries']} queue_full={pol['queue_full']}"
            )
        for name, w in snap.get("wait", {}).items():
            lines.append(
                f"  wait {name}: waits={w['waits']} polls={w['polls']} "
                f"wakes={w['wakes']} irqs={w['irqs']} "
                f"busy={w['busy_s']*1e3:.2f}ms free={w['free_s']*1e3:.2f}ms "
                f"host_free={w['host_free_frac']:.1%} "
                f"(modeled wake/irq overhead {w['modeled_overhead_s']*1e6:.1f}us)"
            )
        return "\n".join(lines)
