"""The paper's primary contribution as a composable JAX module: a DSA-style
descriptor-programmed streaming engine (see DESIGN.md §2-3)."""
from repro.core.api import Stream, dto, dto_enabled, make_stream
from repro.core.descriptor import (
    BatchDescriptor,
    CacheHint,
    CompletionRecord,
    OpType,
    Status,
    WorkDescriptor,
)
from repro.core.engine import DeviceConfig, GroupConfig, StreamEngine
from repro.core.perfmodel import DEFAULT_MODEL, EngineModel, TIERS
from repro.core.queues import WorkQueue

__all__ = [
    "BatchDescriptor",
    "CacheHint",
    "CompletionRecord",
    "DeviceConfig",
    "DEFAULT_MODEL",
    "EngineModel",
    "GroupConfig",
    "OpType",
    "Status",
    "Stream",
    "StreamEngine",
    "TIERS",
    "WorkDescriptor",
    "WorkQueue",
    "dto",
    "dto_enabled",
    "make_stream",
]
