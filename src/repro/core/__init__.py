"""The paper's primary contribution as a composable JAX module: a DSA-style
descriptor-programmed streaming engine (see DESIGN.md §2-3).

Entry point: ``Device`` / ``make_device`` — policy-driven multi-instance
submission returning ``Future`` objects; completion waiting is pluggable
(``WaitPolicy``: spin / pause / umwait / interrupt) with set-oriented
``wait_any`` / ``wait_all`` / ``as_completed`` on the device.

The deprecated ``Stream`` / ``make_stream`` shims were removed; see
docs/api.md ("Migration: Stream -> Device")."""
from repro.core.api import dto, dto_enabled
from repro.core.completion import (
    WAIT_POLICIES,
    CompletionSet,
    InterruptWait,
    PauseWait,
    SpinWait,
    UmwaitWait,
    WaitPolicy,
    WaitStats,
    WaitTimeout,
    get_wait_policy,
)
from repro.core.descriptor import (
    BatchDescriptor,
    CacheHint,
    CompletionRecord,
    OpType,
    Status,
    WorkDescriptor,
)
from repro.core.device import (
    Device,
    Future,
    LeastLoadedPolicy,
    NumaLocalPolicy,
    Promise,
    QueueFull,
    RoundRobinPolicy,
    StickyPolicy,
    SubmitPolicy,
    get_policy,
    make_device,
)
from repro.core.engine import DeviceConfig, GroupConfig, StreamEngine
from repro.core.perfmodel import DEFAULT_MODEL, EngineModel, TIERS
from repro.core.queues import TRAFFIC_CLASSES, WorkQueue, WQConfig
from repro.core.topology import Link, Node, Topology

__all__ = [
    "BatchDescriptor",
    "CacheHint",
    "CompletionRecord",
    "CompletionSet",
    "Device",
    "DeviceConfig",
    "DEFAULT_MODEL",
    "EngineModel",
    "Future",
    "GroupConfig",
    "InterruptWait",
    "LeastLoadedPolicy",
    "Link",
    "Node",
    "NumaLocalPolicy",
    "OpType",
    "PauseWait",
    "Promise",
    "QueueFull",
    "RoundRobinPolicy",
    "SpinWait",
    "Status",
    "StickyPolicy",
    "StreamEngine",
    "SubmitPolicy",
    "TIERS",
    "Topology",
    "TRAFFIC_CLASSES",
    "UmwaitWait",
    "WAIT_POLICIES",
    "WaitPolicy",
    "WaitStats",
    "WaitTimeout",
    "WorkDescriptor",
    "WorkQueue",
    "WQConfig",
    "dto",
    "dto_enabled",
    "get_policy",
    "get_wait_policy",
    "make_device",
]


def __getattr__(name: str):
    if name in ("Stream", "make_stream"):
        raise AttributeError(
            f"repro.core.{name} was removed: the deprecated Stream shim API "
            "is gone. Use repro.core.make_device / Device — submissions "
            "return Future objects. Migration guide: docs/api.md, "
            "'Migration: Stream -> Device'."
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
