"""The paper's primary contribution as a composable JAX module: a DSA-style
descriptor-programmed streaming engine (see DESIGN.md §2-3).

Entry point: ``Device`` / ``make_device`` — policy-driven multi-instance
submission returning ``Future`` objects.  ``Stream`` / ``make_stream`` are
deprecated one-release shims over Device."""
from repro.core.api import Stream, dto, dto_enabled, make_stream
from repro.core.descriptor import (
    BatchDescriptor,
    CacheHint,
    CompletionRecord,
    OpType,
    Status,
    WorkDescriptor,
)
from repro.core.device import (
    Device,
    Future,
    LeastLoadedPolicy,
    Promise,
    QueueFull,
    RoundRobinPolicy,
    StickyPolicy,
    SubmitPolicy,
    get_policy,
    make_device,
)
from repro.core.engine import DeviceConfig, GroupConfig, StreamEngine
from repro.core.perfmodel import DEFAULT_MODEL, EngineModel, TIERS
from repro.core.queues import TRAFFIC_CLASSES, WorkQueue, WQConfig

__all__ = [
    "BatchDescriptor",
    "CacheHint",
    "CompletionRecord",
    "Device",
    "DeviceConfig",
    "DEFAULT_MODEL",
    "EngineModel",
    "Future",
    "GroupConfig",
    "LeastLoadedPolicy",
    "OpType",
    "Promise",
    "QueueFull",
    "RoundRobinPolicy",
    "Status",
    "StickyPolicy",
    "Stream",
    "StreamEngine",
    "SubmitPolicy",
    "TIERS",
    "TRAFFIC_CLASSES",
    "WorkDescriptor",
    "WorkQueue",
    "WQConfig",
    "dto",
    "dto_enabled",
    "get_policy",
    "make_device",
    "make_stream",
]
