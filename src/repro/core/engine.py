"""The streaming engine: groups, PEs, arbitration, async completion.

Maps the DSA execution pipeline (paper Fig. 1a) onto JAX:

  WQs     -> bounded host-side queues (core/queues.py), provisioned by
             WQConfig (mode, size partition, priority 1-15, traffic class)
  group   -> {WQs, PE slots, read-buffer share} with a priority-weighted
             deficit arbiter (WQ -> group -> engine dispatch, Fig. 9)
  PE      -> an async in-flight kernel dispatch slot; "processing" a
             descriptor = dispatching its Pallas kernel (ops.py); JAX's
             async dispatch gives the overlap the paper gets from hardware
             queueing, and poll()/wait() are the UMWAIT analogue
  batch   -> homogeneous copy batches fuse into ONE batch_copy kernel
             launch (F2); mixed batches run back-to-back under one record

The engine is also a *model*: every completion record carries the projected
TPU time from core/perfmodel.py next to the measured host time, which is
what the paper-figure benchmarks plot.  QoS enters the model in two places:
a shared WQ charges the ENQCMD non-posted round trip per submission, and a
WQ with ``traffic_class="to_cache"`` steers destination writes to the LLC /
VMEM tier (DDIO analogue, Fig. 12).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.analysis import lockcheck as _lockcheck
from repro.core.descriptor import (
    BatchDescriptor,
    CacheHint,
    CompletionRecord,
    OpType,
    Status,
    WorkDescriptor,
    op_name,
)
from repro.core.perfmodel import DEFAULT_MODEL, EngineModel
from repro.core.queues import Submittable, WorkQueue, WQConfig
from repro.kernels import dif as dif_ops
from repro.kernels import ops


def _ready(x) -> bool:
    try:
        return x.is_ready()
    except AttributeError:
        return True


# The PE "fabric": kernel dispatch runs on worker threads so descriptors
# genuinely stream while the submitting thread is parked (XLA:CPU dispatches
# big computations synchronously in the calling thread, which would
# otherwise serialize the engine into the host).  One shared pool — per-PE
# concurrency is already bounded by each group's slot count.
_PE_POOL: Optional[concurrent.futures.ThreadPoolExecutor] = None
_PE_POOL_LOCK = _lockcheck.checked_lock("engine.pe_pool")


def _pe_pool() -> concurrent.futures.ThreadPoolExecutor:
    global _PE_POOL
    with _PE_POOL_LOCK:
        if _PE_POOL is None:
            _PE_POOL = concurrent.futures.ThreadPoolExecutor(
                max_workers=max(os.cpu_count() or 4, 4),
                thread_name_prefix="pe",
            )
        return _PE_POOL


@dataclasses.dataclass
class GroupConfig:
    name: str
    wqs: Sequence[WorkQueue]
    n_pes: int = 1
    read_buffers: int = 8  # QoS knob (modeled: scales small-transfer depth)


@dataclasses.dataclass
class DeviceConfig:
    """Default shape mirrors SPR DSA (Table 2): 8 WQs, 4 PEs per instance."""

    groups: Sequence[GroupConfig] = ()
    interpret: Optional[bool] = None
    model: EngineModel = dataclasses.field(default_factory=lambda: DEFAULT_MODEL)

    @staticmethod
    def default(n_groups: int = 1, wqs_per_group: int = 2, pes_per_group: int = 4,
                wq_size: int = 32, wq_mode: str = "dedicated") -> "DeviceConfig":
        groups = []
        for g in range(n_groups):
            wqs = [
                WorkQueue(f"g{g}wq{i}", mode=wq_mode, size=wq_size)
                for i in range(wqs_per_group)
            ]
            groups.append(GroupConfig(f"group{g}", wqs, n_pes=pes_per_group))
        return DeviceConfig(groups=groups)

    @staticmethod
    def from_wq_configs(wq_configs: Sequence[WQConfig],
                        pes_per_group: int = 4) -> "DeviceConfig":
        """Build the WQ -> group topology from WQCFG records (Fig. 9 sweeps).
        WQs with the same ``group`` index share that group's PEs and compete
        under its priority arbiter; groups are created densely 0..max."""
        if not wq_configs:
            raise ValueError("wq_configs must name at least one WQConfig")
        names = [c.name for c in wq_configs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate WQ names in wq_configs: {names}")
        n_groups = max(c.group for c in wq_configs) + 1
        groups = []
        for g in range(n_groups):
            wqs = [WorkQueue.from_config(c) for c in wq_configs if c.group == g]
            if not wqs:
                raise ValueError(f"wq_configs leaves group {g} empty; "
                                 f"group indices must be dense")
            groups.append(GroupConfig(f"group{g}", wqs, n_pes=pes_per_group))
        return DeviceConfig(groups=groups)


class _PESlot:
    """One in-flight descriptor on a processing engine.

    ``work`` is the PE worker's handle (dispatch runs off-thread); once it
    resolves, ``outputs`` holds the dispatched arrays and retirement waits
    only on their device-side readiness."""

    def __init__(self):
        self.record: Optional[CompletionRecord] = None
        self.work: Optional[concurrent.futures.Future] = None
        self.outputs: Any = None
        self.t0: float = 0.0

    @property
    def busy(self) -> bool:
        return self.record is not None and not self.record.is_done()

    def try_retire(self) -> bool:
        if self.record is None:
            return False
        if self.work is not None:
            if not self.work.done():
                return False
            rec = self.record
            try:
                outputs, nbytes, modeled_us = self.work.result()
            except Exception as e:  # noqa: BLE001 — kernel dispatch failed
                rec.status = Status.ERROR
                rec.error = f"{type(e).__name__}: {e}"
                rec.wall_time_us = (time.perf_counter() - self.t0) * 1e6
                self.record = None
                self.work = None
                self.outputs = None
                return True
            rec.result = outputs
            rec.bytes_processed = nbytes
            rec.modeled_time_us = modeled_us
            self.outputs = outputs
            self.work = None
        leaves = jax.tree.leaves(self.outputs)
        if all(_ready(x) for x in leaves):
            self.record.wall_time_us = (time.perf_counter() - self.t0) * 1e6
            if self.record.status == Status.RUNNING:
                self.record.status = Status.SUCCESS
            self.record = None
            self.outputs = None
            return True
        return False

    def block(self):
        """Host-side block until this slot's descriptor can retire (the
        targeted UMWAIT): join the PE worker, then the dispatched arrays."""
        if self.work is not None:
            self.work.exception()  # wait; failures surface at try_retire
        if self.outputs is not None:
            jax.block_until_ready(jax.tree.leaves(self.outputs))


class StreamEngine:
    """One DSA-instance analogue.

    ``node_id``/``topology`` place the instance on a NUMA node
    (core/topology.py): descriptors whose operands live on a foreign node
    are charged the inter-node link (bandwidth cap + latency per crossing),
    and the node's tier table overrides the global one when set.  The
    defaults (node 0, no topology) are the flat single-domain world."""

    def __init__(self, config: Optional[DeviceConfig] = None, name: str = "dsa0",
                 node_id: int = 0, topology: Optional[Any] = None):
        self.config = config or DeviceConfig.default()
        self.name = name
        self.node_id = node_id
        self.topology = topology
        # only a multi-node fabric charges the link; a single node never does
        self.link = (topology.link if topology is not None
                     and getattr(topology, "n_nodes", 1) > 1 else None)
        self._tiers = (topology.node(node_id).tiers if topology is not None
                       else None)
        # completion listeners (core/completion.py): called with each
        # CompletionRecord as it resolves, so a Device can feed its
        # completion sets without anyone pumping per-record
        self._listeners: List[Any] = []
        self.interpret = (
            self.config.interpret
            if self.config.interpret is not None
            else jax.default_backend() != "tpu"
        )
        self.model = self.config.model
        self._slots: Dict[str, List[_PESlot]] = {
            g.name: [_PESlot() for _ in range(g.n_pes)] for g in self.config.groups
        }
        # hot-path slot recycling: ``_free`` is the ready ring of idle slot
        # objects, ``_active`` the in-flight list.  kick() retires only the
        # active list and dispatches by popping the free ring, so a kick is
        # O(in-flight + dispatched) instead of O(total slots); slot objects
        # are reused forever (``_slots`` stays the full inventory).
        self._free: Dict[str, List[_PESlot]] = {
            g.name: list(self._slots[g.name]) for g in self.config.groups
        }
        self._active: Dict[str, List[_PESlot]] = {
            g.name: [] for g in self.config.groups
        }
        # deficit counters for priority-weighted draining (one per WQ)
        self._credit: Dict[str, Dict[str, float]] = {
            g.name: {w.name: 0.0 for w in g.wqs} for g in self.config.groups
        }
        self.records: Dict[int, CompletionRecord] = {}
        # cheap monotonic counters, bumped once per resolved record in
        # _notify: the repro.obs sampler reads deltas of these each tick —
        # O(engines) per sample — instead of rescanning ``records``.
        # ``completed`` counts every resolution (including errors and failed
        # fences), matching what a record-walking Telemetry counts.
        self.counters: Dict[str, float] = {
            "completed": 0, "errors": 0, "bytes": 0,
            "modeled_us": 0.0, "wall_us": 0.0,
            "local_ops": 0, "local_bytes": 0,
            "cross_ops": 0, "cross_bytes": 0, "link_bytes": 0,
            # submission-side counters: every accepted descriptor bumps
            # ``submitted``; those arriving through a fused doorbell
            # (submit_many / submit ring) also bump ``fused_descs``, with
            # one ``fused_batches`` per doorbell — pcm_repro derives its
            # submits/s and fused-batch-ratio columns from these
            "submitted": 0, "fused_batches": 0, "fused_descs": 0,
        }
        self._counters_lock = _lockcheck.checked_lock("engine.counters")
        # deferred submissions waiting on dependency fences:
        # (desc, group, wq, producer, deps, record)
        self._deferred: List[Tuple[Submittable, int, int, Optional[str], List[Any], CompletionRecord]] = []
        # fence capacity: deferred descriptors hold WQ-adjacent state, so the
        # park list is bounded like a WQ (RETRY past this -> caller backoff)
        self.max_deferred = 4 * sum(
            w.size for g in self.config.groups for w in g.wqs
        )

    # ------------------------------------------------------------------ completion notify
    def add_listener(self, fn) -> None:
        """Register ``fn(record)`` to run when any completion record on this
        engine resolves (success, error, or failed fence)."""
        self._listeners.append(fn)

    def _notify(self, rec: CompletionRecord) -> None:
        if rec.trace is not None:
            # completion-record write instant: ends the completion_write
            # span (every resolve path — success, error, failed fence —
            # funnels through here, like the counters)
            rec.trace.mark("resolved")
        self._count(rec)
        for fn in self._listeners:
            fn(rec)

    def _count(self, rec: CompletionRecord) -> None:
        """Fold one resolved record into the monotonic counters (every
        resolve path funnels through _notify, so each record counts once)."""
        with self._counters_lock:
            c = self.counters
            c["completed"] += 1
            if rec.status == Status.ERROR:
                c["errors"] += 1
            c["bytes"] += rec.bytes_processed
            c["modeled_us"] += rec.modeled_time_us
            c["wall_us"] += rec.wall_time_us
            if rec.link_hops > 0:
                c["cross_ops"] += 1
                c["cross_bytes"] += rec.bytes_processed
                c["link_bytes"] += rec.bytes_processed * rec.link_hops
            else:
                c["local_ops"] += 1
                c["local_bytes"] += rec.bytes_processed

    def _count_submitted(self, n: int, fused: bool) -> None:
        with self._counters_lock:
            c = self.counters
            c["submitted"] += n
            if fused:
                c["fused_batches"] += 1
                c["fused_descs"] += n

    def counters_snapshot(self) -> Dict[str, float]:
        """Point-in-time copy of the monotonic counters (delta-sampling
        safe: values never decrease)."""
        with self._counters_lock:
            return dict(self.counters)

    def _retire(self, slot: "_PESlot") -> bool:
        """try_retire + completion notification (the IRQ/monitored-write
        analogue: fires exactly when the record transitions to done)."""
        rec = slot.record
        if slot.try_retire():
            self._notify(rec)
            return True
        return False

    # ------------------------------------------------------------------ submission
    def wq(self, group: int = 0, wq: int = 0) -> WorkQueue:
        return self.config.groups[group].wqs[wq]

    def resolve_wq(self, group: Optional[int] = None,
                   wq: Union[int, str, None] = None,
                   priority: Optional[int] = None) -> Tuple[int, int]:
        """Map per-submit hints to a (group, wq) index pair.

        ``wq`` as a string selects by WQ name across ALL groups (the name
        wins over ``group``).  ``wq=None`` with a ``priority`` hint picks
        the WQ whose configured priority is nearest the hint (ties toward
        the higher-priority WQ) — the QoS-level steer; an explicit
        ``group=`` pins the priority search to that group (so WQs placed in
        an isolation group never lose submissions to another group's WQs).
        Plain ints keep the PR 1 behaviour; ``group=None`` means group 0
        unless a priority hint widens the search."""
        if isinstance(wq, str):
            for gi, g in enumerate(self.config.groups):
                for wi, w in enumerate(g.wqs):
                    if w.name == wq:
                        return gi, wi
            known = [w.name for g in self.config.groups for w in g.wqs]
            raise KeyError(f"no WQ named {wq!r} on {self.name}; have {known}")
        if wq is None and priority is not None:
            candidates = (
                enumerate(self.config.groups) if group is None
                else [(group, self.config.groups[group])]
            )
            best = min(
                ((gi, wi, w) for gi, g in candidates
                 for wi, w in enumerate(g.wqs)),
                key=lambda t: (abs(t[2].priority - priority), -t[2].priority, t[0], t[1]),
            )
            return best[0], best[1]
        return group or 0, int(wq or 0)

    def submit(self, desc: Submittable, group: Optional[int] = None,
               wq: Union[int, str, None] = None,
               producer: Optional[str] = None,
               after: Optional[Sequence[Any]] = None,
               priority: Optional[int] = None,
               trace: Optional[Any] = None) -> Tuple[Status, CompletionRecord]:
        """Enqueue a descriptor.  ``after`` is a sequence of dependency fences
        (CompletionRecords or anything with ``is_done()``/``status``): the
        descriptor is held back — the DSA batch-fence analogue — and only
        enters its WQ once every dependency has retired.  ``wq`` may be an
        index or a WQ name; ``priority`` steers to the nearest-priority WQ
        when no explicit ``wq`` is given (see resolve_wq).  ``trace`` is
        the submission's lifecycle trace (repro.obs), attached to the
        completion record BEFORE any launch so dispatch/exec marks land
        even when the internal kick runs the descriptor synchronously."""
        group, wq_idx = self.resolve_wq(group, wq, priority)
        after = list(after or ())
        failed = next((d for d in after
                       if d.is_done() and d.status in (Status.ERROR, Status.OVERFLOW)), None)
        if failed is not None:
            rec = CompletionRecord(desc_id=desc.desc_id, status=Status.ERROR,
                                   op=op_name(desc),
                                   error=f"dependency failed: {failed.status.name}",
                                   trace=trace)
            self.records[desc.desc_id] = rec
            self._count_submitted(1, fused=False)
            self._notify(rec)
            return Status.ERROR, rec
        deps = [d for d in after if not d.is_done()]
        if deps:
            if len(self._deferred) >= self.max_deferred:
                # fence list full: same RETRY contract as a full WQ, so the
                # Device layer applies bounded backoff / QueueFull here too
                return Status.RETRY, CompletionRecord(
                    desc_id=desc.desc_id, status=Status.RETRY, op=op_name(desc)
                )
            rec = CompletionRecord(desc_id=desc.desc_id, status=Status.PENDING,
                                   op=op_name(desc), trace=trace)
            if trace is not None:
                # accepted into the fence park list: wq_wait covers the
                # fence hold plus any later WQ residency
                trace.mark("accept")
            self.records[desc.desc_id] = rec
            self._deferred.append((desc, group, wq_idx, producer, deps, rec))
            self._count_submitted(1, fused=False)
            self.kick()
            return Status.PENDING, rec
        status = self.wq(group, wq_idx).submit(desc, producer=producer)
        rec = CompletionRecord(desc_id=desc.desc_id, status=status, op=op_name(desc))
        if status != Status.RETRY:
            rec.trace = trace
            if trace is not None:
                trace.mark("accept")
            self.records[desc.desc_id] = rec
            self._count_submitted(1, fused=False)
        self.kick()
        return status, rec

    def submit_many(self, descs: Sequence[Submittable],
                    group: Optional[int] = None,
                    wq: Union[int, str, None] = None,
                    producer: Optional[str] = None,
                    after: Optional[Sequence[Any]] = None,
                    priority: Optional[int] = None,
                    traces: Optional[Sequence[Any]] = None,
                    records: Optional[Sequence[CompletionRecord]] = None,
                    ) -> List[Tuple[Status, CompletionRecord]]:
        """Fused-doorbell submission: enqueue ``descs`` with ONE WQ lock
        acquisition and ONE arbiter kick (the batched MOVDIR64B/ENQCMD
        analogue).  The whole burst shares one ``after`` fence list —
        DSA batch-fence semantics — and is all-or-nothing: on a full WQ the
        single returned entry is ``(RETRY, rec)`` and nothing was enqueued,
        so the Device layer can back off and resubmit the burst as a unit.

        ``traces`` (parallel to ``descs``) carries per-descriptor lifecycle
        traces so spans stay exactly per-descriptor; ``records`` lets a
        submit ring pass in pre-created CompletionRecords whose Futures are
        already in callers' hands."""
        descs = list(descs)
        if not descs:
            return []
        group, wq_idx = self.resolve_wq(group, wq, priority)
        after = list(after or ())
        traces = list(traces) if traces is not None else [None] * len(descs)
        recs = list(records) if records is not None else [None] * len(descs)

        def bind(rec, desc, status, trace):
            if rec is None:
                rec = CompletionRecord(desc_id=desc.desc_id, status=status,
                                       op=op_name(desc), trace=trace)
            else:
                rec.status = status
                if rec.op is None:
                    rec.op = op_name(desc)
                if trace is not None:
                    rec.trace = trace
            return rec

        out: List[Tuple[Status, CompletionRecord]] = []
        failed = next((d for d in after
                       if d.is_done() and d.status in (Status.ERROR, Status.OVERFLOW)), None)
        if failed is not None:
            # a torn fence fails the whole batch (nothing may launch)
            for desc, trace, rec in zip(descs, traces, recs):
                rec = bind(rec, desc, Status.ERROR, trace)
                rec.error = f"dependency failed: {failed.status.name}"
                self.records[desc.desc_id] = rec
                out.append((Status.ERROR, rec))
            self._count_submitted(len(descs), fused=True)
            for _, rec in out:
                self._notify(rec)
            return out
        deps = [d for d in after if not d.is_done()]
        if deps:
            if len(self._deferred) + len(descs) > self.max_deferred:
                return [(Status.RETRY, CompletionRecord(
                    desc_id=descs[0].desc_id, status=Status.RETRY,
                    op=op_name(descs[0])))]
            for desc, trace, rec in zip(descs, traces, recs):
                rec = bind(rec, desc, Status.PENDING, trace)
                if rec.trace is not None:
                    rec.trace.mark("accept")
                self.records[desc.desc_id] = rec
                # members park individually but keep their fused_n stamp, so
                # the amortized doorbell charge survives the fence hold
                self._deferred.append((desc, group, wq_idx, producer,
                                       list(deps), rec))
                out.append((Status.PENDING, rec))
            self._count_submitted(len(descs), fused=True)
            self.kick()
            return out
        status = self.wq(group, wq_idx).submit_many(descs, producer=producer)
        if status == Status.RETRY:
            return [(Status.RETRY, CompletionRecord(
                desc_id=descs[0].desc_id, status=Status.RETRY,
                op=op_name(descs[0])))]
        for desc, trace, rec in zip(descs, traces, recs):
            rec = bind(rec, desc, Status.PENDING, trace)
            if rec.trace is not None:
                rec.trace.mark("accept")
            self.records[desc.desc_id] = rec
            out.append((Status.PENDING, rec))
        self._count_submitted(len(descs), fused=True)
        self.kick()
        return out

    # ------------------------------------------------------------------ dispatch
    def _pump_deferred(self):
        """Release deferred descriptors whose dependency fences have retired.
        A failed dependency fails the dependent (no silent launch on a torn
        fence); a full WQ keeps the entry deferred for the next kick."""
        still: List[Tuple[Submittable, int, int, Optional[str], List[Any], CompletionRecord]] = []
        for desc, group, wq, producer, deps, rec in self._deferred:
            done = [d for d in deps if d.is_done()]
            failed = next((d for d in done
                           if d.status in (Status.ERROR, Status.OVERFLOW)), None)
            if failed is not None:
                rec.status = Status.ERROR
                rec.error = f"dependency failed: {failed.status.name}"
                self._notify(rec)
                continue
            remaining = [d for d in deps if not d.is_done()]
            if remaining:
                still.append((desc, group, wq, producer, remaining, rec))
                continue
            # each deferred entry targets its own (group, wq) — there is no
            # homogeneous burst to fuse here
            status = self.wq(group, wq).submit(desc, producer=producer)  # dsalint: disable=DSA106
            if status == Status.RETRY:
                still.append((desc, group, wq, producer, [], rec))
        self._deferred = still

    def kick(self):
        """Group arbiters: release retired fences, then move descriptors from
        WQs onto PE slots.  Retirement scans only the in-flight list and
        dispatch pops recycled slot objects off the free ring, so a kick
        costs O(in-flight + dispatched) — an idle or fully-busy engine pays
        nothing per spare slot."""
        if self._deferred:
            self._pump_deferred()
        for g in self.config.groups:
            active = self._active[g.name]
            free = self._free[g.name]
            if active:
                still = []
                for s in active:
                    if self._retire(s):
                        free.append(s)
                    else:
                        still.append(s)
                active[:] = still
            while free:
                picked = self._arbitrate(g)
                if picked is None:
                    break
                desc, src_wq = picked
                slot = free.pop()
                self._launch(slot, desc, src_wq)
                active.append(slot)

    def _arbitrate(self, g: GroupConfig) -> Optional[Tuple[Submittable, WorkQueue]]:
        """Priority-weighted deficit draining (paper Fig. 9 arbiter).

        Each round every backlogged WQ earns credit equal to its priority
        (floor 1); the richest WQ is drained and its credit resets.  A
        priority-15 WQ therefore gets ~15 grants for each grant a
        priority-1 WQ gets, and no backlogged WQ starves — its credit grows
        every round until it wins.  Occupancy breaks ties so fuller WQs
        drain first at equal priority."""
        nonempty = [w for w in g.wqs if len(w)]
        if not nonempty:
            return None
        credits = self._credit[g.name]
        for w in nonempty:
            credits[w.name] += max(w.priority, 1)
        w = max(nonempty, key=lambda w: (credits[w.name], w.occupancy))
        credits[w.name] = 0.0
        desc = w.pop()
        if desc is None:
            return None
        return desc, w

    # ------------------------------------------------------------------ execution
    def _launch(self, slot: _PESlot, desc: Submittable, src_wq: Optional[WorkQueue] = None):
        # descriptors may be enqueued on a WQ directly (raw portal writes);
        # materialize their completion record lazily
        rec = self.records.setdefault(
            desc.desc_id, CompletionRecord(desc_id=desc.desc_id, op=op_name(desc))
        )
        if rec.op is None:
            rec.op = op_name(desc)
        rec.status = Status.RUNNING
        sn, dn, hops = self._locality(desc)
        rec.engine_node = self.node_id
        rec.src_node = sn
        rec.dst_node = dn
        rec.link_hops = hops
        dst_tier = "hbm"
        enqcmd_s = 0.0
        if src_wq is not None:
            rec.wq = src_wq.name
            rec.queue_delay_us = src_wq.last_queue_delay_us
            rec.steering = src_wq.traffic_class
            if src_wq.traffic_class == "to_cache":
                dst_tier = "vmem"
            if src_wq.mode == "shared":
                # fused-doorbell amortization (paper Fig. 3 / G1): a burst
                # of N descriptors submitted through one doorbell pays one
                # non-posted ENQCMD round trip total, i.e. 1/N each
                fused_n = max(int(getattr(desc, "fused_n", 1) or 1), 1)
                enqcmd_s = self.model.enqcmd_overhead_s / fused_n
        slot.record = rec
        slot.t0 = time.perf_counter()
        slot.outputs = None
        tr = rec.trace
        if tr is not None:
            tr.mark("dispatch")
            tr.attrs.setdefault("engine", self.name)
            if src_wq is not None:
                tr.attrs.setdefault("wq", src_wq.name)

        def work(desc=desc, dst_tier=dst_tier, enqcmd_s=enqcmd_s, tr=tr):
            # runs on a PE worker thread: the dispatch (and, on platforms
            # where XLA dispatches synchronously, the whole kernel) happens
            # off the submitting thread, so a parked host is genuinely free
            if tr is not None:
                tr.mark("exec0")
            if isinstance(desc, BatchDescriptor):
                outputs, nbytes, modeled = self._execute_batch(desc, dst_tier=dst_tier)
            else:
                outputs, nbytes, modeled = self._execute_one(desc, dst_tier=dst_tier)
            if tr is not None:
                tr.mark("exec1")
            return outputs, nbytes, (modeled + enqcmd_s) * 1e6

        slot.work = _pe_pool().submit(work)

    def _locality(self, desc) -> Tuple[int, int, int]:
        """Resolve a submittable's (src_node, dst_node, link_hops) relative
        to this engine: an unstamped operand is wherever the engine runs."""
        sn = getattr(desc, "src_node", None)
        dn = getattr(desc, "dst_node", None)
        sn = self.node_id if sn is None else sn
        dn = self.node_id if dn is None else dn
        hops = int(sn != self.node_id) + int(dn != self.node_id)
        return sn, dn, hops

    def _model_kw(self, kw: dict, dst_tier: str, hops: int) -> dict:
        """Locality-aware op_time defaults: node tier table + link charge."""
        kw.setdefault("dst_tier", dst_tier)
        if self._tiers is not None:
            kw.setdefault("tiers", self._tiers)
        if hops and self.link is not None:
            kw.setdefault("link", self.link)
            kw.setdefault("link_hops", hops)
        return kw

    def _execute_one(self, d: WorkDescriptor, dst_tier: str = "hbm"):
        it = self.interpret
        m = self.model
        nbytes = d.nbytes
        # per-descriptor TO_CACHE hints steer like a to_cache WQ (G3)
        if d.cache_hint == CacheHint.TO_CACHE:
            dst_tier = "vmem"
        _, _, hops = self._locality(d)

        def t_op(nb, **kw):
            return m.op_time(nb, **self._model_kw(kw, dst_tier, hops))

        if d.op == OpType.MEMCPY:
            out = ops.memcpy(d.src, interpret=it)
            t = t_op(nbytes)
        elif d.op == OpType.DUALCAST:
            out = ops.dualcast(d.src, interpret=it)
            t = t_op(nbytes, read_factor=1.5)
        elif d.op == OpType.FILL:
            out = ops.fill(jnp.asarray(d.pattern, jnp.uint32), d.n_words, interpret=it)
            t = t_op(nbytes, read_factor=0.5)  # write-only
        elif d.op == OpType.COMPARE:
            out = ops.compare(d.src, d.src2, interpret=it)
            t = t_op(nbytes)
        elif d.op == OpType.COMPARE_PATTERN:
            out = ops.compare_pattern(d.src, jnp.asarray(d.pattern, jnp.uint32), interpret=it)
            t = t_op(nbytes, read_factor=0.5)
        elif d.op == OpType.CRC32:
            out = ops.crc32(d.src, interpret=it)
            t = t_op(nbytes, read_factor=0.5)
        elif d.op == OpType.DELTA_CREATE:
            out = ops.delta_create(d.src, d.src2, cap=d.cap, interpret=it)
            t = t_op(nbytes)
        elif d.op == OpType.DELTA_APPLY:
            out = ops.delta_apply(d.src, d.src_idx, d.src2, interpret=it)
            t = t_op(nbytes)
        elif d.op == OpType.DIF_INSERT:
            out = dif_ops.dif_insert(d.src, interpret=it)
            t = t_op(nbytes)
        elif d.op == OpType.DIF_CHECK:
            out = dif_ops.dif_check(d.src, interpret=it)
            t = t_op(nbytes, read_factor=0.5)
        elif d.op == OpType.DIF_STRIP:
            out = dif_ops.dif_strip(d.src)
            t = t_op(nbytes)
        elif d.op == OpType.BATCH_COPY:
            out = ops.batch_copy(d.src, d.dst_pool, d.src_idx, d.dst_idx, interpret=it)
            t = t_op(nbytes, batch_size=int(d.src_idx.shape[0]))
        elif d.op == OpType.COPY_CRC:
            # fused memcpy+CRC32: one launch, one read pass feeding both the
            # write stream and the checksum — vs two launches and two read
            # passes (memcpy at 1.0 + crc32 at 0.5) unfused
            out = ops.copy_crc(d.src, interpret=it)
            t = t_op(nbytes)
        elif d.op == OpType.FILL_VERIFY:
            # fused fill+compare_pattern: the verify reads the tile just
            # written in-kernel, so the pair costs one fill (0.5) instead of
            # fill + compare_pattern (0.5 + 0.5) across two launches
            out = ops.fill_verify(jnp.asarray(d.pattern, jnp.uint32),
                                  d.n_words, interpret=it)
            t = t_op(nbytes, read_factor=0.5)
        elif d.op == OpType.CACHE_FLUSH:
            out = ()  # no TPU analogue (DESIGN.md); modeled only
            t = t_op(nbytes, read_factor=0.5)
        else:
            raise ValueError(f"unsupported op {d.op}")
        return out, nbytes, t

    def _execute_batch(self, b: BatchDescriptor, dst_tier: str = "hbm"):
        descs = list(b.descriptors)
        # F2 fusion: homogeneous same-shape copies -> ONE batch_copy launch.
        # Fuse only when per-descriptor flags agree: a mixed cache-hint batch
        # or an explicit destination pool would be silently dropped by the
        # fused kernel (it writes a fresh zeroed pool), so those fall back to
        # the unfused per-descriptor path.
        if (
            len(descs) > 1
            and all(d.op == OpType.MEMCPY for d in descs)
            and all(d.dst_pool is None for d in descs)
            and len({d.cache_hint for d in descs}) == 1
            and len({(d.src.shape, str(d.src.dtype)) for d in descs}) == 1
        ):
            if descs[0].cache_hint == CacheHint.TO_CACHE:
                dst_tier = "vmem"
            pool = jnp.stack([d.src for d in descs])
            idx = jnp.arange(len(descs), dtype=jnp.int32)
            out = ops.batch_copy(pool, jnp.zeros_like(pool), idx, idx, interpret=self.interpret)
            nbytes = b.nbytes
            _, _, hops = self._locality(b)
            t = self.model.op_time(descs[0].nbytes,
                                   **self._model_kw({"batch_size": len(descs)},
                                                    dst_tier, hops))
            return list(out), nbytes, t
        outs = []
        nbytes = 0
        t = self.model.launch_overhead_s
        for d in descs:
            o, nb, td = self._execute_one(d, dst_tier=dst_tier)
            outs.append(o)
            nbytes += nb
            t += td - self.model.launch_overhead_s + self.model.submit_overhead_s
        return outs, nbytes, t

    # ------------------------------------------------------------------ completion
    def poll(self, rec: CompletionRecord) -> bool:
        self.kick()
        return rec.is_done()

    def _recycle(self, gname: str, slot: _PESlot) -> bool:
        """Retire one in-flight slot and return it to the free ring (the
        blocking-wait counterpart of kick()'s active-list sweep)."""
        if self._retire(slot):
            self._active[gname].remove(slot)
            self._free[gname].append(slot)
            return True
        return False

    def wait(self, rec: CompletionRecord):
        """UMWAIT analogue: block until the completion record resolves."""
        while not rec.is_done():  # dsalint: disable=DSA103 — this IS the raw wait primitive WaitPolicy builds on
            self.kick()
            if rec.status == Status.RUNNING:
                for gname, active in self._active.items():
                    for s in list(active):
                        if s.record is rec:
                            s.block()
                            self._recycle(gname, s)
        self.kick()
        return rec.result

    def drain(self):
        """Run until WQs, PE slots, AND locally-resolvable fences are empty.
        Deferred descriptors whose dependencies live on another engine are
        left for Device.drain(), which pumps every instance."""
        while (  # dsalint: disable=DSA103 — engine drain is the terminal pump
            any(len(w) for g in self.config.groups for w in g.wqs)
            or any(s.busy for active in self._active.values() for s in active)
            or any(all(d.is_done() for d in deps) for *_, deps, _rec in self._deferred)
        ):
            self.kick()
            for gname, active in self._active.items():
                for s in list(active):
                    if s.busy:
                        s.block()
                        self._recycle(gname, s)
