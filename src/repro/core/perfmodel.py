"""Analytical performance model — the paper's §4 quantitative analysis
re-derived for TPU v5e (DESIGN.md §5).

The paper's offload-crossover algebra survives the hardware swap; only the
constants change:

  DSA (SPR)                      ->  TPU v5e adaptation
  ENQCMD/MOVDIR64B ~100s ns      ->  kernel launch/dispatch  ~4 us
  30 GB/s per-instance fabric    ->  819 GB/s HBM (copy: read+write = /2)
  DDR local/remote, CXL tiers    ->  HBM / remote-pod ICI / host DRAM tiers
  PE count per group             ->  parallel DMA lanes in the kernel grid
  WQ depth (async in-flight)     ->  async dispatch depth

Every benchmark (benchmarks/) reports BOTH the measured interpret-mode
timing of our kernels and this model's projection; EXPERIMENTS.md
§Paper-validation checks the model reproduces the SHAPES of paper
Figs. 2-5, 7, 9, 10, 14 (crossover points, batch amortization, PE scaling,
instance scaling, saturation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

# memory tiers: sustained read/write bandwidth (B/s) + extra one-way latency.
# The host tier is read-fast / write-slow like the paper's CXL device (G4:
# prefer the faster-WRITE tier as destination).
TIERS: Dict[str, Dict[str, float]] = {
    "hbm": {"bw": 819e9, "wr_bw": 819e9, "lat": 0.0},  # paper: local DRAM
    "vmem": {"bw": 3.2e12, "wr_bw": 3.2e12, "lat": -2e-6},  # paper: LLC (G3);
    #   negative latency models the skipped HBM round-trip under TO_CACHE
    "remote": {"bw": 100e9, "wr_bw": 100e9, "lat": 2e-6},  # other pod via ICI
    "host": {"bw": 32e9, "wr_bw": 24e9, "lat": 10e-6},  # host DRAM over PCIe
}


@dataclasses.dataclass(frozen=True)
class EngineModel:
    """Mechanisms (all from the paper, constants re-derived for TPU v5e):

    * per-descriptor processing RAMPS with transfer size (address translation
      + read-buffer fill latency): bw(s) = peak * s / (s + ramp_bytes);
    * one PE sustains only ``per_pe_frac`` of the pair bandwidth (finite read
      buffers — §3.4); a GROUP pools PEs, and in-flight descriptors (batch or
      async streaming) spread across them (paper: "a descriptor at the head
      of a WQ is eligible for any free PE");
    * launch overhead amortizes over async depth (G2) and batch size (G1).

    The software baseline differs fundamentally from the paper's: an XLA copy
    on TPU is already memory-bound (~300 GB/s), unlike a CPU core's ~10 GB/s
    — so the LARGE-transfer speedup on TPU is ~1.2-1.4x, and the paper's
    2-27x speedups translate into pipeline-occupancy savings (Fig. 11
    umwait fraction) + VMEM non-pollution (G3).  EXPERIMENTS.md §Paper-
    validation quantifies which claims transfer and which shift.
    """

    launch_overhead_s: float = 4e-6  # one pallas_call dispatch (ENQCMD analogue)
    submit_overhead_s: float = 0.3e-6  # per-descriptor prep/submit on host
    # extra non-posted round trip a SHARED WQ pays per submission (ENQCMD
    # returns a carry flag; MOVDIR64B on a dedicated WQ is posted and pays
    # nothing).  Paper §3.2: ~3x the posted submit cost at low thread counts.
    enqcmd_overhead_s: float = 0.9e-6
    completion_poll_s: float = 0.2e-6  # completion-record check (UMWAIT analogue)
    # completion-wait constants (paper Fig. 11 / "choose your wait scheme"):
    # PAUSE keeps the core busy but throttles the poll loop; UMWAIT parks the
    # core (C0.2) and pays an exit latency on the monitored write; an
    # interrupt frees the core entirely but costs delivery + handler +
    # reschedule per (coalesced) completion group.
    pause_poll_s: float = 0.1e-6  # one PAUSE-throttled poll iteration
    umwait_wake_s: float = 0.5e-6  # C0.2 exit latency on the completion write
    irq_cost_s: float = 4e-6  # interrupt delivery + handler + context switch
    pe_peak_bw: float = 819e9 / 2  # HBM copy roofline (rd+wr)
    pe_ramp_bytes: float = 32e3  # half-saturation transfer size per descriptor
    per_pe_frac: float = 0.75  # single-PE sustained fraction (read buffers)
    max_pes: int = 4  # per DSA instance (paper Table 2)
    sw_memcpy_bw: float = 300e9  # XLA fused copy through the compute pipeline
    sw_launch_s: float = 2e-6  # XLA dispatch overhead

    # ------------------------------------------------------------------ engine
    def _pair_bw(self, src_tier: str, dst_tier: str,
                 tiers: Optional[Dict[str, Dict[str, float]]] = None) -> float:
        t = TIERS if tiers is None else tiers
        if src_tier == dst_tier == "hbm":
            return self.pe_peak_bw
        return min(t[src_tier]["bw"], t[dst_tier]["wr_bw"])

    def op_time(
        self,
        nbytes: float,
        *,
        batch_size: int = 1,
        n_pe: int = 1,
        async_depth: int = 1,
        src_tier: str = "hbm",
        dst_tier: str = "hbm",
        read_factor: float = 1.0,  # dualcast reads once, writes twice => 1.5x
        tiers: Optional[Dict[str, Dict[str, float]]] = None,  # per-node override
        link: Optional[Any] = None,  # inter-node Link (topology.py): bw + lat_s
        link_hops: int = 0,  # crossings: remote src/dst count 1 each (§4 / Fig. 13)
    ) -> float:
        """Seconds to complete ONE submission of ``batch_size`` descriptors of
        ``nbytes`` each.

        ``tiers`` overrides the global tier table (a NUMA node's local
        memory); ``link``/``link_hops`` charge cross-node placement: each
        crossing caps the pair bandwidth at ``link.bw / hops`` (the shared
        UPI/ICI analogue — an engine remote from both buffers crosses
        twice) and adds ``link.lat_s`` of one-way latency per hop, so any
        remote placement is strictly slower than all-local at every size.
        """
        t = TIERS if tiers is None else {**TIERS, **tiers}
        base = self._pair_bw(src_tier, dst_tier, t)
        if link is not None and link_hops > 0:
            base = min(base, link.bw / link_hops)
        pair = base / read_factor
        ramp = nbytes / (nbytes + self.pe_ramp_bytes)
        # in-flight descriptors (batch members and async stream) spread over PEs
        concurrent = min(batch_size * max(async_depth, 1), n_pe)
        agg_bw = min(concurrent * self.per_pe_frac * ramp, 1.0) * pair
        lat = max(t[src_tier]["lat"] + t[dst_tier]["lat"], 0.0)
        if link is not None and link_hops > 0:
            lat += link_hops * link.lat_s
        launch = self.launch_overhead_s / max(async_depth, 1) + lat / max(async_depth, 1)
        submit = self.submit_overhead_s * batch_size + self.completion_poll_s
        return launch + submit + batch_size * nbytes / agg_bw

    def throughput(self, nbytes: float, **kw) -> float:
        bs = kw.get("batch_size", 1)
        return bs * nbytes / self.op_time(nbytes, **kw)

    def op_time_default_pes(self, nbytes: float, **kw) -> float:
        """op_time with the default group shape (all 4 PEs pooled)."""
        kw.setdefault("n_pe", self.max_pes)
        return self.op_time(nbytes, **kw)

    # ------------------------------------------------------------------ baseline "core"
    def sw_time(self, nbytes: float, *, src_tier: str = "hbm", dst_tier: str = "hbm") -> float:
        bw = min(self.sw_memcpy_bw, TIERS[src_tier]["bw"], TIERS[dst_tier]["bw"])
        return self.sw_launch_s + nbytes / bw

    def sw_throughput(self, nbytes: float, **kw) -> float:
        return nbytes / self.sw_time(nbytes, **kw)

    def speedup(self, nbytes: float, **kw) -> float:
        return self.throughput(nbytes, **kw) / self.sw_throughput(
            nbytes, src_tier=kw.get("src_tier", "hbm"), dst_tier=kw.get("dst_tier", "hbm")
        )

    def crossover_bytes(self, **kw) -> float:
        """Smallest transfer where engine >= software (paper: ~4KB sync,
        ~256B async on DSA)."""
        lo, hi = 64.0, 1 << 30
        for _ in range(60):
            mid = (lo + hi) / 2
            if self.speedup(mid, **kw) >= 1.0:
                hi = mid
            else:
                lo = mid
        return hi


DEFAULT_MODEL = EngineModel()
