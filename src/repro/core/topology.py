"""NUMA-node topology: the paper's §4 cross-socket analysis as a first-class
layer.

The paper shows DSA throughput collapses when the engine, the source, or the
destination sits on a remote socket: every cross-socket segment caps
bandwidth at the UPI link and adds its latency, so the guideline is "keep
the accelerator and BOTH buffers NUMA-local".  This module models that axis
for the TPU adaptation (UPI -> inter-node ICI):

  Node      one NUMA domain: its engine instances and (optionally) its own
            memory-tier table overriding the global ``perfmodel.TIERS``.
  Link      the inter-node interconnect: a bandwidth cap plus added one-way
            latency, charged once per crossing segment.
  Topology  the fabric: N nodes + the link between them, with the hop
            arithmetic ``EngineModel.op_time`` charges cross-node transfers
            with.  ``Topology.single_node()`` is the default everywhere, so
            every pre-existing single-domain call site behaves identically.

Hop counting follows the paper's data path: the engine READS the source and
WRITES the destination, so a transfer crosses the link once per operand that
lives on a different node than the engine — remote source or remote
destination is 1 hop; an engine remote from both buffers (even co-located
ones) pays 2 crossings, the worst placement in the paper's Fig. 13 sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Link:
    """Inter-node interconnect (UPI / cross-pod ICI analogue): ``bw`` is the
    per-direction bandwidth cap shared by all crossings, ``lat_s`` the added
    one-way latency per crossing."""

    bw: float = 150e9  # < single-PE sustained HBM copy, so remote always caps
    lat_s: float = 0.8e-6

    def __post_init__(self):
        if self.bw <= 0:
            raise ValueError(f"Link.bw must be > 0, got {self.bw}")
        if self.lat_s < 0:
            raise ValueError(f"Link.lat_s must be >= 0, got {self.lat_s}")


@dataclasses.dataclass(frozen=True)
class Node:
    """One NUMA domain: ``n_engines`` DSA-style instances plus an optional
    memory-tier override (entries merge over ``perfmodel.TIERS``, so a node
    can e.g. model slower local DRAM without redefining every tier)."""

    node_id: int
    n_engines: int = 1
    name: str = ""
    tiers: Optional[Dict[str, Dict[str, float]]] = None

    def __post_init__(self):
        if self.node_id < 0:
            raise ValueError(f"Node.node_id must be >= 0, got {self.node_id}")
        if self.n_engines < 1:
            raise ValueError(f"Node.n_engines must be >= 1, got {self.n_engines}")

    @property
    def label(self) -> str:
        return self.name or f"node{self.node_id}"


class Topology:
    """The device fabric: nodes and the link joining them.

    Node ids must be dense 0..N-1 (engines, pools, and telemetry index by
    them).  A 1-node topology never charges the link, which is what makes
    it a drop-in default for every legacy single-domain call site.
    """

    def __init__(self, nodes: Sequence[Node], link: Link = Link()):
        nodes = list(nodes)
        if not nodes:
            raise ValueError("Topology needs at least one Node")
        if sorted(n.node_id for n in nodes) != list(range(len(nodes))):
            raise ValueError(
                f"Node ids must be dense 0..{len(nodes) - 1}, "
                f"got {[n.node_id for n in nodes]}"
            )
        self.nodes: List[Node] = sorted(nodes, key=lambda n: n.node_id)
        self.link = link

    # ------------------------------------------------------------------ builders
    @staticmethod
    def single_node(n_engines: int = 1) -> "Topology":
        """The flat pre-topology world: one node, no link charges."""
        return Topology([Node(0, n_engines=n_engines)])

    @staticmethod
    def symmetric(n_nodes: int, engines_per_node: int = 1,
                  link: Link = Link()) -> "Topology":
        """N identical nodes over one link (dual-socket SPR analogue at
        ``n_nodes=2``)."""
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        return Topology(
            [Node(i, n_engines=engines_per_node) for i in range(n_nodes)], link
        )

    # ------------------------------------------------------------------ geometry
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def node(self, node_id: int) -> Node:
        return self.nodes[node_id]

    def hops(self, engine_node: int, src_node: int, dst_node: int) -> int:
        """Link crossings for one transfer: the engine reads src and writes
        dst, so each operand on a foreign node costs one crossing."""
        return int(src_node != engine_node) + int(dst_node != engine_node)

    def link_charge(self, engine_node: int, src_node: int,
                    dst_node: int) -> Dict[str, object]:
        """kwargs for ``EngineModel.op_time``: the link and how many times
        this placement crosses it (empty dict when fully local)."""
        h = self.hops(engine_node, src_node, dst_node)
        if h == 0 or self.n_nodes == 1:
            return {}
        return {"link": self.link, "link_hops": h}

    def engine_nodes(self) -> List[int]:
        """Flat node-id list, one entry per engine instance, in build order
        (node-major) — how a Device assigns ``StreamEngine.node_id``."""
        out: List[int] = []
        for n in self.nodes:
            out.extend([n.node_id] * n.n_engines)
        return out

    def __repr__(self) -> str:
        shape = "+".join(str(n.n_engines) for n in self.nodes)
        return (f"Topology({self.n_nodes} nodes x [{shape}] engines, "
                f"link={self.link.bw / 1e9:.0f}GB/s +{self.link.lat_s * 1e6:.1f}us)")
