"""First-class submission futures and the policy-driven Device facade.

The paper's software lesson (§3.3, §5) is that DSA pays off only when
offload is *asynchronous* and completion handling is cheap: ENQCMD retry
must be bounded, descriptors inside a batch can be ordered with fences, and
throughput scales by balancing submissions across instances (Fig. 10).
This module makes each of those a first-class API object:

  Future        one submitted descriptor: owns its engine + completion
                record, supports wait()/poll()/result()/then()/callbacks,
                and can be passed as ``after=`` to any submit to express a
                dependency fence (the engine defers launch until every
                parent retires).
  Promise       an externally-completed Future (``device.promise()``) —
                a software fence for gating submissions on host events.
  SubmitPolicy  pluggable instance selection: round_robin, least_loaded
                (by WQ occupancy), sticky (per-producer affinity).
  WaitPolicy    pluggable completion waiting (core/completion.py): spin /
                pause / umwait / interrupt, selectable per device and per
                wait; ``wait_any``/``wait_all``/``as_completed`` drive one
                policy loop over a whole set of futures, fed by engine
                completion notifications instead of per-Future pumping.
  Device        the top-level entry point: owns N StreamEngine instances,
                applies the policy per submission, and converts ENQCMD
                RETRY into bounded exponential backoff ending in
                ``QueueFull`` instead of an unbounded spin.
"""
from __future__ import annotations

import threading
import time
import weakref
import zlib
from collections import Counter, defaultdict, deque
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import jax

from repro.analysis import lockcheck as _lockcheck
from repro.core import completion as _completion
from repro.core.completion import WaitPolicy, WaitStats, get_wait_policy
from repro.core.descriptor import (
    BatchDescriptor,
    CompletionRecord,
    OpType,
    Status,
    WorkDescriptor,
    next_desc_id,
    op_name,
)
from repro.core.engine import DeviceConfig, StreamEngine
from repro.core.queues import Submittable, WQConfig
from repro.core.topology import Topology


class QueueFull(RuntimeError):
    """All backoff attempts exhausted: every eligible WQ kept returning
    RETRY (ENQCMD's carry flag).  Carries the engine and attempt count so
    callers can rebalance or shed load instead of spinning forever."""

    def __init__(self, engine_name: str, attempts: int):
        super().__init__(
            f"work queue full on {engine_name} after {attempts} submission "
            f"attempts with exponential backoff"
        )
        self.engine_name = engine_name
        self.attempts = attempts


# --------------------------------------------------------------------------- futures
class Future:
    """Handle for one in-flight descriptor: engine + completion record,
    completion callbacks, and chaining.  Replaces the raw (engine, record)
    tuples of the old Stream API."""

    def __init__(self, device: Optional["Device"], engine: Optional[StreamEngine],
                 record: CompletionRecord):
        self.device = device
        self.engine = engine
        self.record = record
        self._callbacks: List[Callable[["Future"], None]] = []
        self._fired = False
        self._cb_lock = _lockcheck.checked_lock("future.callbacks")

    # -- state ---------------------------------------------------------------
    @property
    def status(self) -> Status:
        return self.record.status

    @property
    def op(self) -> Optional[str]:
        return self.record.op

    @property
    def error(self) -> Optional[str]:
        return self.record.error

    # -- WQ QoS attribution (stamped at dispatch; None/0 until then) ---------
    @property
    def wq(self) -> Optional[str]:
        return self.record.wq

    @property
    def queue_delay_us(self) -> float:
        return self.record.queue_delay_us

    @property
    def steering(self) -> Optional[str]:
        return self.record.steering

    # -- lifecycle trace (repro.obs; None when the submission was not sampled)
    @property
    def trace(self) -> Optional[Any]:
        return self.record.trace

    @property
    def trace_id(self) -> Optional[str]:
        tr = self.record.trace
        return tr.trace_id if tr is not None else None

    def done(self) -> bool:
        """Non-kicking completion check."""
        return self.record.is_done()

    # queues.py / engine fences duck-type on is_done(), so a Future can be a
    # dependency anywhere a CompletionRecord can
    def is_done(self) -> bool:
        return self.done()

    # -- progress ------------------------------------------------------------
    def _pump(self):
        if self.device is not None:
            self.device.kick()
        elif self.engine is not None:
            self.engine.kick()

    def poll(self) -> bool:
        """Kick the engine(s), then report completion; fires callbacks on the
        transition to done (the UMWAIT-poll analogue)."""
        self._pump()
        if self.done():
            self._fire_callbacks()
            return True
        return False

    def wait(self, policy: Union[str, WaitPolicy, None] = None) -> Any:
        """Block until the record resolves; returns the raw result payload
        (None when the descriptor errored — use result() to raise instead).
        ``policy`` overrides the device's wait policy for this wait (spin /
        pause / umwait / interrupt — see core/completion.py)."""
        if not self.done():
            if self.device is not None:
                # one-element set wait: same machinery as wait_any/wait_all,
                # so host-busy/host-free accounting covers every wait
                self.device.wait_all([self], policy=policy)
            elif self.engine is None:
                self._pump()
                if not self.done():
                    raise RuntimeError("unresolved promise: no engine will complete it")
            else:
                self.engine.wait(self.record)
        self._fire_callbacks()
        return self.record.result

    def result(self, policy: Union[str, WaitPolicy, None] = None) -> Any:
        """wait(), but a failed descriptor raises instead of returning None."""
        value = self.wait(policy=policy)
        if self.record.status == Status.ERROR:
            raise RuntimeError(self.record.error or "descriptor failed")
        return value

    # -- chaining ------------------------------------------------------------
    def then(self, fn: Callable[[Any], Any]) -> "ChainedFuture":
        """Return a Future for ``fn(result)``, applied when this one retires."""
        return ChainedFuture(self, fn)

    def add_done_callback(self, fn: Callable[["Future"], None]):
        """Register ``fn(future)`` to run when completion is observed
        (poll/wait/result or an engine completion notification).  Callbacks
        fire exactly once — even with concurrent waiters — in registration
        order; a callback added after completion runs immediately."""
        with self._cb_lock:
            if not self._fired:
                self._callbacks.append(fn)
                return
        fn(self)

    # alias matching the issue's spelling
    done_callback = add_done_callback

    def _fire_callbacks(self):
        if not self.done():
            return
        with self._cb_lock:
            if self._fired:
                return
            self._fired = True
            callbacks, self._callbacks = self._callbacks, []
        tr = self.record.trace
        if tr is not None:
            # first observation of the completion by the host: ends the
            # host_wait span (exactly-once, guarded by _fired above)
            tr.mark("observed")
            t_cb = tr.mark("cb0")
        if callbacks:
            # user code runs strictly outside _cb_lock; lockcheck verifies
            # no OTHER instrumented lock is held at this dispatch point
            with _lockcheck.notify_region("future.fire_callbacks"):
                for fn in callbacks:
                    fn(self)
        if tr is not None:
            # no callbacks -> zero-length span at t_cb, so exports always
            # carry the full phase set
            tr.mark("cb1", None if callbacks else t_cb)


class ChainedFuture(Future):
    """Future for a host-side continuation: resolves to fn(parent result)
    once the parent retires.  Errors propagate (parent failure or fn raising
    both mark this record ERROR)."""

    def __init__(self, parent: Future, fn: Callable[[Any], Any]):
        rec = CompletionRecord(desc_id=-1, status=Status.PENDING,
                               op=f"then({op_str(parent)})")
        super().__init__(parent.device, None, rec)
        self.parent = parent
        self.fn = fn
        # trace propagation: a continuation of a traced parent gets its own
        # node (fresh desc_id) under the parent's trace id, linked by a
        # "then" edge the critical-path analyzer walks
        tracer = getattr(parent.device, "tracer", None)
        ptr = parent.record.trace
        if tracer is not None and ptr is not None:
            rec.desc_id = next_desc_id()
            rec.trace = tracer.begin_host(ptr.trace_id, rec.desc_id, rec.op)
            tracer.edge(parent.record.desc_id, rec.desc_id, "then")

    def _resolve(self):
        if self.record.is_done():
            return
        tr = self.record.trace
        if tr is not None:
            tr.mark("exec0")
        if self.parent.record.status == Status.ERROR:
            self.record.status = Status.ERROR
            self.record.error = self.parent.record.error or "parent failed"
        else:
            try:
                self.record.result = self.fn(self.parent.record.result)
                self.record.status = Status.SUCCESS
            except Exception as e:  # noqa: BLE001
                self.record.status = Status.ERROR
                self.record.error = f"{type(e).__name__}: {e}"
        if tr is not None:
            tr.mark("exec1")
            tr.mark("resolved")
        if self.device is not None:
            self.device._on_future_done(self)  # deliver to completion sets

    def done(self) -> bool:
        if not self.record.is_done() and self.parent.done():
            self._resolve()
        return self.record.is_done()

    def poll(self) -> bool:
        if self.parent.poll():
            self._resolve()
        if self.done():
            self._fire_callbacks()
            return True
        return False

    def wait(self, policy: Union[str, WaitPolicy, None] = None) -> Any:
        if not self.record.is_done():
            self.parent.wait(policy=policy)
            self._resolve()
        self._fire_callbacks()
        return self.record.result


class Promise(Future):
    """A software fence: a Future completed by the host, not an engine.
    Use as ``after=[p]`` to hold submissions until ``p.set_result(...)``."""

    def __init__(self, device: Optional["Device"] = None):
        super().__init__(device, None,
                         CompletionRecord(desc_id=-1, status=Status.PENDING, op="promise"))

    def set_result(self, value: Any = None):
        self.record.result = value
        self.record.status = Status.SUCCESS
        if self.device is not None:
            self.device._on_future_done(self)  # callbacks + completion sets
            self.device.kick()  # release anything fenced on this promise
        else:
            self._fire_callbacks()

    def set_error(self, error: Union[str, BaseException]):
        self.record.error = str(error)
        self.record.status = Status.ERROR
        if self.device is not None:
            self.device._on_future_done(self)
            self.device.kick()
        else:
            self._fire_callbacks()

    def wait(self, policy: Union[str, WaitPolicy, None] = None) -> Any:
        """A promise is host-completed: an unresolved one can never be
        waited to completion by pumping engines, so fail fast instead of
        parking forever."""
        if not self.done():
            self._pump()
            if not self.done():
                raise RuntimeError("unresolved promise: no engine will complete it")
        self._fire_callbacks()
        return self.record.result


def op_str(f: Future) -> str:
    return f.record.op or "?"


# --------------------------------------------------------------------------- policies
class SubmitPolicy:
    """Chooses which engine instance receives a submission (paper Fig. 10:
    multi-instance scaling depends on balanced placement)."""

    name = "base"

    def select(self, engines: Sequence[StreamEngine], desc: Submittable,
               producer: Optional[str]) -> StreamEngine:
        raise NotImplementedError


class RoundRobinPolicy(SubmitPolicy):
    """Rotate across instances regardless of load (the paper's baseline)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0
        self._lock = _lockcheck.checked_lock("policy.round_robin")

    def select(self, engines, desc, producer):
        with self._lock:
            e = engines[self._next % len(engines)]
            self._next += 1
            return e


class LeastLoadedPolicy(SubmitPolicy):
    """Pick the instance with the lowest aggregate WQ occupancy — the
    paper's guideline for avoiding a hot instance when transfer sizes are
    skewed.  Ties break toward the lowest index (stable placement)."""

    name = "least_loaded"

    @staticmethod
    def occupancy(e: StreamEngine) -> float:
        qs = [w for g in e.config.groups for w in g.wqs]
        return sum(len(w) for w in qs) / max(sum(w.size for w in qs), 1)

    def select(self, engines, desc, producer):
        return min(engines, key=self.occupancy)


class StickyPolicy(SubmitPolicy):
    """Per-producer affinity: one producer always lands on one instance
    (DWQ-per-core analogue, G6).  Unnamed producers fall back to
    round-robin so anonymous traffic still spreads."""

    name = "sticky"

    def __init__(self):
        self._fallback = RoundRobinPolicy()

    def select(self, engines, desc, producer):
        if producer is None:
            return self._fallback.select(engines, desc, producer)
        h = zlib.crc32(producer.encode()) & 0xFFFFFFFF
        return engines[h % len(engines)]


class NumaLocalPolicy(SubmitPolicy):
    """Locality first (paper §4 / Fig. 13: keep the engine and both buffers
    NUMA-local): prefer engines on the descriptor's home node — the
    destination's node when known (that's where the data lands), else the
    source's — and apply the ``inner`` policy among them.  When every
    home-node engine is saturated (aggregate WQ occupancy >= ``saturation``)
    or the descriptor has no home, degrade gracefully to ``inner`` over ALL
    engines: a remote engine beats a stalled submission."""

    name = "numa_local"

    def __init__(self, inner: Union[str, SubmitPolicy, None] = "least_loaded",
                 saturation: float = 1.0):
        self.inner = get_policy(inner)
        self.saturation = saturation

    def select(self, engines, desc, producer):
        home = getattr(desc, "dst_node", None)
        if home is None:
            home = getattr(desc, "src_node", None)
        if home is not None:
            ready = [e for e in engines
                     if getattr(e, "node_id", 0) == home
                     and LeastLoadedPolicy.occupancy(e) < self.saturation]
            if ready:
                return self.inner.select(ready, desc, producer)
        return self.inner.select(engines, desc, producer)


POLICIES: Dict[str, Callable[[], SubmitPolicy]] = {
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "sticky": StickyPolicy,
    "numa_local": NumaLocalPolicy,
}


def get_policy(policy: Union[str, SubmitPolicy, None]) -> SubmitPolicy:
    if policy is None:
        return RoundRobinPolicy()
    if isinstance(policy, SubmitPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown submit policy {policy!r}; "
                         f"expected one of {sorted(POLICIES)}") from None


def _dominant_node(nodes: Sequence[Optional[int]],
                   default: Optional[int]) -> Optional[int]:
    """Most common known home node in a batch (placement votes), or the
    hint/None when no member has one."""
    known = [n for n in nodes if n is not None]
    if not known:
        return default
    return Counter(known).most_common(1)[0][0]


# --------------------------------------------------------------------------- device
class Device:
    """Top-level submission facade: a fabric of StreamEngine instances laid
    out over a ``Topology`` of NUMA nodes (default: one node — the flat
    pre-topology world, bit-for-bit compatible).

    Every submit routes through the SubmitPolicy, returns a Future, and
    turns WQ RETRY into bounded exponential backoff (max_retries doublings
    of backoff_base_s) ending in QueueFull — never an unbounded spin.

    Locality (paper §4 / Fig. 13): ``register(array, node)`` records a
    buffer's home node; each submission derives its operands' nodes from
    the registry (or a per-submit ``node=`` hint), the policy can place it
    accordingly (``numa_local``), and the engine charges the inter-node
    link for every operand left on a foreign node.
    """

    def __init__(self, engines: Optional[Sequence[StreamEngine]] = None, *,
                 n_instances: int = 1,
                 topology: Optional[Topology] = None,
                 policy: Union[str, SubmitPolicy, None] = "round_robin",
                 wait_policy: Union[str, WaitPolicy, None] = "umwait",
                 config: Optional[DeviceConfig] = None,
                 config_kw: Optional[Dict[str, Any]] = None,
                 wq_configs: Optional[Sequence[WQConfig]] = None,
                 pes_per_group: int = 4,
                 max_retries: int = 10, backoff_base_s: float = 20e-6,
                 validate: str = "warn",
                 trace: Any = None):
        if validate not in ("strict", "warn", "off"):
            raise ValueError(f"validate must be 'strict', 'warn', or 'off', "
                             f"got {validate!r}")
        # opt-in descriptor-lifecycle tracing (repro.obs.trace): None/False
        # off (the default — submit pays one attribute check), True/rate/
        # TraceConfig/Tracer on.  Lazy import keeps core free of obs at
        # module scope; a rate outside [0, 1] raises TraceRateError here.
        if trace is None:
            self.tracer = None
        else:
            from repro.obs.trace import make_tracer

            self.tracer = make_tracer(trace)
        # submit-time descriptor validation mode (repro.analysis.desclint):
        # strict raises the typed DescriptorError taxonomy, warn bumps the
        # desclint_warnings counter, off skips the checks
        self.validate = validate
        if engines is not None:
            if config is not None or wq_configs is not None or config_kw is not None:
                raise ValueError("pass pre-built engines OR a config/wq_configs "
                                 "to build them from, not both")
            self.engines = list(engines)
            self.topology = topology or Topology.single_node(len(self.engines))
        else:
            if config is not None and wq_configs is not None:
                raise ValueError("pass either config= or wq_configs=, not both")
            if config is not None and config_kw is not None:
                raise ValueError("pass either config= or config_kw=, not both")
            # nodes carry their own engine counts; without a topology,
            # n_instances engines land on one node (the legacy shape)
            self.topology = topology or Topology.single_node(n_instances)
            self.engines = []
            per_node = Counter()
            for nid in self.topology.engine_nodes():
                i = per_node[nid]
                per_node[nid] += 1
                if wq_configs is not None:
                    # each instance gets its own WorkQueue objects from the
                    # same WQCFG records (configs are frozen and shareable;
                    # queues are per-instance state)
                    cfg_e = DeviceConfig.from_wq_configs(
                        wq_configs, pes_per_group=pes_per_group)
                elif config is not None:
                    cfg_e = config
                else:
                    cfg_e = DeviceConfig.default(**(config_kw or {}))
                name = (f"dsa{i}" if self.topology.n_nodes == 1
                        else f"n{nid}dsa{i}")
                self.engines.append(StreamEngine(cfg_e, name=name, node_id=nid,
                                                 topology=self.topology))
        # buffer-locality registry: id(array) -> (home node, weakref); the
        # weakref callback evicts the entry when the array dies, so a reused
        # id can't inherit a stale home
        self._homes: Dict[int, Any] = {}
        self.policy = get_policy(policy)
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        # per-policy-decision telemetry: which instance each submission
        # landed on, per op, plus backoff pressure
        self.policy_stats: Dict[str, Any] = {
            "policy": self.policy.name,
            "decisions": Counter(),       # engine name -> submissions routed
            "decisions_by_op": Counter(),  # (engine, op) -> submissions
            "backoff_retries": 0,
            "queue_full": 0,
            "desclint_warnings": 0,  # warn-mode validation findings
        }
        self._lock = _lockcheck.checked_lock("device.stats")
        # serializes engine mutation (records/slots/deferred have no internal
        # locking) so background submitters — e.g. async checkpoint CRCs —
        # can share the device with foreground traffic
        self._engine_lock = _lockcheck.checked_rlock("device.engine")
        # ---- completion subsystem (core/completion.py) -------------------
        # default wait scheme for this device; every wait can override it
        self.wait_policy = get_wait_policy(wait_policy)
        # host-busy/host-free cycle accounting per policy name (Fig. 11)
        self.wait_stats: Dict[str, WaitStats] = defaultdict(WaitStats)
        # live futures keyed by their record's identity, so an engine
        # completion notification finds its Future without a scan; weak so
        # dropped futures don't pin results
        self._inflight: "weakref.WeakValueDictionary[int, Future]" = (
            weakref.WeakValueDictionary()
        )
        self._sinks: List[Any] = []  # registered CompletionSets
        self._sinks_lock = _lockcheck.checked_lock("device.sinks")
        # attached observability samplers (repro.obs): registered on
        # Sampler.start(), detached on stop(), so shutdown paths can find
        # and stop any live background sampler threads
        self._observers: List[Any] = []
        # engine notifications arrive while _engine_lock is held; user
        # callbacks must NOT run under it (a blocking callback would
        # deadlock against other waiters), so notifications queue here and
        # dispatch after the lock is released
        self._done_notifications: "deque[Future]" = deque()
        # SLO hint table (register_slo_classes): slo= submits resolve their
        # wq/priority defaults from here, keeping the class -> WQ mapping in
        # one place instead of at every call site
        self._slo_classes: Dict[str, Any] = {}
        # live submit rings (weak: a dropped ring must not leak); kick()
        # flushes them so every wait-policy pump advances deferred bursts
        self._rings: List[Any] = []
        for e in self.engines:
            e.add_listener(self._on_record_done)

    # ------------------------------------------------------------------ locality
    def register(self, array: Any, node: int) -> Any:
        """Record ``array``'s home node in the buffer-locality registry.
        Descriptors naming it derive their src/dst node from here; returns
        the array so registration chains through pool updates."""
        if not 0 <= node < self.topology.n_nodes:
            raise ValueError(
                f"node {node} out of range for {self.topology.n_nodes}-node topology"
            )
        key = id(array)
        try:
            ref = weakref.ref(array, lambda _r, k=key: self._homes.pop(k, None))
        except TypeError:
            ref = None  # unreferenceable objects: entry lives forever
        self._homes[key] = (node, ref)
        return array

    def home(self, array: Any, default: Optional[int] = None) -> Optional[int]:
        """The registered home node of ``array`` (``default`` if unknown)."""
        if array is None:
            return default
        ent = self._homes.get(id(array))
        return ent[0] if ent is not None else default

    def _stamp_locality(self, desc: Submittable, node_hint: Optional[int]) -> None:
        """Resolve operand home nodes onto the descriptor before placement:
        registry first, then the per-submit ``node=`` hint; operands still
        unresolved stay None (= wherever the engine runs, i.e. local)."""
        members = (desc.descriptors if isinstance(desc, BatchDescriptor)
                   else (desc,))
        for d in members:
            if d.src_node is None:
                d.src_node = self.home(d.src, node_hint)
            if d.dst_node is None:
                d.dst_node = (self.home(d.dst_pool, node_hint)
                              if d.dst_pool is not None else node_hint)
        if isinstance(desc, BatchDescriptor):
            if desc.src_node is None:
                desc.src_node = _dominant_node(
                    [d.src_node for d in members], node_hint)
            if desc.dst_node is None:
                desc.dst_node = _dominant_node(
                    [d.dst_node for d in members], node_hint)

    # ------------------------------------------------------------------ SLO hints
    def register_slo_classes(self, classes: Sequence[Any]) -> None:
        """Register SLO classes (objects with ``name``/``wq``/``priority``,
        e.g. ``repro.serving.slo.SLOClass``) so submissions can carry a
        ``slo=`` hint instead of repeating the class -> WQ mapping at every
        call site.  Re-registering replaces the table."""
        table: Dict[str, Any] = {}
        for c in classes:
            table[c.name] = c
        self._slo_classes = table

    def occupancy(self, wq: Union[str, None] = None,
                  node: Optional[int] = None) -> Optional[float]:
        """Aggregate WQ occupancy probe — the admission controller's view
        of engine-side pressure.  Averages ``len/size`` over the matching
        WQs: ``wq`` restricts to that WQ name, ``node`` to that node's
        engines; None when nothing matches (an unknown name is not 'idle')."""
        occs: List[float] = []
        engines = (self.engines if node is None else self.engines_on(node))
        for e in engines:
            for g in e.config.groups:
                for w in g.wqs:
                    if wq is None or w.name == wq:
                        occs.append(w.occupancy)
        if not occs:
            return None
        return sum(occs) / len(occs)

    # ------------------------------------------------------------------ submit
    def submit(self, desc: Submittable, *, after: Optional[Sequence[Any]] = None,
               group: Optional[int] = None, wq: Union[int, str, None] = None,
               priority: Optional[int] = None,
               producer: Optional[str] = None,
               node: Optional[int] = None,
               slo: Optional[str] = None) -> Future:
        """Submit one descriptor; returns its Future.

        ``after``: Futures / CompletionRecords this descriptor must not
        launch before (DSA batch-fence semantics across submissions).
        ``wq``: target WQ as an index or a WQ name; ``priority`` steers to
        the nearest-priority WQ when ``wq`` is not given (searching all
        groups, or only ``group`` when one is pinned).  Both compose with
        the SubmitPolicy (the policy picks the instance, the hint picks
        the WQ on it) and with ``after=`` fences.
        ``node``: home-node hint for operands the registry doesn't know —
        the ``numa_local`` policy places the submission there and the
        engine charges the link if placement lands elsewhere.
        ``slo``: a registered SLO class name (register_slo_classes); fills
        in ``wq``/``priority`` defaults from the class when the caller
        didn't pass them explicitly.
        Raises QueueFull when the target WQ stays full through every
        backoff attempt."""
        wq, priority = self._resolve_slo(slo, wq, priority)
        deps = list(after) if after is not None else None
        trace = self._prepare(desc, producer=producer, node=node, slo=slo,
                              after=deps)
        eng = self.policy.select(self.engines, desc, producer)
        delay = self.backoff_base_s
        for attempt in range(self.max_retries + 1):
            with self._engine_lock:
                status, rec = eng.submit(desc, group=group, wq=wq,
                                         priority=priority,
                                         producer=producer, after=deps,
                                         trace=trace)
            self._dispatch_done()  # retirals observed by the submit's kick
            if status != Status.RETRY:
                with self._lock:
                    self.policy_stats["decisions"][eng.name] += 1
                    self.policy_stats["decisions_by_op"][f"{eng.name}/{op_name(desc)}"] += 1
                    self.policy_stats["backoff_retries"] += attempt
                if trace is not None and attempt:
                    trace.attrs["retries"] = attempt
                fut = Future(self, eng, rec)
                self._inflight[id(rec)] = fut
                if rec.is_done():
                    # completed (or failed its fence) before the Future
                    # existed: the engine notification missed the registry
                    self._on_future_done(fut)
                return fut
            self.kick()  # give PEs a chance to retire and free WQ slots
            time.sleep(delay)
            delay *= 2
        with self._lock:
            self.policy_stats["backoff_retries"] += self.max_retries
            self.policy_stats["queue_full"] += 1
        if trace is not None:
            # close the trace so a shed submission still folds/export:
            # it consumed host time even though no engine accepted it
            trace.attrs["error"] = "QueueFull"
            trace.mark("resolved")
        raise QueueFull(eng.name, self.max_retries + 1)

    def _resolve_slo(self, slo: Optional[str], wq: Union[int, str, None],
                     priority: Optional[int]) -> Tuple[Union[int, str, None],
                                                       Optional[int]]:
        """Fill wq/priority defaults from a registered SLO class when the
        caller didn't pass them explicitly (shared by submit/submit_many
        and the submit ring)."""
        if slo is None:
            return wq, priority
        cls = self._slo_classes.get(slo)
        if cls is None:
            raise KeyError(f"unregistered SLO class {slo!r}; call "
                           f"register_slo_classes first "
                           f"(have {sorted(self._slo_classes)})")
        cls_wq = getattr(cls, "wq", None)
        if wq is None and cls_wq is not None and self.has_wq(cls_wq):
            wq = cls_wq
        if priority is None and wq is None:
            priority = getattr(cls, "priority", None)
        return wq, priority

    def _prepare(self, desc: Submittable, *, producer: Optional[str],
                 node: Optional[int], slo: Optional[str],
                 after: Optional[Sequence[Any]]) -> Optional[Any]:
        """Per-descriptor submit-side prep shared by every submission path:
        begin the lifecycle trace, stamp operand locality, record fence
        edges, and run desclint between the validate marks.  Returns the
        trace (None when unsampled)."""
        tracer = self.tracer
        trace = tracer.begin(desc) if tracer is not None else None
        self._stamp_locality(desc, node)
        if trace is not None:
            if producer is not None:
                trace.attrs["producer"] = producer
            if slo is not None:
                trace.attrs["slo"] = slo
            if after:
                for dep in after:
                    dep_rec = getattr(dep, "record", dep)
                    dep_id = getattr(dep_rec, "desc_id", None)
                    if dep_id is not None and dep_id >= 0:
                        tracer.edge(dep_id, desc.desc_id, "after")
            trace.mark("validate0")
        if self.validate != "off":
            self._desclint(desc)
        if trace is not None:
            trace.mark("validate1")
        return trace

    def submit_many(self, descs: Sequence[Submittable], *,
                    after: Optional[Sequence[Any]] = None,
                    group: Optional[int] = None,
                    wq: Union[int, str, None] = None,
                    priority: Optional[int] = None,
                    producer: Optional[str] = None,
                    node: Optional[int] = None,
                    slo: Optional[str] = None,
                    chunk: int = 32) -> List[Future]:
        """Fused submission: route ``descs`` in doorbell bursts of up to
        ``chunk``, taking the device and WQ locks once per burst instead of
        once per descriptor and charging the non-posted ENQCMD round trip
        once per burst (each member's ``fused_n`` is stamped with the burst
        width).  Validation and lifecycle traces stay exactly
        per-descriptor; the whole call shares one ``after`` fence list
        (batch-fence semantics) and one policy decision per burst.
        Returns one Future per descriptor, in order; raises QueueFull when
        a burst stays refused through every backoff attempt."""
        descs = list(descs)
        if not descs:
            return []
        wq, priority = self._resolve_slo(slo, wq, priority)
        deps = list(after) if after is not None else None
        futures: List[Future] = []
        step = max(int(chunk), 1)
        for start in range(0, len(descs), step):
            burst = descs[start:start + step]
            traces = [self._prepare(d, producer=producer, node=node, slo=slo,
                                    after=deps) for d in burst]
            for d in burst:
                d.fused_n = len(burst)
            eng = self.policy.select(self.engines, burst[0], producer)
            delay = self.backoff_base_s
            results = None
            for attempt in range(self.max_retries + 1):
                with self._engine_lock:
                    results = eng.submit_many(burst, group=group, wq=wq,
                                              priority=priority,
                                              producer=producer, after=deps,
                                              traces=traces)
                self._dispatch_done()
                if results[0][0] != Status.RETRY:
                    break
                self.kick()
                time.sleep(delay)
                delay *= 2
            else:
                with self._lock:
                    self.policy_stats["backoff_retries"] += self.max_retries
                    self.policy_stats["queue_full"] += 1
                for tr in traces:
                    if tr is not None:
                        tr.attrs["error"] = "QueueFull"
                        tr.mark("resolved")
                raise QueueFull(eng.name, self.max_retries + 1)
            with self._lock:
                self.policy_stats["decisions"][eng.name] += len(burst)
                for d in burst:
                    self.policy_stats["decisions_by_op"][f"{eng.name}/{op_name(d)}"] += 1
                self.policy_stats["backoff_retries"] += attempt
            for _status, rec in results:
                fut = Future(self, eng, rec)
                self._inflight[id(rec)] = fut
                if rec.is_done():
                    self._on_future_done(fut)
                futures.append(fut)
        return futures

    def submit_ring(self, depth: int = 64, chunk: int = 32,
                    **defaults) -> "SubmitRing":
        """Opt-in deferred submission ring (see SubmitRing): ``add`` buffers
        descriptors and returns live Futures; the buffered burst flushes
        through the fused submit_many path on ``flush()``, when the ring
        fills, or on any ``Device.kick()`` — which every wait policy pumps,
        so waiting on a ringed Future flushes it automatically."""
        ring = SubmitRing(self, depth=depth, chunk=chunk, **defaults)
        self._rings.append(weakref.ref(ring))
        return ring

    def _flush_rings(self):
        """Flush live submit rings (dropping dead weakrefs); called from
        kick() so WaitPolicy pump loops advance deferred submissions."""
        dead = False
        for ref in list(self._rings):
            ring = ref()
            if ring is None:
                dead = True
                continue
            ring.flush()
        if dead:
            self._rings = [r for r in self._rings if r() is not None]

    def _desclint(self, desc: Submittable) -> None:
        """Validate after locality stamping (so registry-vs-hint conflicts
        were resolvable) and before placement.  Lazy import: desclint needs
        repro.core.descriptor, which this module helps initialise."""
        from repro.analysis import desclint

        diags = desclint.check(desc, device=self)
        if not diags:
            return
        if self.validate == "strict" and any(
                d.severity == "error" for d in diags):
            raise desclint.error_for(diags, desc=desc)
        with self._lock:
            self.policy_stats["desclint_warnings"] += len(diags)

    def promise(self) -> Promise:
        """A host-completed fence Future (see Promise)."""
        return Promise(self)

    def engines_on(self, node: int) -> List[StreamEngine]:
        """The engine instances living on one NUMA node of the fabric."""
        return [e for e in self.engines if getattr(e, "node_id", 0) == node]

    def has_wq(self, name: str) -> bool:
        """True when every instance exposes a WQ with this name (safe to use
        as a ``wq=`` hint regardless of which instance the policy picks)."""
        return all(
            any(w.name == name for g in e.config.groups for w in g.wqs)
            for e in self.engines
        )

    # ------------------------------------------------------------------ observability
    def attach_observer(self, observer: Any) -> None:
        """Register a live observer (a ``repro.obs.Sampler``); idempotent.
        Observers are plain registrations — the device never calls into
        them, but ``observers`` lets shutdown code stop stray samplers."""
        if observer not in self._observers:
            self._observers.append(observer)

    def detach_observer(self, observer: Any) -> None:
        try:
            self._observers.remove(observer)
        except ValueError:
            pass

    @property
    def observers(self) -> List[Any]:
        return list(self._observers)

    def observe(self, interval_s: float = 0.05, **kw) -> Any:
        """Convenience: build a ``repro.obs.Sampler`` over this device and
        start its background sampling thread.  Caller owns stop():

            sampler = device.observe(interval_s=0.01)
            ... workload ...
            sampler.stop(); print(sampler.to_csv())
        """
        from repro.obs import Sampler  # lazy: obs imports core

        sampler = Sampler(self, interval_s=interval_s, **kw)
        sampler.start()
        return sampler

    # ------------------------------------------------------------------ completion
    def _resolve_wait_policy(self, policy: Union[str, WaitPolicy, None]) -> WaitPolicy:
        return self.wait_policy if policy is None else get_wait_policy(policy)

    def _wait_bucket(self, name: str) -> WaitStats:
        """Per-policy WaitStats, created under the device lock so two
        threads' first waits can't race defaultdict.__missing__ and strand
        one thread's counts in an orphaned bucket."""
        with self._lock:
            return self.wait_stats[name]

    def _on_record_done(self, rec: CompletionRecord):
        """Engine completion notification (runs under _engine_lock): queue
        the resolved record's Future; callbacks and completion-set delivery
        happen in _dispatch_done once the lock is released."""
        fut = self._inflight.pop(id(rec), None)
        if fut is not None:
            self._done_notifications.append(fut)

    def _dispatch_done(self):
        """Fire queued completion notifications — exactly-once callbacks
        plus delivery to registered sets — outside the engine lock."""
        while True:
            try:
                fut = self._done_notifications.popleft()
            except IndexError:
                return
            self._on_future_done(fut)

    def _on_future_done(self, fut: "Future"):
        fut._fire_callbacks()
        with self._sinks_lock:
            sinks = list(self._sinks)
        for sink in sinks:
            sink._deliver(fut)

    def _add_sink(self, sink):
        with self._sinks_lock:
            self._sinks.append(sink)

    def _remove_sink(self, sink):
        with self._sinks_lock:
            try:
                self._sinks.remove(sink)
            except ValueError:
                pass

    def _inflight_work(self):
        """What a parked wait policy blocks on (the UMWAIT monitor arm):
        (PE worker handles still executing, array leaves of dispatched
        outputs not yet device-ready)."""
        with self._engine_lock:
            work: List[Any] = []
            leaves: List[Any] = []
            for e in self.engines:
                for active in e._active.values():
                    for s in active:
                        if s.record is None or s.record.is_done():
                            continue
                        if s.work is not None and not s.work.done():
                            work.append(s.work)
                        elif s.outputs is not None:
                            leaves.extend(jax.tree.leaves(s.outputs))
            return work, leaves

    def wait_any(self, futures: Sequence["Future"], *,
                 policy: Union[str, WaitPolicy, None] = None,
                 timeout: Optional[float] = None):
        """Wait until at least one of ``futures`` completes; returns
        ``(done, pending)``.  ``timeout=0`` is a single non-parking poll
        pass — the pipeline-friendly form."""
        return _completion.wait_any(self, futures, policy=policy, timeout=timeout)

    def wait_all(self, futures: Sequence["Future"], *,
                 policy: Union[str, WaitPolicy, None] = None,
                 timeout: Optional[float] = None):
        """Wait until every future completes (raises WaitTimeout past the
        deadline); returns the futures.  Failures are 'complete' — call
        ``result()`` per future to raise."""
        return _completion.wait_all(self, futures, policy=policy, timeout=timeout)

    def as_completed(self, futures: Sequence["Future"], *,
                     policy: Union[str, WaitPolicy, None] = None,
                     timeout: Optional[float] = None) -> Iterator["Future"]:
        """Iterate ``futures`` in completion order, driving one wait-policy
        loop for the whole set."""
        return _completion.as_completed(self, futures, policy=policy, timeout=timeout)

    # ------------------------------------------------------------------ async ops
    def memcpy_async(self, src: jax.Array, **kw):
        return self.submit(WorkDescriptor(op=OpType.MEMCPY, src=src), **kw)

    def dualcast_async(self, src: jax.Array, **kw):
        return self.submit(WorkDescriptor(op=OpType.DUALCAST, src=src), **kw)

    def fill_async(self, pattern, n_words: int, **kw):
        return self.submit(
            WorkDescriptor(op=OpType.FILL, pattern=pattern, n_words=n_words), **kw
        )

    def compare_async(self, a, b, **kw):
        return self.submit(WorkDescriptor(op=OpType.COMPARE, src=a, src2=b), **kw)

    def crc32_async(self, buf, **kw):
        return self.submit(WorkDescriptor(op=OpType.CRC32, src=buf), **kw)

    def copy_crc_async(self, src, **kw):
        """Fused memcpy+CRC32 in ONE kernel launch; the Future resolves to
        ``(copy, crc)``.  Bit-exact with the unfused memcpy/crc32 pair at
        roughly half the modeled device time (one read pass, one launch)."""
        return self.submit(WorkDescriptor(op=OpType.COPY_CRC, src=src), **kw)

    def fill_verify_async(self, pattern, n_words: int, **kw):
        """Fused fill+compare_pattern in ONE kernel launch; the Future
        resolves to ``(filled, (ok, first_bad_idx))`` — the written buffer
        plus its in-kernel readback verification."""
        return self.submit(
            WorkDescriptor(op=OpType.FILL_VERIFY, pattern=pattern,
                           n_words=n_words), **kw
        )

    def delta_create_async(self, src, ref, cap: int = 1024, **kw):
        return self.submit(
            WorkDescriptor(op=OpType.DELTA_CREATE, src=src, src2=ref, cap=cap), **kw
        )

    def delta_apply_async(self, ref, offsets, data, **kw):
        return self.submit(
            WorkDescriptor(op=OpType.DELTA_APPLY, src=ref, src_idx=offsets, src2=data), **kw
        )

    def compare_pattern_async(self, buf, pattern, **kw):
        return self.submit(
            WorkDescriptor(op=OpType.COMPARE_PATTERN, src=buf, pattern=pattern), **kw
        )

    def dif_insert_async(self, buf, **kw):
        """Frame ``buf`` with per-block DIF tags (CRC + ref/app tag)."""
        return self.submit(WorkDescriptor(op=OpType.DIF_INSERT, src=buf), **kw)

    def dif_check_async(self, framed, **kw):
        """Verify per-block DIF tags; resolves to the ok-mask per block."""
        return self.submit(WorkDescriptor(op=OpType.DIF_CHECK, src=framed), **kw)

    def dif_strip_async(self, framed, **kw):
        """Drop DIF framing, recovering the raw word stream."""
        return self.submit(WorkDescriptor(op=OpType.DIF_STRIP, src=framed), **kw)

    def batch_copy_async(self, src_pool, dst_pool, src_idx, dst_idx, **kw):
        return self.submit(
            WorkDescriptor(op=OpType.BATCH_COPY, src=src_pool, dst_pool=dst_pool,
                           src_idx=src_idx, dst_idx=dst_idx), **kw
        )

    def batch_async(self, descriptors: Sequence[WorkDescriptor], **kw):
        return self.submit(BatchDescriptor(descriptors=list(descriptors)), **kw)

    # ------------------------------------------------------------------ sync sugar
    def wait(self, fut: Future, *, policy: Union[str, WaitPolicy, None] = None) -> Any:
        return fut.wait(policy=policy)

    def poll(self, fut: Future) -> bool:
        return fut.poll()

    def memcpy(self, src):
        return self.wait(self.memcpy_async(src))

    def crc32(self, buf) -> int:
        return int(self.wait(self.crc32_async(buf)))

    def compare(self, a, b):
        return self.wait(self.compare_async(a, b))

    def delta_create(self, src, ref, cap: int = 1024):
        return self.wait(self.delta_create_async(src, ref, cap=cap))

    def delta_apply(self, ref, offsets, data):
        return self.wait(self.delta_apply_async(ref, offsets, data))

    # ------------------------------------------------------------------ lifecycle
    def kick(self):
        """Pump every instance's arbiter + deferred fences once; completion
        callbacks for anything that retired fire after the lock drops.
        Deferred submit rings flush first, so a kick (and therefore every
        wait-policy pump loop) pushes ring-buffered bursts to the engines
        before the arbiters run."""
        if self._rings:
            self._flush_rings()
        with self._engine_lock:
            for e in self.engines:
                e.kick()
        self._dispatch_done()

    def drain(self):
        """Run all instances dry, including cross-engine fences: a deferred
        descriptor on engine A whose parent lives on engine B resolves here
        because every engine is pumped each round."""
        while True:  # dsalint: disable=DSA103 — drain IS the terminal pump
            with self._engine_lock:
                for e in self.engines:
                    e.kick()
                    e.drain()
                pending = any(e._deferred for e in self.engines) or any(
                    len(w) for e in self.engines for g in e.config.groups for w in g.wqs
                )
                done = not pending
                if pending:
                    released = False
                    for e in self.engines:
                        for *_, deps, _rec in e._deferred:
                            if all(d.is_done() for d in deps):
                                released = True
                    if not released:
                        # remaining fences wait on unresolved promises;
                        # nothing an engine pump can do
                        done = True
            self._dispatch_done()  # callbacks fire outside the lock
            if done:
                return


class SubmitRing:
    """Opt-in deferred submission ring (the paper's batched-doorbell
    guideline as an API): ``add()`` validates, traces, and buffers a
    descriptor — returning a live Future immediately — and ``flush()``
    pushes the buffered burst through the engine's fused ``submit_many``
    path, taking the device and WQ locks once per burst and paying one
    amortized ENQCMD doorbell per burst of up to ``chunk``.

    The ring flushes itself when it reaches ``depth``, on ``flush()``/
    ``close()``/context exit, and on every ``Device.kick()`` — which every
    WaitPolicy pump loop calls, so simply waiting on a ringed Future
    flushes it.  A burst refused by a full WQ stays buffered and retries on
    the next flush; consecutive adds sharing the same ``after`` fence list
    flush as one burst (batch-fence semantics).

        with device.submit_ring(depth=64) as ring:
            futs = [ring.add(WorkDescriptor(op=OpType.MEMCPY, src=x))
                    for x in buffers]
        device.wait_all(futs)
    """

    def __init__(self, device: Device, depth: int = 64, chunk: int = 32, *,
                 group: Optional[int] = None, wq: Union[int, str, None] = None,
                 priority: Optional[int] = None, producer: Optional[str] = None,
                 node: Optional[int] = None, slo: Optional[str] = None):
        self.device = device
        self.depth = max(int(depth), 1)
        self.chunk = max(min(int(chunk), self.depth), 1)
        wq, priority = device._resolve_slo(slo, wq, priority)
        self._kw = dict(group=group, wq=wq, priority=priority,
                        producer=producer, node=node, slo=slo)
        # (descriptor, trace, record, deps) in submission order
        self._pending: "deque[Tuple[Any, Any, CompletionRecord, Optional[List[Any]]]]" = deque()
        self._lock = _lockcheck.checked_lock("device.ring")
        self._flushing = False
        self.stats = {"added": 0, "flushed": 0, "doorbells": 0, "retries": 0}

    def __len__(self) -> int:
        return len(self._pending)

    @staticmethod
    def _fence_key(deps: Optional[List[Any]]) -> Tuple[int, ...]:
        return tuple(id(d) for d in deps) if deps else ()

    def add(self, desc: Submittable, *,
            after: Optional[Sequence[Any]] = None) -> Future:
        """Buffer one descriptor; returns its Future immediately (PENDING
        until a flush lands it on an engine).  Validation, locality
        stamping, and trace marks run here at add time — strict desclint
        raises before anything is buffered."""
        deps = list(after) if after is not None else None
        trace = self.device._prepare(desc, producer=self._kw["producer"],
                                     node=self._kw["node"],
                                     slo=self._kw["slo"], after=deps)
        rec = CompletionRecord(desc_id=desc.desc_id, status=Status.PENDING,
                               op=op_name(desc), trace=trace)
        fut = Future(self.device, None, rec)
        self.device._inflight[id(rec)] = fut
        with self._lock:
            self._pending.append((desc, trace, rec, deps))
            self.stats["added"] += 1
            full = len(self._pending) >= self.depth
        if full:
            self.flush()
        return fut

    def flush(self) -> int:
        """Submit buffered descriptors in fused bursts; returns how many
        landed on an engine.  A burst the WQ refuses (RETRY) goes back to
        the head of the ring for the next flush — every wait-policy kick
        retries it, so backpressure resolves without busy-spinning here."""
        dev = self.device
        with self._lock:
            if self._flushing or not self._pending:
                return 0
            self._flushing = True
        flushed = 0
        try:
            while True:
                with self._lock:
                    if not self._pending:
                        break
                    key = self._fence_key(self._pending[0][3])
                    burst = [self._pending.popleft()]
                    while (self._pending and len(burst) < self.chunk
                           and self._fence_key(self._pending[0][3]) == key):
                        burst.append(self._pending.popleft())
                descs = [b[0] for b in burst]
                for d in descs:
                    d.fused_n = len(descs)
                eng = dev.policy.select(dev.engines, descs[0],
                                        self._kw["producer"])
                with dev._engine_lock:
                    results = eng.submit_many(
                        descs, group=self._kw["group"], wq=self._kw["wq"],
                        priority=self._kw["priority"],
                        producer=self._kw["producer"], after=burst[0][3],
                        traces=[b[1] for b in burst],
                        records=[b[2] for b in burst])
                dev._dispatch_done()
                if results[0][0] == Status.RETRY:
                    with self._lock:
                        self._pending.extendleft(reversed(burst))
                        self.stats["retries"] += 1
                    break
                with dev._lock:
                    dev.policy_stats["decisions"][eng.name] += len(burst)
                    for d in descs:
                        dev.policy_stats["decisions_by_op"][
                            f"{eng.name}/{op_name(d)}"] += 1
                flushed += len(burst)
                self.stats["flushed"] += len(burst)
                self.stats["doorbells"] += 1
        finally:
            with self._lock:
                self._flushing = False
        return flushed

    def close(self):
        """Drain the ring completely, pumping the device through WQ
        backpressure with the device's bounded backoff; raises QueueFull
        if the buffered burst can never land."""
        delay = self.device.backoff_base_s
        for _attempt in range(self.device.max_retries + 1):
            self.flush()
            if not self._pending:
                return
            self.device.kick()
            time.sleep(delay)
            delay *= 2
        with self.device._lock:
            self.device.policy_stats["queue_full"] += 1
        raise QueueFull("submit_ring", self.device.max_retries + 1)

    def __enter__(self) -> "SubmitRing":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
        else:
            self.flush()  # best effort; don't mask the in-flight exception


def make_device(n_instances: int = 1, *,
                policy: Union[str, SubmitPolicy, None] = "round_robin",
                wait_policy: Union[str, WaitPolicy, None] = "umwait",
                wq_configs: Optional[Sequence[WQConfig]] = None,
                topology: Optional[Topology] = None,
                max_retries: int = 10, backoff_base_s: float = 20e-6,
                validate: str = "warn",
                trace: Any = None,
                **cfg_kw) -> Device:
    """Build a Device over fresh engine instances (Fig. 10 topology).

    ``topology`` lays the instances out over NUMA nodes (each ``Node``
    names its own engine count; ``n_instances`` is ignored then) and turns
    on cross-node link charging; the default is one flat node with
    ``n_instances`` engines.  ``wq_configs`` provisions each instance from
    WQCFG records (mode, size partition, priority, traffic class — Fig. 9
    knobs); otherwise ``cfg_kw`` forwards to DeviceConfig.default
    (wqs_per_group, wq_size, wq_mode, pes_per_group, n_groups).
    ``wait_policy`` sets the default completion wait scheme (spin / pause /
    umwait / interrupt — Fig. 11).
    ``validate`` sets the submit-time descriptor validation mode
    (repro.analysis.desclint): "strict" raises the typed DescriptorError
    taxonomy on malformed descriptors, "warn" (default) records them on the
    ``desclint_warnings`` counter, "off" skips the checks.
    ``trace`` opts in descriptor-lifecycle tracing (repro.obs): a sampling
    rate in [0, 1] (rates outside raise ``TraceRateError``, dsalint
    DSA105), True (trace everything), or a ``TraceConfig``/``Tracer``;
    the span trees land on ``device.tracer`` (docs/tracing.md)."""
    if wq_configs is not None:
        pes = cfg_kw.pop("pes_per_group", 4)
        if cfg_kw:
            raise ValueError(f"wq_configs replaces default-config knobs; "
                             f"unexpected {sorted(cfg_kw)}")
        return Device(n_instances=n_instances, topology=topology, policy=policy,
                      wait_policy=wait_policy,
                      wq_configs=wq_configs, pes_per_group=pes,
                      max_retries=max_retries, backoff_base_s=backoff_base_s,
                      validate=validate, trace=trace)
    return Device(n_instances=n_instances, topology=topology, policy=policy,
                  wait_policy=wait_policy, config_kw=cfg_kw or None,
                  max_retries=max_retries, backoff_base_s=backoff_base_s,
                  validate=validate, trace=trace)
