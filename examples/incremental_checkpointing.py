"""Fault-tolerance example: incremental checkpoints (delta records + CRC32 +
dualcast replica), corruption detection, and elastic restore.

    PYTHONPATH=src python examples/incremental_checkpointing.py
"""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager

rng = np.random.default_rng(0)
state = {"w": jnp.asarray(rng.normal(size=(512, 512)), jnp.float32),
         "m": jnp.zeros((512, 512), jnp.float32)}

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(CheckpointConfig(
        directory=str(Path(d) / "ck"), full_every=100, replicas=2, async_save=False))

    mgr.save(1, state)  # full snapshot
    # training drift: 0.5% of weights change per "step"
    for step in (2, 3):
        flat = np.asarray(state["w"]).reshape(-1).copy()
        idx = rng.choice(flat.size, flat.size // 200, replace=False)
        flat[idx] += 0.01
        state = {**state, "w": jnp.asarray(flat.reshape(512, 512))}
        mgr.save(step, state)

    print(f"saves: {mgr.all_steps()}  stats: {mgr.stats}")
    print(f"delta saved {mgr.stats['bytes_saved_by_delta']/1e6:.2f}MB vs full snapshots")

    # corrupt the newest save's primary copy; CRC detects it and the replica
    # (dualcast) recovers
    newest = Path(d) / "ck" / "step_00000003"
    victim = next(newest.glob("*.bin"), None) or next(newest.glob("*.npz"))
    raw = bytearray(victim.read_bytes())
    raw[5] ^= 0xFF
    victim.write_bytes(bytes(raw))

    step, restored = mgr.restore(treedef_like=state)
    ok = np.allclose(np.asarray(restored["w"]), np.asarray(state["w"]))
    print(f"restored step {step} after corruption; exact={ok} (replica recovered it)")
