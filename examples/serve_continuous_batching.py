"""Serving example: continuous batching with the Vhost-style 3-stage async
pipeline (paper §6.4) — batched prompt copies through the engine, in-order
admission via the reorder array, decode overlapped with page movement.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_device
from repro.models.api import build_model
from repro.serving.kv_pool import PagedKVPool
from repro.serving.pipeline import Request, VhostStyleServer

cfg = get_config("gemma3-1b").reduced()
model = build_model(cfg, remat=False)
params = model.init(jax.random.key(0))

server = VhostStyleServer(model, params, slots=4, max_cache_len=96,
                          device=make_device(n_instances=2, policy="least_loaded"))
rng = np.random.default_rng(0)
for i in range(10):
    server.enqueue(Request(req_id=i,
                           prompt=rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                           max_new_tokens=6))
t0 = time.perf_counter()
steps = server.run_until_drained()
dt = time.perf_counter() - t0
m = server.metrics
print(f"served {m['completed']} requests in {steps} pipeline steps / {dt:.1f}s; "
      f"{m['decoded_tokens']} tokens; {m['copy_bursts']} batched copy bursts")

# --- two-tier paged KV pool: batch-descriptor swap in/out ---------------------
pool = PagedKVPool(n_device_pages=16, n_host_pages=32, page_tokens=16,
                   kv_dim=cfg.num_kv_heads * cfg.head_dim)
pool.alloc(seq_id=0, n_pages=4)
import jax.numpy as jnp
for p in range(4):
    pool.write_page(0, p, jnp.ones((16, cfg.num_kv_heads * cfg.head_dim)) * p)
pool.swap_out(0)   # device -> host, ONE batch descriptor
pool.swap_in(0)    # host -> device
print(f"kv pool: {pool.stats.pages_moved} pages moved in "
      f"{pool.stats.batch_copies} batch copies; roundtrip ok")
