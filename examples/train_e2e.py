"""End-to-end training driver example: a ~1M-param tinyllama-family model
for a few hundred steps with async incremental checkpointing and restart.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(Full-size runs use the same driver: repro.launch.train --no-reduced with a
production mesh.)
"""
import argparse
import sys

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()

    ns = argparse.Namespace(
        arch=args.arch, reduced=True, steps=args.steps, batch=8, seq=128,
        lr=1e-3, micro_steps=2, seed=0, ckpt_dir="/tmp/repro_example_ckpt",
        ckpt_every=50, full_every=4, replicas=2, log_every=25, no_remat=False,
    )
    final = train(ns)
    print(f"reached step {final}")


if __name__ == "__main__":
    main()
