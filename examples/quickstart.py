"""Quickstart: the DSA-style streaming engine in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

The entry point is a ``Device``: N engine instances (paper Fig. 10) behind
a submit policy.  Every submission returns a ``Future`` — wait on it, poll
it, chain host work with ``.then``, or pass it as ``after=`` to fence a
later descriptor on it (DSA batch-fence semantics across submissions).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import OpType, WorkDescriptor, make_device

# A device over 2 engine instances, placing each submission on the least
# loaded instance (paper Fig. 10: multi-instance load balancing).
device = make_device(n_instances=2, policy="least_loaded")

# --- async memcpy (G2: async always) ----------------------------------------
x = jnp.asarray(np.random.default_rng(0).normal(size=(1024, 128)), jnp.float32)
fut = device.memcpy_async(x)
# ... host does other work here while the engine streams ...
y = fut.result()
print(f"memcpy: {fut.record.bytes_processed} bytes, "
      f"modeled TPU time {fut.record.modeled_time_us:.1f}us, status={fut.status.name}")

# --- chaining: host continuation fires when the copy retires -----------------
crc_hex = device.crc32_async(x).then(lambda c: f"0x{int(c):08x}")
import zlib
assert crc_hex.result() == f"0x{zlib.crc32(np.asarray(x, '<f4').tobytes()) & 0xFFFFFFFF:08x}"
print(f"crc32: {crc_hex.result()} (matches zlib, via .then)")

# --- dependency fences: `after=` defers launch until parents retire ----------
gate = device.promise()  # a host-event fence
fenced = device.memcpy_async(x, after=[gate])
device.kick()
assert not fenced.done()  # parked in the engine's fence list, not launched
gate.set_result(None)     # host event fires -> the engine releases the copy
assert np.allclose(np.asarray(fenced.result()), np.asarray(x))
print("fence: copy deferred until the promise retired, then launched")

# --- delta records (incremental state) ---------------------------------------
base = jnp.asarray(np.random.default_rng(1).integers(0, 2**31, 4096), jnp.uint32)
changed = base.at[jnp.asarray([7, 99, 2048])].add(1)
offsets, data, count, overflow = device.delta_create_async(changed, base, cap=64).result()
restored = device.delta_apply(base, offsets, data)
assert (np.asarray(restored) == np.asarray(changed)).all()
print(f"delta: {int(count)} changed words, overflow={bool(overflow)}; roundtrip exact")

# --- batch descriptor (F2: one submission, many copies) ----------------------
descs = [WorkDescriptor(op=OpType.MEMCPY, src=jnp.full((8, 128), i, jnp.float32))
         for i in range(8)]
outs = device.batch_async(descs).result()
print(f"batch: {len(outs)} copies fused into one kernel launch")

# --- where did the policy place everything? ----------------------------------
device.drain()
placed = dict(device.policy_stats["decisions"])
print(f"policy={device.policy_stats['policy']} placements={placed}")
print("done.")
