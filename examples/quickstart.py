"""Quickstart: the DSA-style streaming engine in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import OpType, WorkDescriptor, make_stream

# A stream over 2 engine instances (paper Fig. 10), each with the default
# SPR-like shape: groups of WQs + 4 PEs.
stream = make_stream(n_instances=2)

# --- async memcpy (G2: async always) ---------------------------------------
x = jnp.asarray(np.random.default_rng(0).normal(size=(1024, 128)), jnp.float32)
handle = stream.memcpy_async(x)
# ... host does other work here while the engine streams ...
y = stream.wait(handle)
_, record = handle
print(f"memcpy: {record.bytes_processed} bytes, "
      f"modeled TPU time {record.modeled_time_us:.1f}us, status={record.status.name}")

# --- batch descriptor (F2: one submission, many copies) ---------------------
descs = [WorkDescriptor(op=OpType.MEMCPY, src=jnp.full((8, 128), i, jnp.float32))
         for i in range(8)]
outs = stream.wait(stream.batch_async(descs))
print(f"batch: {len(outs)} copies fused into one kernel launch")

# --- CRC32 (zlib-compatible, chunk-parallel on TPU) --------------------------
crc = stream.crc32(x)
import zlib
assert crc == zlib.crc32(np.asarray(x, '<f4').tobytes()) & 0xFFFFFFFF
print(f"crc32: 0x{crc:08x} (matches zlib)")

# --- delta records (incremental state) ---------------------------------------
base = jnp.asarray(np.random.default_rng(1).integers(0, 2**31, 4096), jnp.uint32)
changed = base.at[jnp.asarray([7, 99, 2048])].add(1)
offsets, data, count, overflow = stream.delta_create(changed, base, cap=64)
print(f"delta: {int(count)} changed words, overflow={bool(overflow)}")
restored = stream.delta_apply(base, offsets, data)
assert (np.asarray(restored) == np.asarray(changed)).all()
print("delta apply: roundtrip exact")

stream.drain()
print("done.")
